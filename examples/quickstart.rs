//! Quickstart: load the AOT artifacts, run one batch through the PJRT
//! runtime, and print the logits — the smallest possible end-to-end check
//! that the three layers compose (Pallas kernel → JAX model → HLO text →
//! rust PJRT execution).
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use orloj::core::request::{AppId, Request};
use orloj::runtime::executor::PjrtWorker;
use orloj::runtime::ModelRuntime;
use orloj::sim::worker::Worker;
use std::path::Path;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let dir = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "artifacts".to_string());
    println!("loading artifacts from {dir}/ ...");
    let rt = Arc::new(ModelRuntime::load(Path::new(&dir))?);
    println!(
        "platform={} variants={} (depths 1..{} × batch sizes {:?})",
        rt.platform(),
        rt.variant_count(),
        rt.manifest.model.max_depth,
        rt.manifest.batch_sizes
    );

    // Run one real batch at depth 2.
    let seq = rt.manifest.model.seq;
    let tokens: Vec<i32> = (0..2 * seq).map(|i| (i % 7) as i32).collect();
    let logits = rt.execute(2, 2, &tokens)?;
    println!(
        "executed (depth=2, batch=2): {} logits, first row = {:?}",
        logits.len(),
        &logits[..rt.manifest.model.classes.min(8)]
    );

    // Calibrate per-depth solo latency — the numbers the serving examples
    // feed to the schedulers' profilers.
    let mut worker = PjrtWorker::new(rt.clone());
    println!("calibrating per-depth latency (bs=1):");
    for (depth, ms) in worker.calibrate(20) {
        println!("  depth {depth}: {ms:.3} ms");
    }

    // And one timed batch through the Worker interface.
    let batch: Vec<Request> = (0..4)
        .map(|i| Request::new(i, AppId(0), 0, 1_000_000, 1.0).with_variant(1 + (i % 2) as u32))
        .collect();
    let ms = worker.execute(&batch);
    println!("mixed-depth batch of 4 executed in {ms:.3} ms (ran at depth 2)");
    println!("quickstart OK");
    Ok(())
}
