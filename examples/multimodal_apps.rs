//! Multimodal multi-application scenario (the §2.2 "Handling multimodal
//! distribution" challenge): three applications share one model — a fast
//! vision-style app, a medium chat app, and a slow summarization app —
//! and we report *per-app* finish rates for each system.
//!
//! The point this example demonstrates: point-estimate schedulers trade
//! the short app's SLOs away (its requests get stuck behind long-app
//! stragglers in shared batches), while Orloj's per-app distributions and
//! batch-aware score keep all three apps served.
//!
//! Run: `cargo run --release --example multimodal_apps`

use orloj::baselines::{self, PAPER_SYSTEMS};
use orloj::core::batchmodel::BatchCostModel;
use orloj::scheduler::SchedulerConfig;
use orloj::server::metrics::RunReport;
use orloj::sim::{engine, worker::SimWorker};
use orloj::util::cli::Args;
use orloj::workload::azure::AzureTraceConfig;
use orloj::workload::exectime::ExecTimeDist;
use orloj::workload::trace::TraceSpec;

fn main() {
    let args = Args::from_env();
    let duration = args.get_f64("duration", 40.0);
    let slo = args.get_f64("slo", 3.0);

    // Three apps with very different execution-time profiles.
    let dists = vec![
        ExecTimeDist::codepaths("vision", &[4.0, 6.0, 9.0], &[0.5, 0.35, 0.15]),
        ExecTimeDist::lognormal_mean_p99("chat", 30.0, 70.0),
        ExecTimeDist::lognormal_mean_p99("summarize", 90.0, 180.0),
    ];
    let mean = 40.0; // rough mixture mean for calibration
    let cost_model = BatchCostModel::calibrated(mean);
    let cfg = SchedulerConfig {
        cost_model,
        ..Default::default()
    };
    let mut spec = TraceSpec {
        name: "multimodal".into(),
        dists,
        arrivals: AzureTraceConfig {
            apps: 3,
            rate_per_s: 0.0,
            duration_s: duration,
            ..Default::default()
        },
        seed: args.get_u64("seed", 7),
        models: Vec::new(),
    };
    spec.scale_rate_to_load(cost_model, 0.9, 8);
    let trace = spec.generate();
    println!(
        "trace: {} requests over {duration}s (rate {:.0}/s), SLO = {slo}×P99 ({:.0} ms)",
        trace.events.len(),
        spec.arrivals.rate_per_s,
        slo * trace.p99_ms
    );

    println!(
        "\n{:>10} {:>8} {:>14} {:>14} {:>14}",
        "system", "overall", "vision(app0)", "chat(app1)", "summ(app2)"
    );
    for system in PAPER_SYSTEMS {
        let mut sched = baselines::by_name(system, cfg.clone(), spec.seed).unwrap();
        for (model, app, hist) in spec.seed_histograms(cfg.bins) {
            sched.seed_app_profile(model, app, &hist, 1000);
        }
        let mut worker = SimWorker::new(cost_model, 0.0, 99);
        let res = engine::run(sched.as_mut(), &mut worker, trace.requests(slo));
        let report = RunReport::from_completions(&res.completions);
        let app_rate = |a: u32| {
            report
                .per_app
                .get(&a)
                .map(|(f, t)| *f as f64 / (*t).max(1) as f64)
                .unwrap_or(0.0)
        };
        println!(
            "{:>10} {:>8.3} {:>14.3} {:>14.3} {:>14.3}",
            system,
            report.finish_rate(),
            app_rate(0),
            app_rate(1),
            app_rate(2)
        );
    }
    println!("\nmultimodal_apps OK");
}
