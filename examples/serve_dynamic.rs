//! END-TO-END driver: serve the real early-exit transformer through the
//! full stack — AOT artifacts → PJRT runtime → rust coordinator — under an
//! open-loop trace with data-dependent depths, and report finish rate /
//! latency / throughput for Orloj vs a baseline.
//!
//! This is the proof that all layers compose: the Pallas-kernel model
//! compiled by `make artifacts` really executes on the request path, batch
//! latency genuinely varies with the batch's max early-exit depth, and the
//! schedulers react to measured (not simulated) time.
//!
//! Run: `make artifacts && cargo run --release --example serve_dynamic [-- --requests 400]`

use orloj::clock::ms_to_us;
use orloj::core::batchmodel::BatchCostModel;
use orloj::core::request::{AppId, Request};
use orloj::runtime::executor::PjrtWorker;
use orloj::runtime::ModelRuntime;
use orloj::scheduler::{Scheduler, SchedulerConfig};
use orloj::server::metrics::RunReport;
use orloj::server::Server;
use orloj::sim::worker::Worker;
use orloj::util::cli::Args;
use orloj::util::rng::Rng;
use orloj::util::stats;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

struct Workload {
    /// (delay before submit µs, depth)
    arrivals: Vec<(u64, u32)>,
    slo_ms: f64,
}

fn build_workload(n: usize, max_depth: usize, mean_gap_us: f64, slo_ms: f64, seed: u64) -> Workload {
    let mut rng = Rng::new(seed);
    let arrivals = (0..n)
        .map(|_| {
            // Two "apps": shallow-exit traffic and deep-exit traffic.
            let depth = if rng.chance(0.6) {
                1 + rng.index(2) as u32 // depths 1-2
            } else {
                max_depth as u32 // deep path
            };
            (rng.exponential(1.0 / mean_gap_us) as u64, depth)
        })
        .collect();
    Workload { arrivals, slo_ms }
}

fn run_system(
    system: &str,
    runtimes: &[Arc<ModelRuntime>],
    wl: &Workload,
    calib: &[(usize, f64)],
    cost: BatchCostModel,
    router: &str,
) -> (RunReport, f64) {
    let cfg = SchedulerConfig {
        cost_model: cost,
        batch_sizes: runtimes[0].manifest.batch_sizes.clone(),
        refresh_every: 200_000,
        ..Default::default()
    };
    // One scheduler replica + one PJRT worker per runtime handle, behind
    // the unified serve core's router front-end (each replica owns its
    // PJRT client — see runtime::executor::pjrt_replicas).
    let replicas = orloj::runtime::executor::pjrt_replicas(system, &cfg, 7, calib, runtimes)
        .expect("system");
    let (submitter, rx) = Server::<Box<dyn Scheduler>, PjrtWorker>::channel();
    let server = Server::cluster(replicas, orloj::serve::router::by_name(router).expect("router"));
    let handle = std::thread::spawn(move || server.run(rx));
    let t0 = Instant::now();
    for (i, (gap_us, depth)) in wl.arrivals.iter().enumerate() {
        std::thread::sleep(Duration::from_micros(*gap_us));
        let release = t0.elapsed().as_micros() as u64;
        let exec_ms = calib
            .iter()
            .find(|(d, _)| *d == *depth as usize)
            .map(|(_, m)| *m)
            .unwrap_or(1.0);
        let req = Request::new(i as u64, AppId(depth - 1), release, ms_to_us(wl.slo_ms), exec_ms)
            .with_variant(*depth);
        submitter.submit(req);
    }
    drop(submitter);
    let res = handle.join().unwrap();
    let wall_s = t0.elapsed().as_secs_f64();
    let report = RunReport::from_completions(&res.completions)
        .with_worker_stats(&res.per_worker, res.end_time);
    let throughput = report.total as f64 / wall_s;
    (report, throughput)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let dir = args.get_or("artifacts", "artifacts").to_string();
    let n = args.get_usize("requests", 400);
    let rt = Arc::new(ModelRuntime::load(Path::new(&dir))?);
    println!(
        "loaded {} variants on {} (depths 1..{}, batch sizes {:?})",
        rt.variant_count(),
        rt.platform(),
        rt.manifest.model.max_depth,
        rt.manifest.batch_sizes
    );

    // Calibrate real per-depth latencies and fit the linear batch model
    // from measured batch runs.
    let mut worker = PjrtWorker::new(rt.clone());
    let calib = worker.calibrate(30);
    println!("per-depth solo latency: {calib:?}");
    let mean_solo = stats::mean(&calib.iter().map(|(_, m)| *m).collect::<Vec<_>>());
    // Measure batch latency at max depth for each supported size → fit c0/c1.
    let max_depth = rt.manifest.model.max_depth;
    let deep_ms = calib.last().map(|(_, m)| *m).unwrap_or(mean_solo);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for &bs in &rt.manifest.batch_sizes {
        let batch: Vec<Request> = (0..bs)
            .map(|i| {
                Request::new(i as u64, AppId(0), 0, 1_000_000, deep_ms)
                    .with_variant(max_depth as u32)
            })
            .collect();
        let _ = worker.execute(&batch); // warm
        let t0 = Instant::now();
        let reps = 10;
        for _ in 0..reps {
            let _ = worker.execute(&batch);
        }
        let ms = t0.elapsed().as_secs_f64() * 1000.0 / reps as f64;
        println!("  measured batch (depth={max_depth}, bs={bs}): {ms:.3} ms");
        xs.push(bs as f64 * deep_ms);
        ys.push(ms);
    }
    // Least-squares fit ms = c0 + c1·(k·l).
    let xm = stats::mean(&xs);
    let ym = stats::mean(&ys);
    let c1 = xs
        .iter()
        .zip(&ys)
        .map(|(x, y)| (x - xm) * (y - ym))
        .sum::<f64>()
        / xs.iter().map(|x| (x - xm) * (x - xm)).sum::<f64>().max(1e-9);
    let c0 = (ym - c1 * xm).max(0.0);
    let c1 = c1.max(0.01);
    println!("fitted batch cost model: c0={c0:.3} ms, c1={c1:.3}");
    let cost = BatchCostModel::new(c0, c1);

    // Open-loop workload: SLO = 12× the deep solo latency; arrival rate
    // ~70% of fitted bs=8 capacity.
    let cap8 = 8.0 / (cost.latency(8, deep_ms) / 1000.0);
    let rate = 0.7 * cap8;
    let gap_us = 1e6 / rate;
    let slo_ms = args.get_f64("slo-ms", 12.0 * deep_ms);
    println!("offered rate ≈ {rate:.0} req/s (70% of bs=8 capacity), SLO = {slo_ms:.1} ms");
    let wl = build_workload(n, max_depth, gap_us, slo_ms, 2024);

    let workers = args.get_usize("workers", 1).max(1);
    let router = args.get_or("router", "round_robin").to_string();
    // Load the extra per-replica runtimes once and reuse them across the
    // system sweep (the worker threads of one system are joined before the
    // next system runs, so sequential reuse is single-threaded).
    let runtimes: Vec<Arc<ModelRuntime>> = std::iter::once(rt.clone())
        .chain(
            (1..workers).map(|_| Arc::new(ModelRuntime::load(Path::new(&dir)).expect("load"))),
        )
        .collect();
    println!(
        "\n{:>10} {:>12} {:>12} {:>12} {:>12} {:>10}  ({} replica(s), router={router})",
        "system", "finish_rate", "p50(ms)", "p99(ms)", "thru(r/s)", "mean_bs", workers
    );
    let mut rows = Vec::new();
    for system in ["clockwork", "edf", "orloj"] {
        let (report, thru) = run_system(system, &runtimes, &wl, &calib, cost, &router);
        println!(
            "{:>10} {:>12.3} {:>12.2} {:>12.2} {:>12.0} {:>10.1}",
            system,
            report.finish_rate(),
            report.latency.p50,
            report.latency.p99,
            thru,
            report.mean_batch_size
        );
        if workers > 1 {
            let utils: Vec<String> = report
                .per_worker
                .iter()
                .map(|w| format!("w{}={:.2}({}b)", w.worker, w.utilization, w.batches))
                .collect();
            println!("{:>10} per-worker: {}", "", utils.join(" "));
        }
        rows.push((system, report.finish_rate()));
    }
    println!("\nserve_dynamic OK — record these rows in EXPERIMENTS.md §End-to-end");
    Ok(())
}
