"""Pure-jnp oracle for the Pallas transformer block kernel.

The CORE correctness signal (pytest asserts kernel ≡ ref across shapes and
dtypes). Intentionally written independently of the kernel: batched einsum
formulation instead of the kernel's per-example grid.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

EPS = 1e-5


def _ln(x, g, b):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + EPS) * g + b


def transformer_block_ref(x, params, *, heads: int):
    """Reference pre-LN transformer block. x: (batch, seq, d)."""
    bs, seq, d = x.shape
    dh = d // heads

    h = _ln(x, params["ln1_g"], params["ln1_b"])
    q = h @ params["wq"]
    k = h @ params["wk"]
    v = h @ params["wv"]

    def split(t):  # (bs, seq, d) -> (bs, heads, seq, dh)
        return t.reshape(bs, seq, heads, dh).transpose(0, 2, 1, 3)

    q, k, v = split(q), split(k), split(v)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(
        jnp.asarray(dh, x.dtype)
    )
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(bs, seq, d)
    x = x + ctx @ params["wo"]

    h2 = _ln(x, params["ln2_g"], params["ln2_b"])
    f = jax.nn.gelu(h2 @ params["w1"] + params["b1"])
    return x + f @ params["w2"] + params["b2"]
