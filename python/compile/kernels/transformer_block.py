"""L1 — Pallas kernel: one pre-LN transformer block (attention + FFN).

This is the compute hot-spot of the served early-exit transformer. The
kernel fuses LayerNorm → multi-head self-attention → residual → LayerNorm →
FFN → residual for one batch element per grid step.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid iterates over the
batch dimension and each grid step's operand blocks — the (seq, d) activation
tile plus the weight matrices — are the VMEM working set; the matmuls
(QKᵀ, attention·V, and the FFN GEMMs) are MXU work. BlockSpec expresses the
HBM↔VMEM schedule a CUDA implementation would write with threadblocks.

Lowered with ``interpret=True``: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so interpret mode (which lowers to plain HLO) is the
correctness/serving path; real-TPU numbers are estimated analytically in
DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-5


def _layernorm(x, gamma, beta):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + EPS) * gamma + beta


def _block_kernel(
    x_ref,
    wq_ref,
    wk_ref,
    wv_ref,
    wo_ref,
    w1_ref,
    b1_ref,
    w2_ref,
    b2_ref,
    g1_ref,
    be1_ref,
    g2_ref,
    be2_ref,
    o_ref,
    *,
    heads: int,
):
    """One batch element: x (1, seq, d) -> o (1, seq, d)."""
    x = x_ref[0]  # (seq, d)
    seq, d = x.shape
    dh = d // heads

    # --- attention sub-layer (pre-LN) ---
    h = _layernorm(x, g1_ref[...], be1_ref[...])
    q = h @ wq_ref[...]
    k = h @ wk_ref[...]
    v = h @ wv_ref[...]
    # (seq, d) -> (heads, seq, dh)
    q = q.reshape(seq, heads, dh).transpose(1, 0, 2)
    k = k.reshape(seq, heads, dh).transpose(1, 0, 2)
    v = v.reshape(seq, heads, dh).transpose(1, 0, 2)
    scores = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(jnp.asarray(dh, x.dtype))
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("hqk,hkd->hqd", probs, v)
    ctx = ctx.transpose(1, 0, 2).reshape(seq, d)
    x = x + ctx @ wo_ref[...]

    # --- FFN sub-layer (pre-LN) ---
    h2 = _layernorm(x, g2_ref[...], be2_ref[...])
    f = jax.nn.gelu(h2 @ w1_ref[...] + b1_ref[...])
    x = x + f @ w2_ref[...] + b2_ref[...]

    o_ref[0] = x


def transformer_block(x, params, *, heads: int, interpret: bool = True):
    """Apply one transformer block via the Pallas kernel.

    x: (batch, seq, d) activations.
    params: dict with wq/wk/wv/wo (d,d), w1 (d,f), b1 (f,), w2 (f,d),
            b2 (d,), ln1_g/ln1_b/ln2_g/ln2_b (d,).
    """
    bs, seq, d = x.shape
    f = params["w1"].shape[1]
    whole = lambda shape: pl.BlockSpec(shape, lambda i: tuple(0 for _ in shape))
    kernel = functools.partial(_block_kernel, heads=heads)
    return pl.pallas_call(
        kernel,
        grid=(bs,),
        in_specs=[
            pl.BlockSpec((1, seq, d), lambda i: (i, 0, 0)),
            whole((d, d)),
            whole((d, d)),
            whole((d, d)),
            whole((d, d)),
            whole((d, f)),
            whole((f,)),
            whole((f, d)),
            whole((d,)),
            whole((d,)),
            whole((d,)),
            whole((d,)),
            whole((d,)),
        ],
        out_specs=pl.BlockSpec((1, seq, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bs, seq, d), x.dtype),
        interpret=interpret,
    )(
        x,
        params["wq"],
        params["wk"],
        params["wv"],
        params["wo"],
        params["w1"],
        params["b1"],
        params["w2"],
        params["b2"],
        params["ln1_g"],
        params["ln1_b"],
        params["ln2_g"],
        params["ln2_b"],
    )


def init_block_params(key, d: int, f: int, dtype=jnp.float32):
    """Deterministic block parameter initialization."""
    ks = jax.random.split(key, 6)
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    return {
        "wq": (jax.random.normal(ks[0], (d, d)) * scale).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, d)) * scale).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, d)) * scale).astype(dtype),
        "wo": (jax.random.normal(ks[3], (d, d)) * scale).astype(dtype),
        "w1": (jax.random.normal(ks[4], (d, f)) * scale).astype(dtype),
        "b1": jnp.zeros((f,), dtype),
        "w2": (jax.random.normal(ks[5], (f, d)) * scale).astype(dtype),
        "b2": jnp.zeros((d,), dtype),
        "ln1_g": jnp.ones((d,), dtype),
        "ln1_b": jnp.zeros((d,), dtype),
        "ln2_g": jnp.ones((d,), dtype),
        "ln2_b": jnp.zeros((d,), dtype),
    }
