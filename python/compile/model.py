"""L2 — the served model: an early-exit transformer classifier in JAX.

The paper's *dynamic DNN* stand-in (SkipNet / RDI-Nets class): the model has
``max_depth`` transformer blocks and an exit head after every block. A
request "needs" some depth ``d`` (data-dependent in the real systems); a
batch must run at the max depth of its members — the straggler effect Orloj
schedules around. Serving-side, each (depth, batch) pair is one AOT-compiled
PJRT executable (see ``aot.py``); the rust coordinator picks the variant.

Parameters are generated deterministically from a seed at AOT time and baked
into the HLO as constants, so the rust runtime needs nothing but the
artifact files (python never runs on the request path).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .kernels.transformer_block import init_block_params, transformer_block


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 128
    seq: int = 16
    d_model: int = 64
    ffn: int = 128
    heads: int = 4
    classes: int = 16
    max_depth: int = 4
    seed: int = 0

    def validate(self):
        assert self.d_model % self.heads == 0
        assert self.max_depth >= 1


def init_params(cfg: ModelConfig):
    """All model parameters from the config seed."""
    cfg.validate()
    root = jax.random.PRNGKey(cfg.seed)
    k_embed, k_pos, k_blocks, k_heads = jax.random.split(root, 4)
    blocks = [
        init_block_params(k, cfg.d_model, cfg.ffn)
        for k in jax.random.split(k_blocks, cfg.max_depth)
    ]
    # One classifier head per exit depth (RDI-Nets style multi-exit).
    head_keys = jax.random.split(k_heads, cfg.max_depth)
    heads = [
        {
            "w": jax.random.normal(k, (cfg.d_model, cfg.classes))
            / jnp.sqrt(jnp.asarray(cfg.d_model, jnp.float32)),
            "b": jnp.zeros((cfg.classes,), jnp.float32),
        }
        for k in head_keys
    ]
    return {
        "embed": jax.random.normal(k_embed, (cfg.vocab, cfg.d_model)) * 0.02,
        "pos": jax.random.normal(k_pos, (cfg.seq, cfg.d_model)) * 0.02,
        "blocks": blocks,
        "heads": heads,
    }


def forward(params, tokens, *, cfg: ModelConfig, depth: int, interpret: bool = True):
    """Run the model to exit `depth` (1-based). tokens: (bs, seq) int32."""
    assert 1 <= depth <= cfg.max_depth
    x = params["embed"][tokens] + params["pos"][None, :, :]
    for i in range(depth):
        x = transformer_block(
            x, params["blocks"][i], heads=cfg.heads, interpret=interpret
        )
    head = params["heads"][depth - 1]
    pooled = jnp.mean(x, axis=1)  # (bs, d)
    logits = pooled @ head["w"] + head["b"]
    return logits


def make_apply(params, cfg: ModelConfig, depth: int, interpret: bool = True):
    """Closure over params (baked as HLO constants when lowered)."""

    def apply(tokens):
        return (forward(params, tokens, cfg=cfg, depth=depth, interpret=interpret),)

    return apply
