"""AOT lowering: early-exit transformer → HLO text artifacts + manifest.

Emits one HLO **text** file per (depth, batch-size) variant — text, NOT
``.serialize()``: jax ≥ 0.5 emits HloModuleProto with 64-bit instruction
ids that the rust side's xla_extension 0.5.1 rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Run as:  cd python && python -m compile.aot --out ../artifacts
The Makefile `artifacts` target does exactly that and is a no-op when the
inputs are unchanged.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import ModelConfig, init_params, make_apply

BATCH_SIZES = [1, 2, 4, 8]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the interchange format).

    ``print_large_constants`` is essential: the model weights are baked into
    the graph as constants, and the default printer elides anything big as
    ``constant({...})``, which the rust-side text parser cannot reconstruct.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # jax ≥ 0.8 emits source_end_line/source_end_column metadata attributes
    # that xla_extension 0.5.1's text parser rejects; metadata is debug-only.
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def lower_variant(params, cfg: ModelConfig, depth: int, batch: int) -> str:
    apply = make_apply(params, cfg, depth, interpret=True)
    spec = jax.ShapeDtypeStruct((batch, cfg.seq), jnp.int32)
    lowered = jax.jit(apply).lower(spec)
    return to_hlo_text(lowered)


def build(out_dir: str, cfg: ModelConfig, batch_sizes=None, verbose=True) -> dict:
    batch_sizes = batch_sizes or BATCH_SIZES
    os.makedirs(out_dir, exist_ok=True)
    params = init_params(cfg)
    variants = []
    t0 = time.time()
    for depth in range(1, cfg.max_depth + 1):
        for bs in batch_sizes:
            name = f"model_d{depth}_b{bs}.hlo.txt"
            path = os.path.join(out_dir, name)
            text = lower_variant(params, cfg, depth, bs)
            with open(path, "w") as f:
                f.write(text)
            variants.append(
                {"depth": depth, "batch": bs, "path": name, "bytes": len(text)}
            )
            if verbose:
                print(f"  wrote {name} ({len(text)//1024} KiB)")
    # Golden outputs: canonical tokens → logits per depth at bs=1, so the
    # rust runtime can assert numerics parity with the python build path.
    golden_tokens = [(i * 7 + 3) % cfg.vocab for i in range(cfg.seq)]
    golden = []
    tok = jnp.asarray([golden_tokens], dtype=jnp.int32)
    for depth in range(1, cfg.max_depth + 1):
        logits = make_apply(params, cfg, depth)(tok)[0]
        golden.append(
            {"depth": depth, "logits": [float(x) for x in logits[0]]}
        )
    manifest = {
        "model": "early-exit-transformer",
        "golden": {"tokens": golden_tokens, "outputs": golden},
        "config": {
            "vocab": cfg.vocab,
            "seq": cfg.seq,
            "d_model": cfg.d_model,
            "ffn": cfg.ffn,
            "heads": cfg.heads,
            "classes": cfg.classes,
            "max_depth": cfg.max_depth,
            "seed": cfg.seed,
        },
        "batch_sizes": batch_sizes,
        "variants": variants,
        "build_seconds": round(time.time() - t0, 2),
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if verbose:
        print(f"manifest: {len(variants)} variants in {manifest['build_seconds']}s")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--max-depth", type=int, default=4)
    ap.add_argument(
        "--batch-sizes", default="1,2,4,8", help="comma-separated batch sizes"
    )
    ap.add_argument("--smoke", action="store_true", help="tiny grid for CI")
    args = ap.parse_args()
    cfg = ModelConfig(max_depth=1 if args.smoke else args.max_depth)
    bss = [1] if args.smoke else [int(x) for x in args.batch_sizes.split(",")]
    build(args.out, cfg, bss)


if __name__ == "__main__":
    main()
