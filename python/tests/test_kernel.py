"""L1 correctness: Pallas kernel vs pure-jnp oracle.

The CORE correctness signal for the compute layer — exact shapes used in
serving plus a hypothesis sweep over shapes/dtypes/seeds.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import transformer_block_ref
from compile.kernels.transformer_block import (
    init_block_params,
    transformer_block,
)


def _params_and_input(seed, bs, seq, d, f, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    kp, kx = jax.random.split(key)
    params = init_block_params(kp, d, f, dtype=dtype)
    x = jax.random.normal(kx, (bs, seq, d), dtype=dtype)
    return params, x


@pytest.mark.parametrize("bs", [1, 2, 4, 8])
def test_kernel_matches_ref_serving_shapes(bs):
    params, x = _params_and_input(0, bs, 16, 64, 128)
    got = transformer_block(x, params, heads=4)
    want = transformer_block_ref(x, params, heads=4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    bs=st.integers(1, 4),
    seq=st.sampled_from([4, 8, 16]),
    dh=st.sampled_from([8, 16]),
    heads=st.sampled_from([1, 2, 4]),
)
def test_kernel_matches_ref_hypothesis(seed, bs, seq, dh, heads):
    d = dh * heads
    f = 2 * d
    params, x = _params_and_input(seed, bs, seq, d, f)
    got = transformer_block(x, params, heads=heads)
    want = transformer_block_ref(x, params, heads=heads)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


def test_kernel_bfloat16_close_to_f32_ref():
    params32, x32 = _params_and_input(7, 2, 8, 32, 64)
    params16 = {k: v.astype(jnp.bfloat16) for k, v in params32.items()}
    x16 = x32.astype(jnp.bfloat16)
    got = transformer_block(x16, params16, heads=4).astype(jnp.float32)
    want = transformer_block_ref(x32, params32, heads=4)
    # bf16 has ~3 decimal digits; block has residuals so error stays tame.
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0.15, atol=0.15)


def test_batch_elements_independent():
    # Grid iterates over batch: permuting inputs permutes outputs.
    params, x = _params_and_input(3, 4, 8, 32, 64)
    out = np.asarray(transformer_block(x, params, heads=4))
    perm = [2, 0, 3, 1]
    out_perm = np.asarray(transformer_block(x[jnp.array(perm)], params, heads=4))
    np.testing.assert_allclose(out[perm], out_perm, rtol=1e-6, atol=1e-6)


def test_kernel_is_deterministic():
    params, x = _params_and_input(5, 2, 16, 64, 128)
    a = np.asarray(transformer_block(x, params, heads=4))
    b = np.asarray(transformer_block(x, params, heads=4))
    np.testing.assert_array_equal(a, b)


def test_residual_path_preserves_scale():
    # Output should stay O(1): no exploding activations through the block.
    params, x = _params_and_input(9, 2, 16, 64, 128)
    out = np.asarray(transformer_block(x, params, heads=4))
    assert np.isfinite(out).all()
    assert np.abs(out).mean() < 10.0
