"""L2 correctness: early-exit model shapes, exit semantics, AOT lowering."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import build, lower_variant, to_hlo_text
from compile.model import ModelConfig, forward, init_params, make_apply

CFG = ModelConfig(max_depth=3)


@pytest.fixture(scope="module")
def params():
    return init_params(CFG)


def _tokens(bs, seed=0):
    return jax.random.randint(jax.random.PRNGKey(seed), (bs, CFG.seq), 0, CFG.vocab)


def test_forward_shapes(params):
    for bs in [1, 2, 4]:
        for depth in range(1, CFG.max_depth + 1):
            logits = forward(params, _tokens(bs), cfg=CFG, depth=depth)
            assert logits.shape == (bs, CFG.classes)
            assert bool(jnp.isfinite(logits).all())


def test_depths_give_different_outputs(params):
    t = _tokens(2)
    l1 = forward(params, t, cfg=CFG, depth=1)
    l2 = forward(params, t, cfg=CFG, depth=2)
    l3 = forward(params, t, cfg=CFG, depth=3)
    assert not np.allclose(np.asarray(l1), np.asarray(l2))
    assert not np.allclose(np.asarray(l2), np.asarray(l3))


def test_deterministic_params():
    a = init_params(CFG)
    b = init_params(CFG)
    np.testing.assert_array_equal(np.asarray(a["embed"]), np.asarray(b["embed"]))


def test_deeper_variant_lowers_to_larger_hlo(params):
    h1 = lower_variant(params, CFG, depth=1, batch=2)
    h3 = lower_variant(params, CFG, depth=3, batch=2)
    assert len(h3) > len(h1), "more blocks → more HLO"
    assert "ENTRY" in h1


def test_lowered_matches_eager(params):
    # The lowered/compiled variant computes the same numbers as eager.
    apply = make_apply(params, CFG, depth=2)
    t = _tokens(4, seed=3)
    eager = apply(t)[0]
    compiled = jax.jit(apply)(t)[0]
    np.testing.assert_allclose(
        np.asarray(eager), np.asarray(compiled), rtol=1e-5, atol=1e-5
    )


def test_hlo_text_parses_basics(params):
    text = lower_variant(params, CFG, depth=1, batch=1)
    # The format the rust loader expects: an HLO module with an ENTRY
    # computation returning a tuple.
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    assert "s32[1,16]" in text  # tokens input shape
    # Weights are baked as constants and must NOT be elided — the rust
    # text parser reconstructs them from the literal values.
    assert "constant({...})" not in text


def test_build_writes_manifest_and_artifacts():
    with tempfile.TemporaryDirectory() as d:
        cfg = ModelConfig(max_depth=2)
        manifest = build(d, cfg, batch_sizes=[1, 2], verbose=False)
        assert len(manifest["variants"]) == 4
        with open(os.path.join(d, "manifest.json")) as f:
            on_disk = json.load(f)
        assert on_disk["config"]["max_depth"] == 2
        for v in on_disk["variants"]:
            p = os.path.join(d, v["path"])
            assert os.path.exists(p)
            assert os.path.getsize(p) == v["bytes"]


def test_to_hlo_text_smoke():
    lowered = jax.jit(lambda x: (x @ x,)).lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float32)
    )
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
