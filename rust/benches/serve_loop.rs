//! Unified serving-loop dispatch hot-path benchmark (the in-tree harness —
//! the offline vendored set has no criterion, see `util::benchmark`):
//! events/sec of the clock-generic core at 1 vs. 4 workers, plus a
//! multi-model (2 models × 4 workers) case, so later scale-out PRs have a
//! baseline for the router + placement + dispatch overhead.
//!
//! An "event" is one `ServingLoop::on_event` ingestion: every arrival and
//! every batch completion (wakes ride along for free in both pumps).
//!
//! Emits `BENCH_serve.json` (see DESIGN.md §7 for how to read it) so the
//! perf trajectory is machine-readable. `ORLOJ_BENCH_QUICK=1` runs the
//! same cases on a short trace (the CI smoke).
//!
//! Run: `cargo bench --bench serve_loop`

use orloj::clock::VirtualClock;
use orloj::core::batchmodel::BatchCostModel;
use orloj::scheduler::SchedulerConfig;
use orloj::serve::{
    replay, router, AdmissionConfig, AdmissionController, Cluster, ElasticConfig, Placement,
    PlacementController, ServingLoop,
};
use orloj::sim::worker::SimWorker;
use orloj::util::benchmark::{json_report, quick_or};
use orloj::util::json::Json;
use orloj::workload::azure::AzureTraceConfig;
use orloj::workload::exectime::ExecTimeDist;
use orloj::workload::trace::{ModelTraffic, TraceSpec};
use std::time::Instant;

fn trace_duration_s() -> f64 {
    quick_or(6.0, 45.0)
}

#[allow(clippy::too_many_arguments)]
fn run_bench(
    system: &str,
    spec: &TraceSpec,
    cfg: &SchedulerConfig,
    n_workers: usize,
    router_name: &str,
    placement_spec: &str,
    label: &str,
    cases: &mut Vec<Json>,
) {
    let trace = spec.generate();
    let requests = trace.requests(3.0);
    let n_req = requests.len();
    let n_models = spec.models.len().max(1);
    let placement = Placement::parse(placement_spec, n_workers, n_models).unwrap();
    let mut cluster = Cluster::build_placed(system, cfg, 1, placement).unwrap();
    for (model, app, hist) in spec.seed_histograms(cfg.bins) {
        cluster.seed_app_profile(model, app, &hist, 1000);
    }
    let workers: Vec<SimWorker> = (0..n_workers)
        .map(|w| {
            SimWorker::new(cfg.cost_model, 0.0, 0x51 ^ (w as u64))
                .with_model_costs(spec.model_cost_models())
        })
        .collect();
    let core = ServingLoop::new(
        VirtualClock::new(),
        cluster,
        router::by_name(router_name).unwrap(),
    );
    let t0 = Instant::now();
    let res = replay::run_cluster(core, workers, requests);
    let wall = t0.elapsed().as_secs_f64();
    let events = res.completions.len() + res.batches;
    let events_per_s = events as f64 / wall;
    let req_per_s = n_req as f64 / wall;
    println!(
        "  {label:>24} x{n_workers} ({router_name:>19}): {n_req:>6} requests, {:>6} batches, \
         {:>9.0} events/s, {:>8.0} req/s wall",
        res.batches, events_per_s, req_per_s
    );
    assert_eq!(res.completions.len(), n_req, "conservation in bench run");
    cases.push(Json::obj(vec![
        ("label", Json::str(label)),
        ("system", Json::str(system)),
        ("workers", Json::num(n_workers as f64)),
        ("router", Json::str(router_name)),
        ("placement", Json::str(placement_spec)),
        ("models", Json::num(n_models as f64)),
        ("requests", Json::num(n_req as f64)),
        ("batches", Json::num(res.batches as f64)),
        ("events", Json::num(events as f64)),
        ("wall_s", Json::num(wall)),
        ("events_per_s", Json::num(events_per_s)),
        ("req_per_s", Json::num(req_per_s)),
        ("us_per_event", Json::num(wall * 1e6 / events.max(1) as f64)),
    ]));
}

fn single_model_spec(n_workers: usize) -> (TraceSpec, SchedulerConfig) {
    let model = BatchCostModel::calibrated(35.0);
    let mut spec = TraceSpec {
        name: "bench".into(),
        dists: vec![ExecTimeDist::multimodal("m3", 3, 10.0, 100.0, 1.0, None)],
        arrivals: AzureTraceConfig {
            apps: 1,
            rate_per_s: 0.0,
            duration_s: trace_duration_s(),
            ..Default::default()
        },
        seed: 1,
        models: Vec::new(),
    };
    // Offer n× one worker's capacity so every replica stays busy and the
    // dispatch path (not idle waiting) dominates.
    spec.scale_rate_to_load(model, 0.9 * n_workers as f64, 8);
    let cfg = SchedulerConfig {
        cost_model: model,
        ..Default::default()
    };
    (spec, cfg)
}

fn multi_model_spec(n_workers: usize) -> (TraceSpec, SchedulerConfig) {
    let model = BatchCostModel::calibrated(30.0);
    let mut spec = TraceSpec {
        name: "bench-mm".into(),
        dists: Vec::new(),
        arrivals: AzureTraceConfig {
            apps: 1,
            rate_per_s: 0.0,
            duration_s: trace_duration_s(),
            ..Default::default()
        },
        seed: 2,
        models: vec![
            ModelTraffic::new(0, 0.7, vec![ExecTimeDist::constant("hot", 12.0)]),
            ModelTraffic::new(
                1,
                0.3,
                vec![ExecTimeDist::multimodal("cold", 2, 20.0, 100.0, 1.0, None)],
            ),
        ],
    };
    spec.scale_rate_to_load(model, 0.9 * n_workers as f64, 8);
    let cfg = SchedulerConfig {
        cost_model: model,
        ..Default::default()
    };
    (spec, cfg)
}

fn bench_cluster(system: &str, n_workers: usize, router_name: &str, cases: &mut Vec<Json>) {
    let (spec, cfg) = single_model_spec(n_workers);
    run_bench(
        system, &spec, &cfg, n_workers, router_name, "all", system, cases,
    );
}

fn bench_multimodel(system: &str, n_workers: usize, placement: &str, cases: &mut Vec<Json>) {
    let (spec, cfg) = multi_model_spec(n_workers);
    run_bench(
        system,
        &spec,
        &cfg,
        n_workers,
        "least_loaded",
        placement,
        &format!("{system}/2models/{placement}"),
        cases,
    );
}

/// Placement-churn case: a drifting 2-model mix on capacity-1 workers,
/// with the elastic controller on or off — measures the dispatch-path
/// cost of live placement control (demand tracking, warming windows,
/// evict-drain re-routes) against the identical static run.
fn bench_churn(system: &str, n_workers: usize, elastic: bool, cases: &mut Vec<Json>) {
    let (spec, cfg) = multi_model_spec(n_workers);
    let mut spec = spec.drift_rotating(quick_or(3.0, 9.0), 0.85);
    // Re-scale *after* installing the drift schedule: the calibration
    // weights by the time-averaged (rotating ≈ even) mix, not the static
    // 0.7/0.3 shares, so the churn case runs at the same 0.9×N load as
    // the other bench cases.
    spec.scale_rate_to_load(cfg.cost_model, 0.9 * n_workers as f64, 8);
    let trace = spec.generate();
    let requests = trace.requests(3.0);
    let n_req = requests.len();
    let placement = Placement::parse("partition", n_workers, 2).unwrap();
    let mut cluster = Cluster::build_placed(system, &cfg, 1, placement).unwrap();
    for (model, app, hist) in spec.seed_histograms(cfg.bins) {
        cluster.seed_app_profile_everywhere(model, app, &hist, 1000);
    }
    let workers: Vec<SimWorker> = (0..n_workers)
        .map(|w| {
            SimWorker::new(cfg.cost_model, 0.0, 0x51 ^ (w as u64))
                .with_model_costs(spec.model_cost_models())
        })
        .collect();
    let mut core = ServingLoop::new(
        VirtualClock::new(),
        cluster,
        router::by_name("least_loaded").unwrap(),
    );
    if elastic {
        core = core.with_elastic(PlacementController::new(ElasticConfig {
            capacity: 1,
            ..Default::default()
        }));
    }
    let t0 = Instant::now();
    let res = replay::run_cluster(core, workers, requests);
    let wall = t0.elapsed().as_secs_f64();
    let events = res.completions.len() + res.batches;
    let mode = if elastic { "elastic" } else { "static" };
    let label = format!("{system}/drift/{mode}");
    println!(
        "  {label:>24} x{n_workers} ({:>19}): {n_req:>6} requests, {:>6} batches, \
         {:>9.0} events/s, {:>4} placement actions",
        "least_loaded",
        res.batches,
        events as f64 / wall,
        res.placement.actions(),
    );
    assert_eq!(res.completions.len(), n_req, "conservation in churn bench");
    cases.push(Json::obj(vec![
        ("label", Json::str(&label)),
        ("system", Json::str(system)),
        ("workers", Json::num(n_workers as f64)),
        ("router", Json::str("least_loaded")),
        ("placement", Json::str("partition")),
        ("models", Json::num(2.0)),
        ("elastic", Json::Bool(elastic)),
        ("requests", Json::num(n_req as f64)),
        ("batches", Json::num(res.batches as f64)),
        ("events", Json::num(events as f64)),
        ("wall_s", Json::num(wall)),
        ("events_per_s", Json::num(events as f64 / wall)),
        ("req_per_s", Json::num(n_req as f64 / wall)),
        ("load_actions", Json::num(res.placement.loads as f64)),
        ("unload_actions", Json::num(res.placement.unloads as f64)),
        ("rerouted", Json::num(res.placement.rerouted as f64)),
    ]));
}

/// Overload admission case (DESIGN.md §10): a 2-app trace at 2× one
/// worker's capacity, gated through the admission controller vs the
/// shed-at-formation baseline on the identical trace — the events/s
/// delta is the per-arrival admission decision cost on the hot path.
fn bench_admission(system: &str, n_workers: usize, gated: bool, cases: &mut Vec<Json>) {
    let model = BatchCostModel::calibrated(35.0);
    let mut spec = TraceSpec {
        name: "bench-adm".into(),
        dists: vec![
            ExecTimeDist::multimodal("fast", 1, 10.0, 10.0, 1.0, None),
            ExecTimeDist::multimodal("slow", 1, 60.0, 60.0, 1.0, None),
        ],
        arrivals: AzureTraceConfig {
            apps: 2,
            rate_per_s: 0.0,
            duration_s: trace_duration_s(),
            ..Default::default()
        },
        seed: 3,
        models: Vec::new(),
    };
    spec.scale_rate_to_load(model, 2.0 * n_workers as f64, 8);
    let cfg = SchedulerConfig {
        cost_model: model,
        ..Default::default()
    };
    let trace = spec.generate();
    let requests = trace.requests(2.0);
    let n_req = requests.len();
    let placement = Placement::parse("all", n_workers, 1).unwrap();
    let mut cluster = Cluster::build_placed(system, &cfg, 1, placement).unwrap();
    let mut ctl = gated.then(|| AdmissionController::new(AdmissionConfig::default()));
    for (model, app, hist) in spec.seed_histograms(cfg.bins) {
        cluster.seed_app_profile(model, app, &hist, 1000);
        if let Some(c) = ctl.as_mut() {
            c.seed_profile(model, app, &hist);
        }
    }
    let workers: Vec<SimWorker> = (0..n_workers)
        .map(|w| {
            SimWorker::new(cfg.cost_model, 0.0, 0x51 ^ (w as u64))
                .with_model_costs(spec.model_cost_models())
        })
        .collect();
    let mut core = ServingLoop::new(
        VirtualClock::new(),
        cluster,
        router::by_name("least_loaded").unwrap(),
    );
    if let Some(c) = ctl {
        core = core.with_admission(c);
    }
    let t0 = Instant::now();
    let res = replay::run_cluster(core, workers, requests);
    let wall = t0.elapsed().as_secs_f64();
    let events = res.completions.len() + res.batches;
    let mode = if gated { "gated" } else { "shed" };
    let label = format!("{system}/overload/{mode}");
    println!(
        "  {label:>24} x{n_workers} ({:>19}): {n_req:>6} requests, {:>6} batches, \
         {:>9.0} events/s, A/D/R {}/{}/{}",
        "least_loaded",
        res.batches,
        events as f64 / wall,
        res.admission.admitted,
        res.admission.downgraded,
        res.admission.early_rejected,
    );
    assert_eq!(res.completions.len(), n_req, "conservation in admission bench");
    cases.push(Json::obj(vec![
        ("label", Json::str(&label)),
        ("system", Json::str(system)),
        ("workers", Json::num(n_workers as f64)),
        ("router", Json::str("least_loaded")),
        ("placement", Json::str("all")),
        ("models", Json::num(1.0)),
        ("admission", Json::Bool(gated)),
        ("requests", Json::num(n_req as f64)),
        ("batches", Json::num(res.batches as f64)),
        ("events", Json::num(events as f64)),
        ("wall_s", Json::num(wall)),
        ("events_per_s", Json::num(events as f64 / wall)),
        ("req_per_s", Json::num(n_req as f64 / wall)),
        ("us_per_event", Json::num(wall * 1e6 / events.max(1) as f64)),
        ("admitted", Json::num(res.admission.admitted as f64)),
        ("downgraded", Json::num(res.admission.downgraded as f64)),
        (
            "early_rejected",
            Json::num(res.admission.early_rejected as f64),
        ),
        (
            "best_effort_served",
            Json::num(res.admission.best_effort_served as f64),
        ),
    ]));
}

fn main() {
    let mut cases: Vec<Json> = Vec::new();
    println!("### unified serving-loop dispatch benchmarks");
    println!("\nvirtual-time replay throughput (dispatch + routing hot path):");
    for system in ["edf", "orloj"] {
        for &n in &[1usize, 4] {
            bench_cluster(system, n, "round_robin", &mut cases);
        }
    }
    println!("\nrouter comparison (orloj, 4 workers):");
    for router_name in router::ROUTERS {
        bench_cluster("orloj", 4, router_name, &mut cases);
    }
    println!("\nmulti-model placement (2 models × 4 workers):");
    for system in ["edf", "orloj"] {
        for placement in ["all", "skewed"] {
            bench_multimodel(system, 4, placement, &mut cases);
        }
    }
    println!("\nplacement churn (drifting mix × 4 capacity-1 workers, elastic on/off):");
    for system in ["edf", "orloj"] {
        for elastic in [false, true] {
            bench_churn(system, 4, elastic, &mut cases);
        }
    }
    println!("\noverload admission (2 apps at 2x load, gated vs shed-at-formation):");
    for system in ["edf", "orloj"] {
        for gated in [false, true] {
            bench_admission(system, 1, gated, &mut cases);
        }
    }
    match json_report("BENCH_serve.json", "serve_loop", cases) {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write BENCH_serve.json: {e}"),
    }
    println!("serve_loop bench OK");
}
