//! Scheduler hot-path benchmarks (§5.7 overheads + §Perf):
//!
//! * Orloj `on_arrival` cost vs pending-queue depth (template
//!   instantiation + 5-queue hull insert);
//! * `next_batch` iteration cost (milestones + feasibility pruning +
//!   candidate selection + PopBatch);
//! * estimator precompute cost (the §4.3 off-critical-path work, now
//!   including the score-template build);
//! * whole-simulation throughput in virtual requests/second.
//!
//! Emits `BENCH_sched.json` with per-iteration p50/p99 (see DESIGN.md §7).
//! `ORLOJ_BENCH_QUICK=1` runs shrunk iteration counts (the CI smoke).
//!
//! Run: `cargo bench --bench scheduler`

use orloj::clock::ms_to_us;
use orloj::core::batchmodel::BatchCostModel;
use orloj::core::histogram::Histogram;
use orloj::core::request::{AppId, ModelId, Request};
use orloj::scheduler::estimator::Estimator;
use orloj::scheduler::orloj::OrlojScheduler;
use orloj::scheduler::profiler::OnlineProfiler;
use orloj::scheduler::{Scheduler, SchedulerConfig};
use orloj::util::benchmark::{json_report, quick_or, summary_json, time_batched, time_per_iter};
use orloj::util::json::Json;
use orloj::util::rng::Rng;
use std::time::Instant;

fn cfg() -> SchedulerConfig {
    SchedulerConfig {
        cost_model: BatchCostModel::calibrated(30.0),
        ..Default::default()
    }
}

fn seeded(n_apps: u32) -> OrlojScheduler {
    let mut s = OrlojScheduler::new(cfg(), 42);
    let mut rng = Rng::new(5);
    for a in 0..n_apps {
        let samples: Vec<f64> = (0..4000)
            .map(|_| rng.lognormal(3.0 + a as f64 * 0.4, 0.6))
            .collect();
        let h = Histogram::from_samples(&samples, 64);
        s.seed_profile(ModelId::DEFAULT, AppId(a), &h, 1000);
    }
    s
}

fn fill(s: &mut OrlojScheduler, n: usize, rng: &mut Rng) -> u64 {
    let mut id = 1_000_000;
    for _ in 0..n {
        let app = AppId(rng.index(3) as u32);
        let slo = ms_to_us(500.0 + rng.f64() * 4_000.0);
        s.on_arrival(Request::new(id, app, 0, slo, 30.0), 0);
        id += 1;
    }
    id
}

fn depths() -> Vec<usize> {
    quick_or(vec![100, 1_000], vec![100, 1_000, 5_000, 10_000])
}

/// One JSON case row: the op + pending depth + the per-iter percentiles.
fn case_with_summary(op: &str, pending: usize, s: &orloj::util::stats::Summary) -> Json {
    let mut m = match summary_json(s) {
        Json::Obj(m) => m,
        _ => unreachable!("summary_json returns an object"),
    };
    m.insert("op".to_string(), Json::str(op));
    m.insert("pending".to_string(), Json::num(pending as f64));
    Json::Obj(m)
}

fn main() {
    let mut cases: Vec<Json> = Vec::new();
    println!("### scheduler hot-path benchmarks");

    // --- on_arrival vs pending depth ---
    println!("\non_arrival (template instantiation + hull insert into |S|=5 queues):");
    for &n in &depths() {
        let mut s = seeded(3);
        let mut rng = Rng::new(9);
        let id = fill(&mut s, n, &mut rng);
        let iters = quick_or(100, 500);
        let summary = time_per_iter(quick_or(10, 50), iters, |i| {
            let app = AppId((i % 3) as u32);
            s.on_arrival(
                Request::new(id + i as u64, app, 0, ms_to_us(2_000.0), 30.0),
                0,
            );
        });
        println!(
            "  pending={n:>6}: {:.1} µs/arrival (p50 {:.1}, p99 {:.1})",
            summary.mean / 1000.0,
            summary.p50 / 1000.0,
            summary.p99 / 1000.0
        );
        cases.push(case_with_summary("on_arrival", n, &summary));
    }

    // --- next_batch iteration ---
    println!("\nnext_batch (one Algorithm-1 iteration incl. PopBatch):");
    for &n in &depths() {
        let mut s = seeded(3);
        let mut rng = Rng::new(11);
        fill(&mut s, n, &mut rng);
        let mut t = 1_000u64;
        let iters = quick_or(50, 200);
        let summary = time_per_iter(quick_or(2, 5), iters, |_| {
            t += 500;
            s.next_batch(t)
        });
        println!(
            "  pending={n:>6}: {:.1} µs/iteration (p50 {:.1}, p99 {:.1})",
            summary.mean / 1000.0,
            summary.p50 / 1000.0,
            summary.p99 / 1000.0
        );
        cases.push(case_with_summary("next_batch", n, &summary));
    }

    // --- estimator precompute ---
    println!("\nestimator precompute (per (app, bs) batch-latency distribution + template):");
    let mut profiler = OnlineProfiler::new(4096, 1.0, 64, 3);
    let mut rng = Rng::new(13);
    for a in 0..4u32 {
        for _ in 0..2000 {
            profiler.record(
                ModelId::DEFAULT,
                AppId(a),
                rng.lognormal(3.0 + a as f64 * 0.3, 0.7),
            );
        }
    }
    let snap = profiler.snapshot();
    for &bs in &[1usize, 4, 16] {
        let ns = time_batched(quick_or(1, 3), quick_or(10, 50), |i| {
            let mut e = Estimator::new(BatchCostModel::calibrated(30.0), 64, 0.5);
            e.refresh(snap.clone());
            e.batch_latency(ModelId::DEFAULT, AppId((i % 4) as u32), bs).mean
        });
        println!(
            "  bs={bs:>3}: {:.1} µs (cold compute incl. refresh)",
            ns / 1000.0
        );
        cases.push(Json::obj(vec![
            ("op", Json::str("estimator_precompute")),
            ("bs", Json::num(bs as f64)),
            ("ns_mean", Json::num(ns)),
        ]));
    }

    // --- whole-sim throughput ---
    println!("\nend-to-end simulation throughput:");
    {
        use orloj::sim::{engine, worker::SimWorker};
        use orloj::workload::azure::AzureTraceConfig;
        use orloj::workload::exectime::ExecTimeDist;
        use orloj::workload::trace::TraceSpec;
        let mut spec = TraceSpec {
            name: "bench".into(),
            dists: vec![ExecTimeDist::multimodal("m3", 3, 10.0, 100.0, 1.0, None)],
            arrivals: AzureTraceConfig {
                apps: 1,
                rate_per_s: 0.0,
                duration_s: quick_or(8.0, 60.0),
                ..Default::default()
            },
            seed: 1,
            models: Vec::new(),
        };
        let model = BatchCostModel::calibrated(35.0);
        spec.scale_rate_to_load(model, 0.9, 8);
        let trace = spec.generate();
        for system in ["clockwork", "orloj"] {
            let mut sched = orloj::baselines::by_name(
                system,
                SchedulerConfig {
                    cost_model: model,
                    ..Default::default()
                },
                1,
            )
            .unwrap();
            for (model, app, hist) in spec.seed_histograms(64) {
                sched.seed_app_profile(model, app, &hist, 1000);
            }
            let mut worker = SimWorker::new(model, 0.0, 2);
            let reqs = trace.requests(3.0);
            let n = reqs.len();
            let t0 = Instant::now();
            let res = engine::run(sched.as_mut(), &mut worker, reqs);
            let wall = t0.elapsed().as_secs_f64();
            println!(
                "  {system:>10}: {n} virtual requests in {:.3}s wall = {:.0} req/s ({} batches)",
                wall,
                n as f64 / wall,
                res.batches
            );
            cases.push(Json::obj(vec![
                ("op", Json::str("end_to_end_sim")),
                ("system", Json::str(system)),
                ("requests", Json::num(n as f64)),
                ("batches", Json::num(res.batches as f64)),
                ("wall_s", Json::num(wall)),
                ("req_per_s", Json::num(n as f64 / wall)),
            ]));
        }
    }
    match json_report("BENCH_sched.json", "scheduler", cases) {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write BENCH_sched.json: {e}"),
    }
    println!("scheduler bench OK");
}
