//! Fig. 12 — efficiency of the priority queue.
//!
//! Measures per-request insertion time and query time of the dynamic
//! convex hull for queue sizes 10..10000 (the paper's x-axis), next to the
//! naive O(n) scan queue. Expectation (paper §5.5): insertion grows ~
//! O(log² n) and stays well under 0.5 ms at n = 10⁴; query time is ~flat.
//!
//! Run: `cargo bench --bench priority_queue`

use orloj::ds::hull::point::Point;
use orloj::ds::hull::DynamicHull;
use orloj::ds::naive::NaiveMaxQueue;
use orloj::util::benchmark::time_batched;
use orloj::util::rng::Rng;

fn random_points(n: usize, seed: u64) -> Vec<Point> {
    let mut rng = Rng::new(seed);
    (0..n as u64)
        .map(|i| Point::new(rng.f64() * 1000.0, rng.f64() * 1000.0, i))
        .collect()
}

fn main() {
    println!("### Fig. 12 — priority queue insertion / query time");
    println!(
        "{:>8} {:>16} {:>16} {:>16} {:>16}  {:>10}",
        "n", "hull_insert(ns)", "hull_query(ns)", "naive_query(ns)", "hull_delete(ns)", "log2^2(n)"
    );
    let sizes = [10usize, 30, 100, 300, 1_000, 3_000, 10_000];
    let mut log2sq_base = 0.0;
    let mut insert_base = 0.0;
    for (si, &n) in sizes.iter().enumerate() {
        let pts = random_points(n + 2_000, 1234);

        // Insertion: amortized over filling from n to n+1000.
        let mut hull = DynamicHull::new();
        for p in &pts[..n] {
            hull.insert(*p);
        }
        let ins = time_batched(100, 1_000, |i| {
            hull.insert(pts[n + (i % 1_000)]);
            if i >= 1_000 {
                // keep size bounded: delete an earlier extra
                hull.delete(&pts[n + (i - 1_000) % 1_000]);
            }
        });

        // Query with random slopes (paper: "a line of random slope").
        let mut rng = Rng::new(77);
        let slopes: Vec<f64> = (0..1024).map(|_| rng.f64() * 100.0).collect();
        let q = time_batched(100, 5_000, |i| hull.query_max(slopes[i % 1024]));

        // Naive scan baseline.
        let mut naive = NaiveMaxQueue::new();
        for p in &pts[..n] {
            naive.insert(*p);
        }
        let nq = time_batched(10, 1_000, |i| naive.query_max(slopes[i % 1024]));

        // Deletion.
        let mut hull2 = DynamicHull::new();
        for p in &pts[..n + 1_000] {
            hull2.insert(*p);
        }
        let del = time_batched(0, 1_000, |i| hull2.delete(&pts[n + (i % 1_000)]));

        let log2 = (n as f64).log2();
        let log2sq = log2 * log2;
        if si == 0 {
            log2sq_base = log2sq;
            insert_base = ins;
        }
        println!(
            "{n:>8} {ins:>16.0} {q:>16.0} {nq:>16.0} {del:>16.0}  {:>10.1}",
            log2sq
        );
    }
    // Scaling check: insertion at 10k vs 10 should grow no faster than
    // ~3× the log²n ratio (constant factors + cache effects allowed).
    let ratio_bound = {
        let l_small = (10f64).log2().powi(2);
        let l_big = (10_000f64).log2().powi(2);
        3.0 * l_big / l_small
    };
    println!("\n(log²n growth 10→10000 is {:.1}×; paper's fit line)", ratio_bound / 3.0);
    let _ = (log2sq_base, insert_base);
}
