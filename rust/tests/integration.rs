//! Cross-module integration tests: workload → scheduler → engine → metrics.

use orloj::baselines::{self, PAPER_SYSTEMS};
use orloj::clock::ms_to_us;
use orloj::core::batchmodel::BatchCostModel;
use orloj::core::request::{AppId, ModelId, Outcome, Request};
use orloj::scheduler::orloj::OrlojScheduler;
use orloj::scheduler::{Scheduler, SchedulerConfig};
use orloj::server::metrics::RunReport;
use orloj::sim::{engine, worker::SimWorker};
use orloj::workload::azure::AzureTraceConfig;
use orloj::workload::exectime::ExecTimeDist;
use orloj::workload::trace::TraceSpec;

fn spec(seed: u64, duration_s: f64) -> (TraceSpec, SchedulerConfig) {
    let model = BatchCostModel::calibrated(35.0);
    let mut spec = TraceSpec {
        name: "itest".into(),
        dists: vec![
            ExecTimeDist::multimodal("short", 1, 12.0, 12.0, 1.0, None),
            ExecTimeDist::multimodal("long", 1, 90.0, 90.0, 1.0, None),
        ],
        arrivals: AzureTraceConfig {
            apps: 2,
            rate_per_s: 0.0,
            duration_s,
            ..Default::default()
        },
        seed,
        models: Vec::new(),
    };
    spec.scale_rate_to_load(model, 0.85, 8);
    let cfg = SchedulerConfig {
        cost_model: model,
        ..Default::default()
    };
    (spec, cfg)
}

/// Every request in the trace is accounted for exactly once in completions.
#[test]
fn conservation_across_all_systems() {
    let (s, cfg) = spec(3, 15.0);
    let trace = s.generate();
    for system in PAPER_SYSTEMS.iter().chain(["edf"].iter()) {
        let mut sched = baselines::by_name(system, cfg.clone(), 1).unwrap();
        for (model, app, hist) in s.seed_histograms(cfg.bins) {
            sched.seed_app_profile(model, app, &hist, 100);
        }
        let mut worker = SimWorker::new(cfg.cost_model, 0.0, 4);
        let reqs = trace.requests(3.0);
        let n = reqs.len();
        let ids: std::collections::BTreeSet<u64> = reqs.iter().map(|r| r.id.0).collect();
        let res = engine::run(sched.as_mut(), &mut worker, reqs);
        assert_eq!(res.completions.len(), n, "{system}: lost/duplicated requests");
        let seen: std::collections::BTreeSet<u64> =
            res.completions.iter().map(|c| c.request.id.0).collect();
        assert_eq!(seen, ids, "{system}: id mismatch");
    }
}

/// Finished requests really finished by their deadline; Late really didn't.
#[test]
fn outcome_labels_are_truthful() {
    let (s, cfg) = spec(5, 12.0);
    let trace = s.generate();
    let mut sched = baselines::by_name("orloj", cfg.clone(), 1).unwrap();
    for (model, app, hist) in s.seed_histograms(cfg.bins) {
        sched.seed_app_profile(model, app, &hist, 100);
    }
    let mut worker = SimWorker::new(cfg.cost_model, 0.0, 4);
    let res = engine::run(sched.as_mut(), &mut worker, trace.requests(2.0));
    for c in &res.completions {
        match c.outcome {
            Outcome::Finished => assert!(c.at <= c.request.deadline),
            Outcome::Late => assert!(c.at > c.request.deadline),
            _ => {}
        }
    }
}

/// Identical seeds → identical results (record/replay determinism across
/// the whole stack).
#[test]
fn full_stack_determinism() {
    let run = || {
        let (s, cfg) = spec(7, 10.0);
        let trace = s.generate();
        let mut sched = baselines::by_name("orloj", cfg.clone(), 9).unwrap();
        for (model, app, hist) in s.seed_histograms(cfg.bins) {
            sched.seed_app_profile(model, app, &hist, 100);
        }
        let mut worker = SimWorker::new(cfg.cost_model, 0.0, 4);
        let res = engine::run(sched.as_mut(), &mut worker, trace.requests(3.0));
        RunReport::from_completions(&res.completions).finish_rate()
    };
    assert_eq!(run(), run());
}

/// The paper's headline direction on this two-app mix at a moderate SLO.
#[test]
fn orloj_wins_on_dynamic_two_app_mix() {
    let (s, cfg) = spec(11, 25.0);
    let trace = s.generate();
    let mut rates = std::collections::BTreeMap::new();
    for system in PAPER_SYSTEMS {
        let mut sched = baselines::by_name(system, cfg.clone(), 2).unwrap();
        for (model, app, hist) in s.seed_histograms(cfg.bins) {
            sched.seed_app_profile(model, app, &hist, 100);
        }
        let mut worker = SimWorker::new(cfg.cost_model, 0.0, 4);
        let res = engine::run(sched.as_mut(), &mut worker, trace.requests(3.0));
        rates.insert(
            system,
            RunReport::from_completions(&res.completions).finish_rate(),
        );
    }
    let orloj = rates["orloj"];
    for (sys, r) in &rates {
        if *sys != "orloj" {
            assert!(
                orloj >= *r,
                "orloj ({orloj:.3}) should be >= {sys} ({r:.3}); all: {rates:?}"
            );
        }
    }
    assert!(orloj > 0.8, "orloj should serve most requests: {orloj}");
}

/// Static workload (constant exec): everyone close; Orloj comparable
/// (paper Fig. 11 claim).
#[test]
fn static_workload_parity() {
    let model = BatchCostModel::calibrated(8.0);
    let mut s = TraceSpec {
        name: "static".into(),
        dists: vec![ExecTimeDist::constant("resnet", 8.0)],
        arrivals: AzureTraceConfig {
            apps: 1,
            rate_per_s: 0.0,
            duration_s: 20.0,
            ..Default::default()
        },
        seed: 13,
        models: Vec::new(),
    };
    s.scale_rate_to_load(model, 0.8, 8);
    let cfg = SchedulerConfig {
        cost_model: model,
        ..Default::default()
    };
    let trace = s.generate();
    let mut orloj_rate = 0.0;
    let mut clockwork_rate = 0.0;
    for system in ["orloj", "clockwork"] {
        let mut sched = baselines::by_name(system, cfg.clone(), 3).unwrap();
        for (model, app, hist) in s.seed_histograms(cfg.bins) {
            sched.seed_app_profile(model, app, &hist, 100);
        }
        let mut worker = SimWorker::new(cfg.cost_model, 0.0, 4);
        let res = engine::run(sched.as_mut(), &mut worker, trace.requests(4.0));
        let rate = RunReport::from_completions(&res.completions).finish_rate();
        if system == "orloj" {
            orloj_rate = rate;
        } else {
            clockwork_rate = rate;
        }
    }
    // Paper Table 4: orloj 0.84–0.99 on static at mid/relaxed SLOs.
    assert!(orloj_rate > 0.8, "orloj on static: {orloj_rate}");
    assert!(
        (orloj_rate - clockwork_rate).abs() < 0.25,
        "parity: orloj={orloj_rate} clockwork={clockwork_rate}"
    );
}

/// Scheduler survives a long virtual run crossing several base-time resets.
#[test]
fn long_run_with_base_resets() {
    let cfg = SchedulerConfig {
        cost_model: BatchCostModel::calibrated(20.0),
        ..Default::default()
    };
    let mut sched = OrlojScheduler::new(cfg, 1);
    sched.seed_profile(
        ModelId::DEFAULT,
        AppId(0),
        &orloj::core::histogram::Histogram::constant(20.0),
        100,
    );
    // Requests spread over 30 virtual minutes (b=1e-4/ms resets ~every 400 s).
    let reqs: Vec<Request> = (0..2_000u64)
        .map(|i| {
            Request::new(
                i,
                AppId(0),
                i * 900_000, // 0.9 s apart → 30 min span
                ms_to_us(500.0),
                20.0,
            )
        })
        .collect();
    let mut worker = SimWorker::new(cfg_model(), 0.0, 4);
    let res = engine::run(&mut sched, &mut worker, reqs);
    let report = RunReport::from_completions(&res.completions);
    assert_eq!(report.total, 2_000);
    assert!(
        report.finish_rate() > 0.95,
        "light load across resets should all finish: {}",
        report.finish_rate()
    );
}

fn cfg_model() -> BatchCostModel {
    BatchCostModel::calibrated(20.0)
}

/// Trace JSON record/replay preserves results bit-exactly.
#[test]
fn trace_replay_equivalence() {
    let (s, cfg) = spec(17, 8.0);
    let trace = s.generate();
    let path = std::env::temp_dir().join("orloj_itest_trace.json");
    trace.save(&path).unwrap();
    let replayed = orloj::workload::trace::Trace::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let run = |t: &orloj::workload::trace::Trace| {
        let mut sched = baselines::by_name("orloj", cfg.clone(), 4).unwrap();
        for (model, app, hist) in s.seed_histograms(cfg.bins) {
            sched.seed_app_profile(model, app, &hist, 100);
        }
        let mut worker = SimWorker::new(cfg.cost_model, 0.0, 4);
        let res = engine::run(sched.as_mut(), &mut worker, t.requests(3.0));
        RunReport::from_completions(&res.completions).finish_rate()
    };
    assert_eq!(run(&trace), run(&replayed));
}
