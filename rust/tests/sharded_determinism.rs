//! Determinism property tests for the sharded virtual-time pump
//! (DESIGN.md §11): sharded replays must produce byte-identical
//! completion sequences and run reports to the sequential pump, across
//! every system, and the idle-advance path must jump to the next event
//! instead of crawling in 1 ms hops.

use orloj::clock::{ms_to_us, Micros, VirtualClock};
use orloj::core::batchmodel::BatchCostModel;
use orloj::core::request::{AppId, Outcome, Request};
use orloj::scheduler::{Scheduler, SchedulerConfig};
use orloj::serve::{replay, router, Cluster, ElasticConfig, ServingLoop};
use orloj::sim::engine;
use orloj::sim::runner::{run_one, Cell, ClusterSpec};
use orloj::sim::worker::{SimWorker, Worker};
use orloj::workload::azure::AzureTraceConfig;
use orloj::workload::exectime::ExecTimeDist;
use orloj::workload::trace::{ModelTraffic, TraceSpec};

/// All five systems: the four paper baselines plus the EDF control.
const SYSTEMS: [&str; 5] = ["clipper", "nexus", "clockwork", "edf", "orloj"];

fn spec(seed: u64, duration_s: f64) -> TraceSpec {
    let mut spec = TraceSpec {
        name: "shard-unit".into(),
        dists: Vec::new(),
        arrivals: AzureTraceConfig {
            apps: 1,
            rate_per_s: 0.0, // set by scaling below
            duration_s,
            ..Default::default()
        },
        seed,
        models: vec![
            ModelTraffic::new(0, 0.6, vec![ExecTimeDist::constant("fast", 8.0)]),
            ModelTraffic::new(
                1,
                0.4,
                vec![ExecTimeDist::multimodal("slow", 2, 12.0, 60.0, 1.0, None)],
            ),
        ],
    };
    spec.scale_rate_to_load(BatchCostModel::gpu_like(), 0.6, 8);
    spec
}

fn cfg() -> SchedulerConfig {
    SchedulerConfig {
        cost_model: BatchCostModel::gpu_like(),
        ..Default::default()
    }
}

/// Everything a run observably produced: the full report (latency
/// percentiles, per-model and per-worker stats) — completions are
/// compared inside `run_one`'s cross-check, byte for byte.
fn fingerprint(cell: &Cell) -> String {
    format!(
        "report={:?} util={:.9} placement={:?} admission={:?}",
        cell.report, cell.utilization, cell.placement, cell.admission
    )
}

/// Satellite 4 (core property): every system × {4, 8} workers × shards
/// ∈ {2, 4} on the virtual clock reproduces the sequential pump exactly.
/// `with_cross_check` makes `run_one` itself assert byte-identical
/// completion sequences; on top we pin the derived reports.
#[test]
fn sharded_replay_matches_sequential_for_all_systems() {
    let spec = spec(41, 10.0);
    let trace = spec.generate();
    for system in SYSTEMS {
        for workers in [4usize, 8] {
            let base = run_one(
                system,
                &spec,
                &trace,
                3.0,
                &cfg(),
                7,
                &ClusterSpec::new(workers, "round_robin"),
            );
            for shards in [2usize, 4] {
                let sharded = run_one(
                    system,
                    &spec,
                    &trace,
                    3.0,
                    &cfg(),
                    7,
                    &ClusterSpec::new(workers, "round_robin")
                        .with_shards(shards)
                        .with_cross_check(),
                );
                assert_eq!(
                    fingerprint(&base),
                    fingerprint(&sharded),
                    "{system} x{workers}w: shards={shards} diverged from sequential"
                );
            }
        }
    }
}

/// Coupled configurations (load-aware router + elastic placement) are
/// not parallel-safe: sharding must conservatively fall back to the
/// sequential pump and still produce identical results.
#[test]
fn elastic_runs_are_shard_invariant() {
    let spec = spec(42, 8.0).drift_rotating(4.0, 0.9);
    let trace = spec.generate();
    let ecfg = ElasticConfig {
        capacity: 1,
        interval_us: 250_000,
        alpha: 0.5,
        min_dwell_us: 1_000_000,
        ..Default::default()
    };
    for system in ["edf", "orloj"] {
        let base = run_one(
            system,
            &spec,
            &trace,
            3.0,
            &cfg(),
            11,
            &ClusterSpec::new(4, "least_loaded")
                .with_placement("partition")
                .with_elastic(ecfg.clone()),
        );
        let sharded = run_one(
            system,
            &spec,
            &trace,
            3.0,
            &cfg(),
            11,
            &ClusterSpec::new(4, "least_loaded")
                .with_placement("partition")
                .with_elastic(ecfg.clone())
                .with_shards(4)
                .with_cross_check(),
        );
        assert_eq!(
            fingerprint(&base),
            fingerprint(&sharded),
            "{system}: elastic run must be shard-invariant"
        );
    }
}

/// Admission control reads cluster-wide backlog on every arrival — also
/// a coupled configuration. Sharded runs must match, fallback or not.
#[test]
fn admission_runs_are_shard_invariant() {
    let spec = spec(43, 8.0);
    let trace = spec.generate();
    for system in ["clipper", "orloj"] {
        let base = run_one(
            system,
            &spec,
            &trace,
            2.0,
            &cfg(),
            13,
            &ClusterSpec::new(4, "round_robin").with_admission(0.5),
        );
        let sharded = run_one(
            system,
            &spec,
            &trace,
            2.0,
            &cfg(),
            13,
            &ClusterSpec::new(4, "round_robin")
                .with_admission(0.5)
                .with_shards(2)
                .with_cross_check(),
        );
        assert_eq!(
            fingerprint(&base),
            fingerprint(&sharded),
            "{system}: admission run must be shard-invariant"
        );
    }
}

// ---------------------------------------------------------------------
// Satellite 3: the idle-advance fallback must jump to the scheduler's
// earliest deadline, not crawl in 1 ms hops.
// ---------------------------------------------------------------------

/// A policy that holds every request until its deadline, publishes no
/// wake hint, but reports its earliest queued deadline. Before the
/// earliest-deadline fallback the pump crawled through such idle spans
/// at 1 ms per step; now it jumps straight to the deadline.
struct HoldUntilDeadline {
    queue: Vec<Request>,
}

impl Scheduler for HoldUntilDeadline {
    fn name(&self) -> &'static str {
        "hold_until_deadline"
    }
    fn on_arrival(&mut self, req: Request, _now: Micros) {
        self.queue.push(req);
    }
    fn next_batch(&mut self, now: Micros) -> Option<Vec<Request>> {
        let due = self
            .queue
            .iter()
            .enumerate()
            .filter(|(_, r)| r.deadline <= now)
            .min_by_key(|(_, r)| r.deadline)
            .map(|(i, _)| i)?;
        Some(vec![self.queue.swap_remove(due)])
    }
    fn on_batch_complete(&mut self, _batch: &[Request], _batch_ms: f64, _now: Micros) {}
    fn drain_dropped(&mut self) -> Vec<(Request, Outcome)> {
        Vec::new()
    }
    /// Deliberately silent: the pump must fall back to
    /// [`Scheduler::earliest_deadline`].
    fn wake_hint(&self, _now: Micros) -> Option<Micros> {
        None
    }
    fn earliest_deadline(&self) -> Option<Micros> {
        self.queue.iter().map(|r| r.deadline).min()
    }
    fn pending(&self) -> usize {
        self.queue.len()
    }
    fn pending_for(&self, model: orloj::core::request::ModelId) -> usize {
        self.queue.iter().filter(|r| r.model == model).count()
    }
}

/// A sparse trace: 20 requests a full second apart, each held until its
/// deadline 500 ms after release. With 1 ms crawling the pump would need
/// ~500 advances per idle span (> 10,000 total); jumping to the earliest
/// deadline needs a small constant number per request.
fn sparse_requests() -> Vec<Request> {
    (0..20u64)
        .map(|i| {
            Request::new(
                i,
                AppId(0),
                ms_to_us(i as f64 * 1_000.0),
                ms_to_us(500.0),
                10.0,
            )
        })
        .collect()
}

#[test]
fn sparse_trace_completes_in_few_steps_prerouted_pump() {
    let mut sched = HoldUntilDeadline { queue: Vec::new() };
    let mut worker = SimWorker::new(BatchCostModel::new(0.0, 1.0), 0.0, 0);
    // round_robin is load-oblivious → this drives the per-slot pump.
    let res = engine::run(&mut sched, &mut worker, sparse_requests());
    assert_eq!(res.completions.len(), 20);
    assert!(
        res.steps < 200,
        "prerouted pump crawled: {} clock advances for 20 sparse events",
        res.steps
    );
}

#[test]
fn sparse_trace_completes_in_few_steps_sequential_pump() {
    let mut sched = HoldUntilDeadline { queue: Vec::new() };
    let mut worker = SimWorker::new(BatchCostModel::new(0.0, 1.0), 0.0, 0);
    // least_loaded is load-aware → this drives the sequential pump.
    let core = ServingLoop::new(
        VirtualClock::new(),
        Cluster::new(vec![&mut sched as &mut dyn Scheduler]),
        router::by_name("least_loaded").expect("registry has least_loaded"),
    );
    let res = replay::run_cluster(
        core,
        vec![&mut worker as &mut dyn Worker],
        sparse_requests(),
    );
    assert_eq!(res.completions.len(), 20);
    assert!(
        res.steps < 200,
        "sequential pump crawled: {} clock advances for 20 sparse events",
        res.steps
    );
}
