//! Network ingress integration tests (DESIGN.md §12).
//!
//! Conservation over a real loopback socket — every framed request gets
//! exactly one reply or is a counted wire drop — plus frame-parser abuse
//! (malformed input closes the connection, never panics the shard) and
//! multi-producer stress on the lock-free arrival ring.

use orloj::baselines;
use orloj::clock::RealClock;
use orloj::core::batchmodel::BatchCostModel;
use orloj::core::histogram::Histogram;
use orloj::core::request::{AppId, ModelId};
use orloj::scheduler::{Scheduler, SchedulerConfig};
use orloj::serve::ingress::{
    decode_reply, encode_frame, Ingress, IngressConfig, IngressController, IngressCounts,
    ReqFrame, REPLY_LEN, WIRE_DROP,
};
use orloj::serve::realtime::ServeResult;
use orloj::serve::ring::ArrivalRing;
use orloj::serve::router;
use orloj::server::Server;
use orloj::sim::worker::SimWorker;
use orloj::workload::loadgen::{self, LoadgenConfig};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

type ServerHandle = (
    std::net::SocketAddr,
    IngressController,
    std::thread::JoinHandle<(ServeResult, IngressCounts)>,
);

/// A two-replica sim-worker server behind the TCP ingress on an
/// ephemeral loopback port, pumping on its own thread.
fn start_server(system: &str, shards: usize, ring_capacity: usize) -> ServerHandle {
    let workers = 2;
    let cfg = SchedulerConfig {
        cost_model: BatchCostModel::calibrated(2.0),
        ..Default::default()
    };
    let hist = Histogram::from_weights(1.5, 1.0, &[1.0]);
    let replicas: Vec<(Box<dyn Scheduler>, SimWorker)> = (0..workers)
        .map(|w| {
            let mut sched =
                baselines::by_name(system, cfg.clone(), w as u64).expect("known system");
            for app in 0..4u32 {
                sched.seed_app_profile(ModelId(0), AppId(app), &hist, 100);
            }
            (sched, SimWorker::new(cfg.cost_model, 0.0, w as u64))
        })
        .collect();
    let server = Server::cluster(replicas, router::by_name("round_robin").unwrap());
    let icfg = IngressConfig {
        shards,
        ring_capacity,
        ..Default::default()
    };
    let bound = server.listen("127.0.0.1:0", icfg).expect("bind loopback");
    let addr = bound.local_addr();
    let ctl = bound.controller();
    let handle = std::thread::spawn(move || bound.run());
    (addr, ctl, handle)
}

#[test]
fn loopback_conservation_across_systems_and_shards() {
    for system in ["orloj", "edf"] {
        for shards in [1usize, 4] {
            let (addr, ctl, handle) = start_server(system, shards, 1 << 12);
            let rep = loadgen::run(&LoadgenConfig {
                addr: addr.to_string(),
                conns: 8,
                rate_per_s: 2_000.0,
                duration_s: 0.4,
                apps: 2,
                models: 1,
                slo_multiple: 50.0,
                exec_ms: 2.0,
                payload: 16,
                seed: 7,
                workers: 2,
                drain_timeout_s: 10.0,
            })
            .expect("loadgen runs");
            ctl.begin_drain();
            let (res, counts) = handle.join().expect("server pump panicked");
            assert!(rep.sent > 0, "{system}/{shards}: loadgen sent nothing");
            assert_eq!(
                rep.conservation_violations, 0,
                "{system}/{shards}: every request must be answered ({rep:?})"
            );
            assert_eq!(
                counts.frames,
                res.completions.len() as u64 + counts.wire_drops,
                "{system}/{shards}: frames either complete or drop ({counts:?})"
            );
            assert!(rep.finished > 0, "{system}/{shards}: nothing finished ({rep:?})");
            assert_eq!(counts.proto_errors, 0, "{system}/{shards}: clean protocol");
        }
    }
}

/// Read until the peer closes (`Ok(0)`) or resets; any payload before
/// that would be a reply the server must not have sent.
fn assert_closed_without_reply(mut s: TcpStream, what: &str) {
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut buf = [0u8; 64];
    match s.read(&mut buf) {
        Ok(0) => {}
        Ok(n) => panic!("{what}: expected close, got {n} reply bytes"),
        Err(_) => {} // reset is as good as FIN here
    }
}

#[test]
fn malformed_frames_close_the_connection_without_panic() {
    let (addr, ctl, handle) = start_server("edf", 2, 1 << 12);

    // Bad magic: 28 bytes of garbage.
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(&[0xAA; 28]).unwrap();
    assert_closed_without_reply(s, "bad magic");

    // Zero SLO is a protocol error.
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(&encode_frame(&ReqFrame {
        seq: 0,
        app: 0,
        model: 0,
        slo_us: 0,
        exec_us: 1_000,
        payload_len: 0,
    }))
    .unwrap();
    assert_closed_without_reply(s, "zero slo");

    // Oversized payload claim.
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(&encode_frame(&ReqFrame {
        seq: 0,
        app: 0,
        model: 0,
        slo_us: 1_000_000,
        exec_us: 1_000,
        payload_len: u32::MAX,
    }))
    .unwrap();
    assert_closed_without_reply(s, "oversized payload");

    // A truncated header followed by a hangup must just reap the
    // connection (nothing to assert on the wire — the server must not
    // die, which the valid exchange below proves).
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(&[0x51, 0x4C, 0x52, 0x4F, 0x01]).unwrap();
    drop(s);

    // The shard that ate all that abuse still serves a valid client.
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s.write_all(&encode_frame(&ReqFrame {
        seq: 77,
        app: 0,
        model: 0,
        slo_us: 1_000_000,
        exec_us: 2_000,
        payload_len: 0,
    }))
    .unwrap();
    let mut reply = [0u8; REPLY_LEN];
    s.read_exact(&mut reply).expect("reply after abuse");
    let r = decode_reply(&reply).expect("well-formed reply");
    assert_eq!(r.seq, 77);
    assert_ne!(r.outcome, WIRE_DROP, "roomy ring must not drop");
    drop(s);

    ctl.begin_drain();
    let (_res, counts) = handle.join().expect("server pump panicked");
    assert!(
        counts.proto_errors >= 3,
        "three malformed frames were counted: {counts:?}"
    );
    assert_eq!(counts.frames, 1, "only the valid frame parsed");
}

#[test]
fn ring_full_backpressure_is_a_counted_wire_drop() {
    // No pump: bind the ingress alone with a 2-slot arrival ring and
    // blast 100 frames down one connection. Two land in the ring; the
    // other 98 must come back immediately as WIRE_DROP replies — the
    // backpressure contract is "counted drop, never a block".
    let icfg = IngressConfig {
        shards: 1,
        ring_capacity: 2,
        ..Default::default()
    };
    let net = Ingress::bind("127.0.0.1:0", icfg, RealClock::new()).expect("bind");
    let mut s = TcpStream::connect(net.local_addr()).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut batch = Vec::new();
    for seq in 0..100u32 {
        batch.extend_from_slice(&encode_frame(&ReqFrame {
            seq,
            app: 0,
            model: 0,
            slo_us: 1_000_000,
            exec_us: 1_000,
            payload_len: 0,
        }));
    }
    s.write_all(&batch).unwrap();

    let mut dropped = Vec::new();
    let mut buf = [0u8; REPLY_LEN];
    for _ in 0..98 {
        s.read_exact(&mut buf).expect("drop reply");
        let r = decode_reply(&buf).expect("well-formed drop reply");
        assert_eq!(r.outcome, WIRE_DROP);
        dropped.push(r.seq);
    }
    // The two ring slots were claimed in parse order.
    assert_eq!(dropped, (2..100).collect::<Vec<u32>>());
    assert!(net.pop_arrival().is_some());
    assert!(net.pop_arrival().is_some());
    assert!(net.pop_arrival().is_none());
    drop(s);
    let counts = net.finish();
    assert_eq!(counts.frames, 100);
    assert_eq!(counts.wire_drops, 98);
    assert_eq!(counts.proto_errors, 0);
}

#[test]
fn arrival_ring_survives_many_producers() {
    const PRODUCERS: usize = 8;
    const PER_PRODUCER: u64 = 20_000;
    let ring: Arc<ArrivalRing<u64>> = Arc::new(ArrivalRing::new(1 << 10));
    let handles: Vec<_> = (0..PRODUCERS as u64)
        .map(|p| {
            let ring = Arc::clone(&ring);
            std::thread::spawn(move || {
                for i in 0..PER_PRODUCER {
                    let mut v = (p << 32) | i;
                    loop {
                        match ring.push(v) {
                            Ok(()) => break,
                            Err(back) => {
                                v = back;
                                std::thread::yield_now();
                            }
                        }
                    }
                }
            })
        })
        .collect();
    let total = PRODUCERS as u64 * PER_PRODUCER;
    let mut got = 0u64;
    let mut sum = 0u64;
    while got < total {
        match ring.pop() {
            Some(v) => {
                got += 1;
                sum = sum.wrapping_add(v);
            }
            None => std::thread::yield_now(),
        }
    }
    for h in handles {
        h.join().unwrap();
    }
    assert!(ring.is_empty());
    let expected: u64 = (0..PRODUCERS as u64)
        .flat_map(|p| (0..PER_PRODUCER).map(move |i| (p << 32) | i))
        .fold(0u64, u64::wrapping_add);
    assert_eq!(sum, expected, "no item lost or duplicated under contention");
}
