//! Integration and property tests for the unified serve API (DESIGN.md
//! §3): request conservation across systems × replica counts × clocks,
//! and router behaviour at the cluster level.

use orloj::baselines::ALL_SYSTEMS;
use orloj::clock::{ms_to_us, RealClock, VirtualClock};
use orloj::core::batchmodel::BatchCostModel;
use orloj::core::request::{AppId, ModelId, Request};
use orloj::prop_assert;
use orloj::scheduler::SchedulerConfig;
use orloj::serve::realtime;
use orloj::serve::replay;
use orloj::serve::{
    router, AdmissionConfig, AdmissionController, Cluster, ColdStartCost, Dispatch, ElasticConfig,
    Placement, PlacementController, ServingLoop,
};
use orloj::sim::worker::SimWorker;
use orloj::util::proptest::check_cases;
use orloj::util::rng::Rng;
use orloj::workload::azure::AzureTraceConfig;
use orloj::workload::exectime::ExecTimeDist;
use orloj::workload::trace::{ModelTraffic, TraceSpec};
use std::collections::BTreeMap;

const WORKER_COUNTS: [usize; 3] = [1, 2, 4];

/// Placement specs exercised by the multi-model properties: co-located,
/// disjoint, and hot-model-everywhere.
const PLACEMENTS: [&str; 3] = ["all", "partition", "skewed"];

fn spec(seed: u64, duration_s: f64, load: f64) -> (TraceSpec, SchedulerConfig) {
    let model = BatchCostModel::calibrated(30.0);
    let mut spec = TraceSpec {
        name: "serve-prop".into(),
        dists: vec![
            ExecTimeDist::multimodal("short", 1, 10.0, 10.0, 1.0, None),
            ExecTimeDist::multimodal("long", 1, 80.0, 80.0, 1.0, None),
        ],
        arrivals: AzureTraceConfig {
            apps: 2,
            rate_per_s: 0.0,
            duration_s,
            ..Default::default()
        },
        seed,
        models: Vec::new(),
    };
    spec.scale_rate_to_load(model, load, 8);
    let cfg = SchedulerConfig {
        cost_model: model,
        ..Default::default()
    };
    (spec, cfg)
}

/// A skewed two-model mix: a hot fast model taking 3/4 of the traffic and
/// a cold slow one taking the rest.
fn multimodel_spec(seed: u64, duration_s: f64, load: f64) -> (TraceSpec, SchedulerConfig) {
    let model = BatchCostModel::calibrated(25.0);
    let mut spec = TraceSpec {
        name: "serve-mm-prop".into(),
        dists: Vec::new(),
        arrivals: AzureTraceConfig {
            apps: 1,
            rate_per_s: 0.0,
            duration_s,
            ..Default::default()
        },
        seed,
        models: vec![
            ModelTraffic::new(0, 0.75, vec![ExecTimeDist::constant("hot", 10.0)]),
            ModelTraffic::new(
                1,
                0.25,
                vec![ExecTimeDist::multimodal("cold", 2, 20.0, 90.0, 1.0, None)],
            ),
        ],
    };
    spec.scale_rate_to_load(model, load, 8);
    let cfg = SchedulerConfig {
        cost_model: model,
        ..Default::default()
    };
    (spec, cfg)
}

fn seeded_cluster(
    system: &str,
    s: &TraceSpec,
    cfg: &SchedulerConfig,
    seed: u64,
    n: usize,
) -> Cluster<Box<dyn orloj::scheduler::Scheduler>> {
    let mut cluster = Cluster::build(system, cfg, seed, n).expect("known system");
    for (model, app, hist) in s.seed_histograms(cfg.bins) {
        cluster.seed_app_profile(model, app, &hist, 100);
    }
    cluster
}

fn seeded_placed_cluster(
    system: &str,
    s: &TraceSpec,
    cfg: &SchedulerConfig,
    seed: u64,
    placement: Placement,
) -> Cluster<Box<dyn orloj::scheduler::Scheduler>> {
    let mut cluster = Cluster::build_placed(system, cfg, seed, placement).expect("known system");
    for (model, app, hist) in s.seed_histograms(cfg.bins) {
        cluster.seed_app_profile(model, app, &hist, 100);
    }
    cluster
}

fn sim_workers(cfg: &SchedulerConfig, seed: u64, n: usize) -> Vec<SimWorker> {
    (0..n)
        .map(|w| SimWorker::new(cfg.cost_model, 0.0, seed ^ (w as u64)))
        .collect()
}

/// Every trace request completes exactly once
/// (Finished/Late/TimedOut/Aborted) — for all five systems, worker counts
/// {1, 2, 4} and every router, in virtual time.
#[test]
fn prop_conservation_virtual_clock() {
    check_cases("serve-conservation-virtual", 0x5E12, 4, |rng| {
        let (s, cfg) = spec(rng.next_u64(), 4.0 + rng.f64() * 4.0, 0.7 + rng.f64() * 0.4);
        let trace = s.generate();
        let slo = 1.5 + rng.f64() * 2.5;
        let requests = trace.requests(slo);
        let want: BTreeMap<u64, usize> = requests.iter().map(|r| (r.id.0, 1)).collect();
        for system in ALL_SYSTEMS {
            for n in WORKER_COUNTS {
                let router_name = router::ROUTERS[rng.index(router::ROUTERS.len())];
                let core = ServingLoop::new(
                    VirtualClock::new(),
                    seeded_cluster(system, &s, &cfg, rng.next_u64(), n),
                    router::by_name(router_name).unwrap(),
                );
                let res = replay::run_cluster(core, sim_workers(&cfg, 3, n), requests.clone());
                let mut got: BTreeMap<u64, usize> = BTreeMap::new();
                for c in &res.completions {
                    *got.entry(c.request.id.0).or_insert(0) += 1;
                }
                prop_assert!(
                    got == want,
                    "{system} x{n} ({router_name}): {} completions for {} requests",
                    res.completions.len(),
                    requests.len()
                );
            }
        }
        Ok(())
    });
}

/// The same conservation property on the real clock: the wall-clock pump
/// (channel intake, worker threads) must not lose or duplicate requests
/// either. SimWorker executes instantly, so this exercises the loop
/// mechanics, not the sleep behaviour.
#[test]
fn prop_conservation_real_clock() {
    for system in ALL_SYSTEMS {
        for n in WORKER_COUNTS {
            let cfg = SchedulerConfig {
                cost_model: BatchCostModel::calibrated(10.0),
                ..Default::default()
            };
            let mut cluster = Cluster::build(system, &cfg, 11, n).expect("known system");
            for app in 0..2u32 {
                cluster.seed_app_profile(
                    ModelId::DEFAULT,
                    AppId(app),
                    &orloj::core::histogram::Histogram::constant(10.0),
                    100,
                );
            }
            let core = ServingLoop::new(
                RealClock::new(),
                cluster,
                router::by_name("least_loaded").unwrap(),
            );
            let workers = sim_workers(&cfg, 17, n);
            let (tx, rx) = std::sync::mpsc::channel();
            let n_req = 80u64;
            let mut rng = Rng::new(n as u64);
            for i in 0..n_req {
                // Mix of comfortable and hopeless SLOs so both completion
                // and drop paths run (SimWorker returns instantly, so the
                // comfortable budget only bounds loop latency).
                let slo_ms = if rng.chance(0.8) { 800.0 } else { 0.05 };
                tx.send(Request::new(
                    i,
                    AppId((i % 2) as u32),
                    0,
                    ms_to_us(slo_ms),
                    10.0,
                ))
                .unwrap();
            }
            drop(tx);
            let res = realtime::serve_cluster(core, workers, rx);
            assert_eq!(
                res.completions.len(),
                n_req as usize,
                "{system} x{n}: lost/duplicated requests"
            );
            let mut seen = std::collections::BTreeSet::new();
            for c in &res.completions {
                assert!(
                    seen.insert(c.request.id.0),
                    "{system} x{n}: request {} completed twice",
                    c.request.id.0
                );
            }
            assert_eq!(res.per_worker.len(), n);
        }
    }
}

/// Multi-model request conservation **and hosting**: for all five systems
/// × worker counts {1, 2, 4} × skewed placements, every trace request
/// completes exactly once, and no request is ever executed by a worker
/// that does not host its model.
#[test]
fn prop_conservation_multimodel_placements() {
    let (s, cfg) = multimodel_spec(0x77, 6.0, 0.8);
    let trace = s.generate();
    let requests = trace.requests(3.0);
    let want: BTreeMap<u64, usize> = requests.iter().map(|r| (r.id.0, 1)).collect();
    assert!(
        requests.iter().any(|r| r.model == ModelId(1)),
        "trace must actually mix models"
    );
    for system in ALL_SYSTEMS {
        for n in WORKER_COUNTS {
            for placement_spec in PLACEMENTS {
                let placement = Placement::parse(placement_spec, n, 2).expect("placement");
                let cluster =
                    seeded_placed_cluster(system, &s, &cfg, 3, placement.clone());
                let core = ServingLoop::new(
                    VirtualClock::new(),
                    cluster,
                    router::by_name("least_loaded").unwrap(),
                );
                let res = replay::run_cluster(core, sim_workers(&cfg, 5, n), requests.clone());
                let mut got: BTreeMap<u64, usize> = BTreeMap::new();
                for c in &res.completions {
                    *got.entry(c.request.id.0).or_insert(0) += 1;
                    // The hosting invariant: an executed request ran on a
                    // worker hosting its model (drops carry no worker).
                    if let Some(w) = c.worker {
                        assert!(
                            placement.hosts(w, c.request.model),
                            "{system} x{n} {placement_spec}: request {} (model {:?}) \
                             executed on non-hosting worker {w}",
                            c.request.id.0,
                            c.request.model
                        );
                    }
                }
                assert_eq!(
                    got, want,
                    "{system} x{n} {placement_spec}: lost/duplicated requests"
                );
                // Both models must actually get served (the placement
                // hosts both, and the mix offers both).
                for m in [ModelId(0), ModelId(1)] {
                    assert!(
                        res.completions.iter().any(|c| {
                            c.request.model == m && c.worker.is_some()
                        }),
                        "{system} x{n} {placement_spec}: model {m:?} never executed"
                    );
                }
            }
        }
    }
}

/// Elastic placement property, for all five systems × worker counts
/// {1, 2, 4}: (a) no batch is ever dispatched for a model on a worker
/// that has not finished loading it — a `Load` opens a warming window of
/// exactly the predicted cold-start length (SimWorker realizes the
/// prediction), and no `Execute` of that (worker, model) pair may land
/// inside it; (b) request conservation holds across every
/// evict-triggered re-route (every trace request completes exactly
/// once). The drifting mix guarantees the controller actually acts on
/// the multi-worker configurations.
#[test]
fn prop_elastic_no_dispatch_before_load_and_conservation() {
    let (s, cfg) = multimodel_spec(0x7E, 8.0, 0.9);
    let s = s.drift_rotating(3.0, 0.9);
    let trace = s.generate();
    let requests = trace.requests(3.0);
    let want: BTreeMap<u64, usize> = requests.iter().map(|r| (r.id.0, 1)).collect();
    let mut total_actions = 0usize;
    let mut total_rerouted = 0usize;
    for system in ALL_SYSTEMS {
        for n in WORKER_COUNTS {
            // Capacity floor so both models always fit the cluster.
            let capacity = 2usize.div_ceil(n).max(1);
            let placement = Placement::parse("partition", n, 2).expect("placement");
            let mut cluster = Cluster::build_placed(system, &cfg, 3, placement).unwrap();
            for (model, app, hist) in s.seed_histograms(cfg.bins) {
                // Elastic: any replica may acquire any model.
                cluster.seed_app_profile_everywhere(model, app, &hist, 100);
            }
            let ctl = PlacementController::new(ElasticConfig {
                capacity,
                interval_us: 200_000,
                alpha: 0.5,
                min_dwell_us: 500_000,
                cold_start: ColdStartCost::new(20.0, 30.0),
            });
            let core = ServingLoop::new(
                VirtualClock::new(),
                cluster,
                router::by_name("least_loaded").unwrap(),
            )
            .with_elastic(ctl);
            // Warming windows: (worker, model, until) opened by each Load.
            let mut warming: Vec<(usize, u32, u64)> = Vec::new();
            let res = replay::run_cluster_traced(
                core,
                sim_workers(&cfg, 5, n),
                requests.clone(),
                |t, d| match d {
                    Dispatch::Load {
                        worker,
                        model,
                        cost_ms,
                    } => {
                        warming.push((*worker, model.0, t + ms_to_us(*cost_ms)));
                    }
                    Dispatch::Execute { worker, batch } => {
                        let m = batch[0].model.0;
                        for &(ww, wm, until) in &warming {
                            assert!(
                                !(ww == *worker && wm == m && t < until),
                                "{system} x{n}: worker {worker} executed model {m} at {t} \
                                 inside its warming window (until {until})"
                            );
                        }
                    }
                    Dispatch::Unload { .. } => {}
                },
            );
            let mut got: BTreeMap<u64, usize> = BTreeMap::new();
            for c in &res.completions {
                *got.entry(c.request.id.0).or_insert(0) += 1;
            }
            assert_eq!(
                got, want,
                "{system} x{n}: lost/duplicated requests under elastic placement"
            );
            total_actions += res.placement.actions();
            total_rerouted += res.placement.rerouted;
        }
    }
    // The drifting mix must actually exercise the elastic machinery
    // somewhere in the sweep (the 4-worker capacity-1 configurations
    // leave the controller no choice).
    assert!(total_actions > 0, "no placement actions across the sweep");
    assert!(
        total_rerouted > 0 || total_actions > 0,
        "evict-drain path never exercised"
    );
}

/// An admission controller seeded with the same deployment-time
/// histograms the schedulers get (DESIGN.md §10).
fn admission_ctl(s: &TraceSpec, cfg: &SchedulerConfig, threshold: f64) -> AdmissionController {
    let mut ctl = AdmissionController::new(AdmissionConfig::with_threshold(threshold));
    for (model, app, hist) in s.seed_histograms(cfg.bins) {
        ctl.seed_profile(model, app, &hist);
    }
    ctl
}

/// Admission-gated overload conservation (virtual clock): at 2× offered
/// load, for all five systems × worker counts {1, 4}, every trace request
/// still completes exactly once, every arrival gets exactly one admission
/// fate (admitted + downgraded + early-rejected = arrivals), every
/// downgraded request terminates in the best-effort lane, and the
/// SLO-lane outcome counts (finished + late + shed) reconcile with the
/// admitted + rejected population.
#[test]
fn prop_admission_conservation_virtual_clock() {
    use orloj::core::request::Outcome;
    let (s, cfg) = spec(0xAD, 6.0, 2.0);
    let trace = s.generate();
    let requests = trace.requests(2.0);
    let want: BTreeMap<u64, usize> = requests.iter().map(|r| (r.id.0, 1)).collect();
    for system in ALL_SYSTEMS {
        for n in [1usize, 4] {
            let core = ServingLoop::new(
                VirtualClock::new(),
                seeded_cluster(system, &s, &cfg, 9, n),
                router::by_name("least_loaded").unwrap(),
            )
            .with_admission(admission_ctl(&s, &cfg, 0.5));
            let res = replay::run_cluster(core, sim_workers(&cfg, 3, n), requests.clone());
            let mut got: BTreeMap<u64, usize> = BTreeMap::new();
            for c in &res.completions {
                *got.entry(c.request.id.0).or_insert(0) += 1;
            }
            assert_eq!(
                got, want,
                "{system} x{n}: lost/duplicated requests under admission"
            );
            let st = &res.admission;
            assert!(st.enabled);
            assert_eq!(
                st.admitted + st.downgraded + st.early_rejected,
                requests.len(),
                "{system} x{n}: every arrival needs exactly one admission fate"
            );
            let best_effort = res.completions.iter().filter(|c| c.best_effort).count();
            assert_eq!(
                best_effort, st.downgraded,
                "{system} x{n}: every downgraded request must terminate in the \
                 best-effort lane (and only those)"
            );
            assert!(
                st.best_effort_served <= st.downgraded,
                "{system} x{n}: can't serve more best-effort requests than were downgraded"
            );
            // SLO lane reconciliation: the non-best-effort completions are
            // exactly the admitted + early-rejected arrivals, split across
            // the four outcomes.
            let mut slo_outcomes = [0usize; 4];
            for c in res.completions.iter().filter(|c| !c.best_effort) {
                let i = match c.outcome {
                    Outcome::Finished => 0,
                    Outcome::Late => 1,
                    Outcome::TimedOut => 2,
                    Outcome::Aborted => 3,
                };
                slo_outcomes[i] += 1;
            }
            assert_eq!(
                slo_outcomes.iter().sum::<usize>(),
                st.admitted + st.early_rejected,
                "{system} x{n}: SLO-lane completions must equal admitted + rejected"
            );
        }
    }
}

/// The same admission conservation property on the real clock: the
/// wall-clock pump must not lose, duplicate, or strand requests in the
/// best-effort lane when the intake channel closes.
#[test]
fn prop_admission_conservation_real_clock() {
    use orloj::core::histogram::Histogram;
    for system in ALL_SYSTEMS {
        for n in [1usize, 4] {
            let cfg = SchedulerConfig {
                cost_model: BatchCostModel::calibrated(10.0),
                ..Default::default()
            };
            let mut cluster = Cluster::build(system, &cfg, 11, n).expect("known system");
            let mut ctl = AdmissionController::new(AdmissionConfig::with_threshold(0.5));
            for app in 0..2u32 {
                let hist = Histogram::constant(10.0);
                cluster.seed_app_profile(ModelId::DEFAULT, AppId(app), &hist, 100);
                ctl.seed_profile(ModelId::DEFAULT, AppId(app), &hist);
            }
            let core = ServingLoop::new(
                RealClock::new(),
                cluster,
                router::by_name("least_loaded").unwrap(),
            )
            .with_admission(ctl);
            let workers = sim_workers(&cfg, 17, n);
            let (tx, rx) = std::sync::mpsc::channel();
            let n_req = 80u64;
            let mut rng = Rng::new(0xADC0 + n as u64);
            for i in 0..n_req {
                // Mix of comfortable, marginal, and hopeless SLOs so all
                // three admission fates run.
                let slo_ms = if rng.chance(0.6) {
                    800.0
                } else if rng.chance(0.5) {
                    12.0
                } else {
                    0.05
                };
                tx.send(Request::new(
                    i,
                    AppId((i % 2) as u32),
                    0,
                    ms_to_us(slo_ms),
                    10.0,
                ))
                .unwrap();
            }
            drop(tx);
            let res = realtime::serve_cluster(core, workers, rx);
            assert_eq!(
                res.completions.len(),
                n_req as usize,
                "{system} x{n}: lost/duplicated requests under admission"
            );
            let mut seen = std::collections::BTreeSet::new();
            for c in &res.completions {
                assert!(
                    seen.insert(c.request.id.0),
                    "{system} x{n}: request {} completed twice",
                    c.request.id.0
                );
            }
            let st = &res.admission;
            assert_eq!(
                st.admitted + st.downgraded + st.early_rejected,
                n_req as usize,
                "{system} x{n}: every arrival needs exactly one admission fate"
            );
        }
    }
}

/// Deficit-counter fairness: two identical apps at 3× load must end with
/// comparable admitted shares — the gate may shed aggressively, but it
/// may not starve one app to feed the other.
#[test]
fn admission_fairness_two_apps_at_overload() {
    let model = BatchCostModel::calibrated(20.0);
    let mut s = TraceSpec {
        name: "fairness".into(),
        dists: vec![
            ExecTimeDist::multimodal("a0", 1, 20.0, 20.0, 1.0, None),
            ExecTimeDist::multimodal("a1", 1, 20.0, 20.0, 1.0, None),
        ],
        arrivals: AzureTraceConfig {
            apps: 2,
            rate_per_s: 0.0,
            duration_s: 6.0,
            ..Default::default()
        },
        seed: 0xFA1,
        models: Vec::new(),
    };
    s.scale_rate_to_load(model, 3.0, 8);
    let cfg = SchedulerConfig {
        cost_model: model,
        ..Default::default()
    };
    let trace = s.generate();
    let requests = trace.requests(2.0);
    let core = ServingLoop::new(
        VirtualClock::new(),
        seeded_cluster("orloj", &s, &cfg, 4, 1),
        router::by_name("least_loaded").unwrap(),
    )
    .with_admission(admission_ctl(&s, &cfg, 0.5));
    let res = replay::run_cluster(core, sim_workers(&cfg, 3, 1), requests);
    let st = &res.admission;
    assert_eq!(st.per_app.len(), 2, "both apps must arrive");
    for (app, a) in &st.per_app {
        assert!(
            a.admitted > 0,
            "app {app}: starved under the fairness guard ({a:?})"
        );
    }
    let (lo, hi) = st
        .admit_share_spread()
        .expect("two active apps give a spread");
    assert!(
        hi - lo < 0.30,
        "identical apps at 3x load must keep comparable admitted shares: \
         spread {lo:.2}..{hi:.2}"
    );
}

/// Round-robin admission spreads a steady trace over every replica.
#[test]
fn round_robin_exercises_every_replica() {
    let (s, cfg) = spec(21, 8.0, 0.9);
    let trace = s.generate();
    let core = ServingLoop::new(
        VirtualClock::new(),
        seeded_cluster("edf", &s, &cfg, 1, 4),
        router::by_name("round_robin").unwrap(),
    );
    let res = replay::run_cluster(core, sim_workers(&cfg, 5, 4), trace.requests(3.0));
    assert_eq!(res.per_worker.len(), 4);
    for w in &res.per_worker {
        assert!(w.batches > 0, "replica {} never executed: {:?}", w.worker, res.per_worker);
    }
}

/// Adding replicas monotonically improves (or preserves) the finish count
/// on an overloaded trace, for every router.
#[test]
fn replicas_relieve_overload() {
    let (s, cfg) = spec(33, 10.0, 2.5); // 2.5× one worker's capacity
    let trace = s.generate();
    for router_name in router::ROUTERS {
        let finished = |n: usize| {
            let core = ServingLoop::new(
                VirtualClock::new(),
                seeded_cluster("orloj", &s, &cfg, 2, n),
                router::by_name(router_name).unwrap(),
            );
            let res = replay::run_cluster(core, sim_workers(&cfg, 7, n), trace.requests(3.0));
            res.completions
                .iter()
                .filter(|c| c.outcome == orloj::core::request::Outcome::Finished)
                .count()
        };
        let one = finished(1);
        let four = finished(4);
        assert!(
            four > one,
            "{router_name}: 4 replicas ({four}) should beat 1 ({one}) at 2.5x load"
        );
    }
}
