//! Sharded wall-clock pump properties (DESIGN.md §13).
//!
//! Conservation under live loopback load for every system × shard count
//! with load-aware routing over the `LoadBoard`: the total wire invariant
//! (frames = completions + wire drops), every per-shard ledger (pops +
//! handoffs in = completions + handoffs out), and the S=1 delegation
//! contract — one scheduling shard must take the sequential pump path
//! (empty shard ledger), which is what keeps the existing `serve_cluster`
//! goldens byte-identical.

use orloj::baselines;
use orloj::core::batchmodel::BatchCostModel;
use orloj::core::histogram::Histogram;
use orloj::core::request::{AppId, ModelId};
use orloj::scheduler::{Scheduler, SchedulerConfig};
use orloj::serve::ingress::{IngressConfig, IngressController, IngressCounts};
use orloj::serve::realtime::ServeResult;
use orloj::serve::router;
use orloj::server::Server;
use orloj::sim::worker::SimWorker;
use orloj::workload::loadgen::{self, LoadgenConfig};

type ServerHandle = (
    std::net::SocketAddr,
    IngressController,
    std::thread::JoinHandle<(ServeResult, IngressCounts)>,
);

/// A four-replica sim-worker server behind the TCP ingress on an
/// ephemeral loopback port, pumping with `sched_shards` scheduling
/// shards on its own thread(s).
fn start_server(system: &str, router_name: &str, sched_shards: usize) -> ServerHandle {
    let workers = 4;
    let cfg = SchedulerConfig {
        cost_model: BatchCostModel::calibrated(2.0),
        ..Default::default()
    };
    let hist = Histogram::from_weights(1.5, 1.0, &[1.0]);
    let replicas: Vec<(Box<dyn Scheduler>, SimWorker)> = (0..workers)
        .map(|w| {
            let mut sched =
                baselines::by_name(system, cfg.clone(), w as u64).expect("known system");
            for app in 0..4u32 {
                sched.seed_app_profile(ModelId(0), AppId(app), &hist, 100);
            }
            (sched, SimWorker::new(cfg.cost_model, 0.0, w as u64))
        })
        .collect();
    let server = Server::cluster(replicas, router::by_name(router_name).unwrap())
        .with_shards(sched_shards);
    let icfg = IngressConfig {
        shards: 2,
        ring_capacity: 1 << 12,
        ..Default::default()
    };
    let bound = server.listen("127.0.0.1:0", icfg).expect("bind loopback");
    let addr = bound.local_addr();
    let ctl = bound.controller();
    let handle = std::thread::spawn(move || bound.run());
    (addr, ctl, handle)
}

fn drive(system: &str, router_name: &str, sched_shards: usize) -> (ServeResult, IngressCounts) {
    let (addr, ctl, handle) = start_server(system, router_name, sched_shards);
    let rep = loadgen::run(&LoadgenConfig {
        addr: addr.to_string(),
        conns: 8,
        rate_per_s: 2_000.0,
        duration_s: 0.3,
        apps: 2,
        models: 1,
        slo_multiple: 50.0,
        exec_ms: 2.0,
        payload: 16,
        seed: 11,
        workers: 2,
        drain_timeout_s: 10.0,
    })
    .expect("loadgen runs");
    ctl.begin_drain();
    let (res, counts) = handle.join().expect("server pump panicked");
    let tag = format!("{system}/{router_name}/s{sched_shards}");
    assert!(rep.sent > 0, "{tag}: loadgen sent nothing");
    assert_eq!(
        rep.conservation_violations, 0,
        "{tag}: every request must be answered ({rep:?})"
    );
    assert!(rep.finished > 0, "{tag}: nothing finished ({rep:?})");
    (res, counts)
}

#[test]
fn sharded_conservation_across_systems() {
    for system in ["orloj", "clipper", "clockwork", "nexus", "edf"] {
        for sched_shards in [1usize, 2, 4] {
            let (res, counts) = drive(system, "least_loaded", sched_shards);
            let tag = format!("{system}/s{sched_shards}");
            // Total wire invariant, shards or not.
            assert_eq!(
                counts.frames,
                res.completions.len() as u64 + counts.wire_drops,
                "{tag}: frames either complete or drop ({counts:?})"
            );
            if sched_shards <= 1 {
                // S=1 must delegate to the sequential pump — the golden
                // and byte-compat guarantee; no shard ledger exists.
                assert!(res.shards.is_empty(), "{tag}: S=1 must not shard");
            } else {
                assert_eq!(res.shards.len(), sched_shards, "{tag}: one ledger per shard");
                for ss in &res.shards {
                    assert!(
                        ss.conserved(),
                        "{tag}: shard {} ledger imbalance ({ss:?})",
                        ss.shard
                    );
                }
                let shard_completions: u64 = res.shards.iter().map(|s| s.completions).sum();
                assert_eq!(
                    shard_completions,
                    res.completions.len() as u64,
                    "{tag}: merged completions must equal the shard ledgers"
                );
                let popped: u64 = res.shards.iter().map(|s| s.popped).sum();
                assert_eq!(
                    popped,
                    counts.frames - counts.wire_drops,
                    "{tag}: every undropped frame was popped by exactly one shard"
                );
                // Handoffs balance globally: nothing vanished in transit.
                let out: u64 = res.shards.iter().map(|s| s.handoff_out).sum();
                let inn: u64 = res.shards.iter().map(|s| s.handoff_in).sum();
                assert_eq!(out, inn, "{tag}: handoff rings drained");
            }
        }
    }
}

#[test]
fn sharded_jsq_routing_conserves_too() {
    // The other board-backed load-aware policy takes the same path.
    let (res, counts) = drive("orloj", "join_shortest_queue", 2);
    assert_eq!(counts.frames, res.completions.len() as u64 + counts.wire_drops);
    assert_eq!(res.shards.len(), 2);
    assert!(res.shards.iter().all(|s| s.conserved()));
}

#[test]
fn sharded_merge_lifts_worker_ids_to_global() {
    // With 4 workers in 4 shards every completion's worker id is local 0
    // in its sub-core; the merge must lift them back onto 0..4, and the
    // per-worker stats must cover distinct global ids.
    let (res, _counts) = drive("edf", "least_loaded", 4);
    assert_eq!(res.per_worker.len(), 4);
    let mut ids: Vec<usize> = res.per_worker.iter().map(|w| w.worker).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 1, 2, 3], "global worker ids after the merge");
    assert!(
        res.completions.iter().filter_map(|c| c.worker).all(|w| w < 4),
        "completion worker ids are global"
    );
    // Completions come back merged in completion-time order.
    assert!(
        res.completions.windows(2).all(|p| p[0].at <= p[1].at),
        "merge sorts by completion time"
    );
}
