//! Golden dispatch-sequence regression test (§Perf acceptance): the
//! hot-path optimizations (score templates, slab entries, incremental
//! candidate index, per-model sub-queues, event-heap pump) change *cost*,
//! not *decisions*. Each of the five systems replays a fixed seeded trace
//! and its exact (dispatch time, worker, request ids) sequence is compared
//! bit-for-bit against a recorded snapshot.
//!
//! Snapshot protocol: on the first run (or with `ORLOJ_GOLDEN_RECORD=1`)
//! the sequences are recorded to `tests/golden/dispatch_sequences.json`
//! and the test passes; subsequent runs assert equality. After an
//! *intentional* policy change, re-record and commit the new snapshot.
//! Independently of the snapshot, every configuration is run twice and the
//! two runs must agree exactly — scheduling is deterministic by
//! construction (no HashMap iteration, no wall-clock, seeded RNGs).

use orloj::baselines::ALL_SYSTEMS;
use orloj::clock::{ms_to_us, Micros, VirtualClock};
use orloj::core::batchmodel::BatchCostModel;
use orloj::core::histogram::Histogram;
use orloj::core::request::{AppId, ModelId, Request};
use orloj::scheduler::SchedulerConfig;
use orloj::serve::{replay, router, Cluster, ServingLoop};
use orloj::sim::worker::SimWorker;
use orloj::util::json::Json;
use orloj::util::rng::Rng;
use std::collections::BTreeMap;
use std::path::PathBuf;

/// A fixed two-model, two-app trace: bursty arrivals, mixed SLO tightness,
/// exercising dispatch, milestone refresh, pruning and admission control.
fn fixed_trace() -> Vec<Request> {
    let mut rng = Rng::new(0xD15C);
    let mut reqs = Vec::new();
    let mut t: Micros = 0;
    for i in 0..400u64 {
        t += ms_to_us(rng.exponential(1.0 / 4.0)); // ~4 ms mean gap
        let model = ModelId((rng.index(2)) as u32);
        let app = AppId(rng.index(2) as u32);
        let exec = 4.0 + rng.f64() * 22.0;
        let slo_ms = if rng.chance(0.2) {
            25.0 + rng.f64() * 30.0 // tight: prune/admission paths
        } else {
            120.0 + rng.f64() * 500.0 // roomy: batching paths
        };
        reqs.push(
            Request::new(i, app, t, ms_to_us(slo_ms), exec).with_model(model),
        );
    }
    reqs
}

fn seed_hists() -> Vec<(ModelId, AppId, Histogram)> {
    let fast = Histogram::from_weights(4.0, 2.0, &[2.0, 3.0, 2.0, 1.0]);
    let slow = Histogram::from_weights(8.0, 3.0, &[1.0, 2.0, 2.0, 1.0, 1.0]);
    vec![
        (ModelId(0), AppId(0), fast.clone()),
        (ModelId(0), AppId(1), slow.clone()),
        (ModelId(1), AppId(0), fast),
        (ModelId(1), AppId(1), slow),
    ]
}

/// The (time, worker, ids...) dispatch sequence of one system/worker-count
/// configuration, as a JSON array of `[t_us, worker, [ids...]]` rows.
fn dispatch_sequence(system: &str, workers: usize) -> Json {
    let cfg = SchedulerConfig {
        cost_model: BatchCostModel::new(0.5, 0.5),
        ..Default::default()
    };
    let mut cluster = Cluster::build(system, &cfg, 7, workers).expect("known system");
    for (model, app, hist) in seed_hists() {
        cluster.seed_app_profile(model, app, &hist, 500);
    }
    let sim_workers: Vec<SimWorker> = (0..workers)
        .map(|w| SimWorker::new(cfg.cost_model, 0.0, 0x90 + w as u64))
        .collect();
    let core = ServingLoop::new(
        VirtualClock::new(),
        cluster,
        router::by_name("round_robin").unwrap(),
    );
    let mut rows: Vec<Json> = Vec::new();
    let res = replay::run_cluster_traced(core, sim_workers, fixed_trace(), |t, d| {
        rows.push(Json::arr(vec![
            Json::num(t as f64),
            Json::num(d.worker as f64),
            Json::Arr(d.batch.iter().map(|r| Json::num(r.id.0 as f64)).collect()),
        ]));
    });
    assert_eq!(res.completions.len(), 400, "conservation for {system} x{workers}");
    Json::Arr(rows)
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/dispatch_sequences.json")
}

#[test]
fn dispatch_sequences_are_deterministic_and_match_golden() {
    let mut got: BTreeMap<String, Json> = BTreeMap::new();
    for system in ALL_SYSTEMS {
        for workers in [1usize, 3] {
            let a = dispatch_sequence(system, workers);
            let b = dispatch_sequence(system, workers);
            assert_eq!(
                a, b,
                "nondeterministic dispatch sequence for {system} x{workers}"
            );
            assert!(
                !a.as_arr().unwrap().is_empty(),
                "{system} x{workers} dispatched nothing"
            );
            got.insert(format!("{system}/w{workers}"), a);
        }
    }
    let got = Json::Obj(got);

    let path = golden_path();
    let force_record = std::env::var("ORLOJ_GOLDEN_RECORD")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    if force_record || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, got.to_pretty()).unwrap();
        eprintln!(
            "recorded golden dispatch sequences to {} — COMMIT this file so the \
             regression gate actually compares on fresh checkouts (until it is \
             committed, this test only asserts run-to-run determinism)",
            path.display()
        );
        return;
    }
    let want = Json::parse(&std::fs::read_to_string(&path).unwrap())
        .expect("golden file parses");
    // Compare per configuration for a readable failure.
    let want_obj = want.as_obj().expect("golden file is an object");
    let got_obj = got.as_obj().unwrap();
    for (key, w) in want_obj {
        let g = got.get(key);
        assert_eq!(
            g, w,
            "dispatch sequence for {key} diverged from the golden snapshot; \
             if the policy change is intentional, re-record with \
             ORLOJ_GOLDEN_RECORD=1 cargo test --test golden_dispatch"
        );
    }
    assert_eq!(
        got_obj.len(),
        want_obj.len(),
        "configuration set changed; re-record the golden snapshot"
    );
}
