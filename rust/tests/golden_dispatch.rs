//! Golden dispatch-sequence regression test (§Perf acceptance): the
//! hot-path optimizations (score templates, slab entries, incremental
//! candidate index, per-model sub-queues, event-heap pump) change *cost*,
//! not *decisions*. Each of the five systems replays a fixed seeded trace
//! and its exact (dispatch time, worker, request ids) sequence is compared
//! bit-for-bit against a recorded snapshot.
//!
//! Snapshot protocol: on the first run (or with `ORLOJ_GOLDEN_RECORD=1`)
//! the sequences are recorded to `tests/golden/dispatch_sequences.json`
//! and the test passes; subsequent runs assert equality. After an
//! *intentional* policy change, re-record and commit the new snapshot.
//! Independently of the snapshot, every configuration is run twice and the
//! two runs must agree exactly — scheduling is deterministic by
//! construction (no HashMap iteration, no wall-clock, seeded RNGs).

use orloj::baselines::ALL_SYSTEMS;
use orloj::clock::{ms_to_us, Micros, VirtualClock};
use orloj::core::batchmodel::BatchCostModel;
use orloj::core::histogram::Histogram;
use orloj::core::request::{AppId, ModelId, Request};
use orloj::scheduler::SchedulerConfig;
use orloj::serve::{
    replay, router, AdmissionConfig, AdmissionController, Cluster, ColdStartCost, Dispatch,
    ElasticConfig, Placement, PlacementController, ServingLoop,
};
use orloj::sim::worker::SimWorker;
use orloj::util::json::Json;
use orloj::util::rng::Rng;
use std::collections::BTreeMap;
use std::path::PathBuf;

/// A fixed two-model, two-app trace: bursty arrivals, mixed SLO tightness,
/// exercising dispatch, milestone refresh, pruning and admission control.
fn fixed_trace() -> Vec<Request> {
    let mut rng = Rng::new(0xD15C);
    let mut reqs = Vec::new();
    let mut t: Micros = 0;
    for i in 0..400u64 {
        t += ms_to_us(rng.exponential(1.0 / 4.0)); // ~4 ms mean gap
        let model = ModelId((rng.index(2)) as u32);
        let app = AppId(rng.index(2) as u32);
        let exec = 4.0 + rng.f64() * 22.0;
        let slo_ms = if rng.chance(0.2) {
            25.0 + rng.f64() * 30.0 // tight: prune/admission paths
        } else {
            120.0 + rng.f64() * 500.0 // roomy: batching paths
        };
        reqs.push(
            Request::new(i, app, t, ms_to_us(slo_ms), exec).with_model(model),
        );
    }
    reqs
}

fn seed_hists() -> Vec<(ModelId, AppId, Histogram)> {
    let fast = Histogram::from_weights(4.0, 2.0, &[2.0, 3.0, 2.0, 1.0]);
    let slow = Histogram::from_weights(8.0, 3.0, &[1.0, 2.0, 2.0, 1.0, 1.0]);
    vec![
        (ModelId(0), AppId(0), fast.clone()),
        (ModelId(0), AppId(1), slow.clone()),
        (ModelId(1), AppId(0), fast),
        (ModelId(1), AppId(1), slow),
    ]
}

/// The (time, worker, ids...) dispatch sequence of one system/worker-count
/// configuration, as a JSON array of `[t_us, worker, [ids...]]` rows.
fn dispatch_sequence(system: &str, workers: usize) -> Json {
    let cfg = SchedulerConfig {
        cost_model: BatchCostModel::new(0.5, 0.5),
        ..Default::default()
    };
    let mut cluster = Cluster::build(system, &cfg, 7, workers).expect("known system");
    for (model, app, hist) in seed_hists() {
        cluster.seed_app_profile(model, app, &hist, 500);
    }
    let sim_workers: Vec<SimWorker> = (0..workers)
        .map(|w| SimWorker::new(cfg.cost_model, 0.0, 0x90 + w as u64))
        .collect();
    let core = ServingLoop::new(
        VirtualClock::new(),
        cluster,
        router::by_name("round_robin").unwrap(),
    );
    let mut rows: Vec<Json> = Vec::new();
    let res = replay::run_cluster_traced(core, sim_workers, fixed_trace(), |t, d| {
        let Dispatch::Execute { worker, batch } = d else {
            panic!("static golden run produced a placement dispatch: {d:?}");
        };
        rows.push(Json::arr(vec![
            Json::num(t as f64),
            Json::num(*worker as f64),
            Json::Arr(batch.iter().map(|r| Json::num(r.id.0 as f64)).collect()),
        ]));
    });
    assert_eq!(res.completions.len(), 400, "conservation for {system} x{workers}");
    Json::Arr(rows)
}

/// A drifting two-model trace for the elastic configurations: ~500
/// arrivals at a ~3 ms mean gap span ~1.5 s, and the hot model flips
/// every 400 ms — several full rotations land inside the trace, so the
/// snapshot captures repeated unload/reload churn, not just the initial
/// adaptation.
fn drifting_trace() -> Vec<Request> {
    let mut rng = Rng::new(0xDB1F7);
    let mut reqs = Vec::new();
    let mut t: Micros = 0;
    for i in 0..500u64 {
        t += ms_to_us(rng.exponential(1.0 / 3.0)); // ~3 ms mean gap
        let seg = (t / 400_000) % 2; // 400 ms hot phases
        let hot = seg as u32; // model 0 hot first, then model 1
        let model = if rng.chance(0.85) {
            ModelId(hot)
        } else {
            ModelId(1 - hot)
        };
        let app = AppId(rng.index(2) as u32);
        let exec = 4.0 + rng.f64() * 20.0;
        let slo_ms = 100.0 + rng.f64() * 400.0;
        reqs.push(Request::new(i, app, t, ms_to_us(slo_ms), exec).with_model(model));
    }
    reqs
}

/// The dispatch sequence of one system under the elastic controller on
/// the drifting trace: `Execute` rows as `[t, worker, [ids...]]`, `Load`
/// rows as `[t, worker, "load", model]`, `Unload` rows as
/// `[t, worker, "unload", model]` — placement churn is part of the
/// snapshot, so a controller behaviour drift trips the gate too.
fn elastic_dispatch_sequence(system: &str, workers: usize) -> Json {
    let cfg = SchedulerConfig {
        cost_model: BatchCostModel::new(0.5, 0.5),
        ..Default::default()
    };
    let placement = Placement::parse("partition", workers, 2).unwrap();
    let mut cluster = Cluster::build_placed(system, &cfg, 7, placement).expect("known system");
    for (model, app, hist) in seed_hists() {
        cluster.seed_app_profile_everywhere(model, app, &hist, 500);
    }
    let sim_workers: Vec<SimWorker> = (0..workers)
        .map(|w| SimWorker::new(cfg.cost_model, 0.0, 0x90 + w as u64))
        .collect();
    // Decision cadence, dwell and cold start all sized well inside the
    // 400 ms hot phases so every rotation triggers visible churn.
    let ctl = PlacementController::new(ElasticConfig {
        capacity: 1,
        interval_us: 50_000,
        alpha: 0.6,
        min_dwell_us: 150_000,
        cold_start: ColdStartCost::new(10.0, 20.0),
    });
    let core = ServingLoop::new(
        VirtualClock::new(),
        cluster,
        router::by_name("least_loaded").unwrap(),
    )
    .with_elastic(ctl);
    let mut rows: Vec<Json> = Vec::new();
    let res = replay::run_cluster_traced(core, sim_workers, drifting_trace(), |t, d| {
        rows.push(match d {
            Dispatch::Execute { worker, batch } => Json::arr(vec![
                Json::num(t as f64),
                Json::num(*worker as f64),
                Json::Arr(batch.iter().map(|r| Json::num(r.id.0 as f64)).collect()),
            ]),
            Dispatch::Load { worker, model, .. } => Json::arr(vec![
                Json::num(t as f64),
                Json::num(*worker as f64),
                Json::str("load"),
                Json::num(model.0 as f64),
            ]),
            Dispatch::Unload { worker, model } => Json::arr(vec![
                Json::num(t as f64),
                Json::num(*worker as f64),
                Json::str("unload"),
                Json::num(model.0 as f64),
            ]),
        });
    });
    assert_eq!(
        res.completions.len(),
        500,
        "conservation for elastic {system} x{workers}"
    );
    Json::Arr(rows)
}

fn golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/dispatch_sequences.json")
}

/// A fixed seeded ~2× overload trace for the admission snapshot: tight
/// SLOs land in the reject/downgrade bands of the seeded histograms,
/// roomy ones in the admit band, and the 2 ms mean gap builds real
/// backlog so the decisions shift over the run.
fn overload_trace() -> Vec<Request> {
    let mut rng = Rng::new(0xAD0C);
    let mut reqs = Vec::new();
    let mut t: Micros = 0;
    for i in 0..500u64 {
        t += ms_to_us(rng.exponential(1.0 / 2.0)); // ~2 ms mean gap
        let model = ModelId(rng.index(2) as u32);
        let app = AppId(rng.index(2) as u32);
        let exec = 4.0 + rng.f64() * 22.0;
        let slo_ms = if rng.chance(0.3) {
            4.0 + rng.f64() * 10.0 // tight: downgrade/reject bands
        } else {
            40.0 + rng.f64() * 200.0 // roomy: admit band
        };
        reqs.push(Request::new(i, app, t, ms_to_us(slo_ms), exec).with_model(model));
    }
    reqs
}

/// One system's admission-enabled run on the fixed overload trace: the
/// per-arrival A/D/R decision sequence (from the telemetry stream, in
/// arrival order) plus the resulting dispatch sequence — SLO-lane and
/// best-effort batches alike.
fn admission_sequence(system: &str, workers: usize) -> Json {
    use orloj::telemetry::{EventKind, Recorder, RecorderConfig};
    let cfg = SchedulerConfig {
        cost_model: BatchCostModel::new(0.5, 0.5),
        ..Default::default()
    };
    let mut cluster = Cluster::build(system, &cfg, 7, workers).expect("known system");
    let mut ctl = AdmissionController::new(AdmissionConfig::default());
    for (model, app, hist) in seed_hists() {
        cluster.seed_app_profile(model, app, &hist, 500);
        ctl.seed_profile(model, app, &hist);
    }
    let sim_workers: Vec<SimWorker> = (0..workers)
        .map(|w| SimWorker::new(cfg.cost_model, 0.0, 0x90 + w as u64))
        .collect();
    let core = ServingLoop::new(
        VirtualClock::new(),
        cluster,
        router::by_name("round_robin").unwrap(),
    )
    .with_admission(ctl)
    .with_telemetry(Recorder::with_config(RecorderConfig {
        // Generous ring: a wrapped ring would silently lose the oldest
        // decisions and break the one-decision-per-arrival check.
        capacity: 1 << 16,
        ..Default::default()
    }));
    let mut dispatches: Vec<Json> = Vec::new();
    let res = replay::run_cluster_traced(core, sim_workers, overload_trace(), |t, d| {
        let Dispatch::Execute { worker, batch } = d else {
            panic!("admission golden run produced a placement dispatch: {d:?}");
        };
        dispatches.push(Json::arr(vec![
            Json::num(t as f64),
            Json::num(*worker as f64),
            Json::Arr(batch.iter().map(|r| Json::num(r.id.0 as f64)).collect()),
        ]));
    });
    assert_eq!(
        res.completions.len(),
        500,
        "conservation for admission {system} x{workers}"
    );
    let rec = res.telemetry.expect("recorder");
    let decisions: Vec<Json> = rec
        .events()
        .filter_map(|ev| {
            let (req, letter) = match ev.kind {
                EventKind::Admitted { req, .. } => (req, "A"),
                EventKind::Downgraded { req, .. } => (req, "D"),
                EventKind::EarlyReject { req, .. } => (req, "R"),
                _ => return None,
            };
            Some(Json::arr(vec![Json::num(req.0 as f64), Json::str(letter)]))
        })
        .collect();
    assert_eq!(
        decisions.len(),
        500,
        "one admission decision per arrival for {system} x{workers}"
    );
    Json::obj(vec![
        ("decisions", Json::Arr(decisions)),
        ("dispatches", Json::Arr(dispatches)),
    ])
}

fn admission_golden_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden/admission_sequences.json")
}

/// Admission-enabled golden gate — same snapshot protocol as the dispatch
/// gate but a SEPARATE file, so re-recording one never silently rewrites
/// the other.
#[test]
fn admission_sequences_are_deterministic_and_match_golden() {
    let mut got: BTreeMap<String, Json> = BTreeMap::new();
    for system in ALL_SYSTEMS {
        let a = admission_sequence(system, 2);
        let b = admission_sequence(system, 2);
        assert_eq!(a, b, "nondeterministic admission sequence for {system}");
        got.insert(format!("{system}/w2"), a);
    }
    // The fixed 2x-overload trace must exercise all three fates somewhere
    // in the sweep, or the snapshot guards nothing.
    for letter in ["A", "D", "R"] {
        assert!(
            got.values().any(|v| {
                v.get("decisions")
                    .as_arr()
                    .unwrap()
                    .iter()
                    .any(|d| d.as_arr().unwrap()[1].as_str() == Some(letter))
            }),
            "decision {letter} never taken on the overload trace"
        );
    }
    let got = Json::Obj(got);

    let path = admission_golden_path();
    let force_record = std::env::var("ORLOJ_GOLDEN_RECORD")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    if force_record || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, got.to_pretty()).unwrap();
        eprintln!(
            "recorded golden admission sequences to {} — COMMIT this file so the \
             regression gate actually compares on fresh checkouts (until it is \
             committed, this test only asserts run-to-run determinism)",
            path.display()
        );
        return;
    }
    let want = Json::parse(&std::fs::read_to_string(&path).unwrap())
        .expect("admission golden file parses");
    let want_obj = want.as_obj().expect("admission golden file is an object");
    let got_obj = got.as_obj().unwrap();
    for (key, w) in want_obj {
        let g = got.get(key);
        assert_eq!(
            g, w,
            "admission sequence for {key} diverged from the golden snapshot; \
             if the policy change is intentional, re-record with \
             ORLOJ_GOLDEN_RECORD=1 cargo test --test golden_dispatch"
        );
    }
    assert_eq!(
        got_obj.len(),
        want_obj.len(),
        "configuration set changed; re-record the admission golden snapshot"
    );
}

#[test]
fn dispatch_sequences_are_deterministic_and_match_golden() {
    let mut got: BTreeMap<String, Json> = BTreeMap::new();
    for system in ALL_SYSTEMS {
        for workers in [1usize, 3] {
            let a = dispatch_sequence(system, workers);
            let b = dispatch_sequence(system, workers);
            assert_eq!(
                a, b,
                "nondeterministic dispatch sequence for {system} x{workers}"
            );
            assert!(
                !a.as_arr().unwrap().is_empty(),
                "{system} x{workers} dispatched nothing"
            );
            got.insert(format!("{system}/w{workers}"), a);
        }
        // One drifting elastic configuration per system: controller
        // decisions (loads/unloads) are snapshotted alongside executes.
        let a = elastic_dispatch_sequence(system, 4);
        let b = elastic_dispatch_sequence(system, 4);
        assert_eq!(
            a, b,
            "nondeterministic elastic dispatch sequence for {system}"
        );
        assert!(
            !a.as_arr().unwrap().is_empty(),
            "elastic {system} dispatched nothing"
        );
        got.insert(format!("elastic/{system}/w4"), a);
    }
    let got = Json::Obj(got);

    let path = golden_path();
    let force_record = std::env::var("ORLOJ_GOLDEN_RECORD")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false);
    if force_record || !path.exists() {
        std::fs::create_dir_all(path.parent().unwrap()).unwrap();
        std::fs::write(&path, got.to_pretty()).unwrap();
        eprintln!(
            "recorded golden dispatch sequences to {} — COMMIT this file so the \
             regression gate actually compares on fresh checkouts (until it is \
             committed, this test only asserts run-to-run determinism)",
            path.display()
        );
        return;
    }
    let want = Json::parse(&std::fs::read_to_string(&path).unwrap())
        .expect("golden file parses");
    // Compare per configuration for a readable failure.
    let want_obj = want.as_obj().expect("golden file is an object");
    let got_obj = got.as_obj().unwrap();
    for (key, w) in want_obj {
        let g = got.get(key);
        assert_eq!(
            g, w,
            "dispatch sequence for {key} diverged from the golden snapshot; \
             if the policy change is intentional, re-record with \
             ORLOJ_GOLDEN_RECORD=1 cargo test --test golden_dispatch"
        );
    }
    assert_eq!(
        got_obj.len(),
        want_obj.len(),
        "configuration set changed; re-record the golden snapshot"
    );
}
