//! Allocation audit of the dispatch hot path (§Perf acceptance; the full
//! audit narrative lives in DESIGN.md §7).
//!
//! A counting global allocator (thread-local counter, so parallel test
//! threads don't pollute the measurement) asserts the invariants the
//! refactor establishes:
//!
//! * a warm, drained scheduler polls `next_batch` / `wake_hint` /
//!   `pending_for` with **zero** heap allocations (the common steady-state
//!   case: the serve loop polls every idle replica on each wake);
//! * a warm Fibonacci heap runs insert/pop cycles with **zero**
//!   allocations (the consolidate scratch buffers are reused);
//! * the per-dispatch cycle's scheduler-owned bookkeeping reuses pooled
//!   buffers — measured here informationally (the hull's tree nodes and
//!   the returned batch `Vec` remain, see DESIGN.md §7);
//! * a warm admission controller decides arrival fates (DESIGN.md §10)
//!   with **zero** allocations — the per-app table and class profiles
//!   only grow on first sight;
//! * the sharded pump's per-frame wire path — arrival partition, load
//!   board, handoff ring (DESIGN.md §13) — runs with **zero** allocations
//!   once its rings and board are built.

use orloj::clock::ms_to_us;
use orloj::core::batchmodel::BatchCostModel;
use orloj::core::histogram::Histogram;
use orloj::core::request::{AppId, ModelId, Request};
use orloj::ds::fibheap::FibHeap;
use orloj::scheduler::orloj::OrlojScheduler;
use orloj::scheduler::{Scheduler, SchedulerConfig};
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

thread_local! {
    /// None = not measuring; Some(n) = allocations observed on this thread.
    static ALLOC_COUNT: Cell<Option<u64>> = const { Cell::new(None) };
}

struct CountingAlloc;

// Counting is thread-local and `try_with` tolerates TLS teardown, so the
// allocator never recurses or panics.
fn bump() {
    let _ = ALLOC_COUNT.try_with(|c| {
        if let Some(n) = c.get() {
            c.set(Some(n + 1));
        }
    });
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        bump();
        unsafe { System.alloc(layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        bump();
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Run `f` with this thread's allocation counter armed; returns (allocs, result).
fn count_allocs<R>(f: impl FnOnce() -> R) -> (u64, R) {
    ALLOC_COUNT.with(|c| c.set(Some(0)));
    let r = f();
    let n = ALLOC_COUNT.with(|c| {
        let n = c.get().expect("counter armed");
        c.set(None);
        n
    });
    (n, r)
}

fn seeded_sched() -> OrlojScheduler {
    let cfg = SchedulerConfig {
        batch_sizes: vec![1, 2, 4, 8],
        cost_model: BatchCostModel::new(0.5, 0.5),
        ..Default::default()
    };
    let mut s = OrlojScheduler::new(cfg, 42);
    let h = Histogram::from_weights(8.0, 1.0, &[1.0, 2.0, 1.0, 1.0]);
    s.seed_profile(ModelId(0), AppId(0), &h, 100);
    s
}

/// Warm the scheduler through arrival→dispatch→complete churn, then drain
/// it fully (no pending entries, caches and pools at their high-water
/// capacity).
fn warm_and_drain(s: &mut OrlojScheduler) -> u64 {
    let mut t = 0u64;
    for i in 0..300u64 {
        s.on_arrival(
            Request::new(i, AppId(0), t, ms_to_us(400.0), 10.0),
            t,
        );
        t += ms_to_us(3.0);
        if let Some(b) = s.next_batch(t) {
            s.on_batch_complete(&b, 10.0, t);
        }
    }
    let mut guard = 0;
    while s.pending() > 0 && guard < 10_000 {
        t += ms_to_us(5.0);
        if let Some(b) = s.next_batch(t) {
            s.on_batch_complete(&b, 10.0, t);
        }
        guard += 1;
    }
    assert_eq!(s.pending(), 0, "warmup must drain");
    s.drain_dropped();
    t
}

#[test]
fn warm_idle_next_batch_allocates_nothing() {
    let mut s = seeded_sched();
    let mut t = warm_and_drain(&mut s);
    // Steady-state idle polling: milestone peek + prune scan + candidate
    // index scan, all on warm structures. Must not touch the allocator.
    let (allocs, _) = count_allocs(|| {
        for _ in 0..1_000 {
            t += 100;
            assert!(s.next_batch(t).is_none());
            let _ = s.wake_hint(t);
            let _ = s.pending_for(ModelId(0));
            let _ = s.pending();
        }
    });
    assert_eq!(
        allocs, 0,
        "warm idle next_batch/wake_hint must be allocation-free"
    );
}

#[test]
fn warm_fib_heap_cycles_allocate_nothing() {
    let mut h: FibHeap<u64> = FibHeap::new();
    // Warm: grow the node arena, free list and consolidate scratch to
    // their high-water capacity.
    for k in 0..2_000u64 {
        h.insert((k * 7919) % 4096, k);
    }
    while h.pop_min().is_some() {}
    // Measured: a full insert/pop cycle within the warmed capacity.
    let (allocs, _) = count_allocs(|| {
        for k in 0..1_000u64 {
            h.insert((k * 104_729) % 4096, k);
        }
        let mut prev = 0;
        while let Some((k, _)) = h.pop_min() {
            assert!(k >= prev);
            prev = k;
        }
    });
    assert_eq!(allocs, 0, "warm fib-heap cycles must be allocation-free");
}

#[test]
fn disabled_telemetry_idle_wake_allocates_nothing() {
    // The telemetry recorder is threaded through the serve loop as an
    // `Option<Box<Recorder>>`; disabled (the default) every hook is a
    // single `None` branch. Guard that promise at the loop level: a warm,
    // drained `ServingLoop` polled with `Event::Wake` must not touch the
    // allocator at all — same bar as the scheduler-level idle poll above.
    use orloj::clock::VirtualClock;
    use orloj::serve::{router, Cluster, Event, ServingLoop};

    let clock = VirtualClock::new();
    let cluster = Cluster::new(vec![seeded_sched()]);
    let mut core = ServingLoop::new(
        clock.clone(),
        cluster,
        router::by_name("round_robin").unwrap(),
    );
    // Warm end to end: arrivals routed, batches dispatched and completed,
    // so the completions vector and scheduler pools sit at their
    // high-water capacity before measuring.
    let mut t = 0u64;
    for i in 0..300u64 {
        clock.advance_to(t);
        core.on_event(Event::Arrival(Request::new(
            i,
            AppId(0),
            t,
            ms_to_us(400.0),
            10.0,
        )));
        let ds = core.on_event(Event::Wake);
        t += ms_to_us(3.0);
        clock.advance_to(t);
        for _ in ds {
            core.on_event(Event::BatchDone {
                worker: 0,
                batch_ms: 10.0,
            });
        }
    }
    let mut guard = 0;
    while (core.pending() > 0 || core.in_flight() > 0) && guard < 10_000 {
        t += ms_to_us(5.0);
        clock.advance_to(t);
        if core.in_flight() > 0 {
            core.on_event(Event::BatchDone {
                worker: 0,
                batch_ms: 10.0,
            });
        }
        core.on_event(Event::Wake);
        guard += 1;
    }
    assert_eq!(core.pending(), 0, "warmup must drain");
    assert_eq!(core.in_flight(), 0);
    let (allocs, _) = count_allocs(|| {
        for _ in 0..1_000 {
            t += 100;
            clock.advance_to(t);
            let ds = core.on_event(Event::Wake);
            assert!(ds.is_empty());
            let _ = core.next_wake(t);
        }
    });
    assert_eq!(
        allocs, 0,
        "idle serve-loop wake with telemetry disabled must be allocation-free"
    );
}

#[test]
fn warm_admission_decisions_allocate_nothing() {
    // The admission gate sits on the arrival hot path (DESIGN.md §10):
    // once every app has its fairness entry, `decide()` is linear probes
    // over small warm tables — no hashing, no growth, no allocator.
    use orloj::serve::{AdmissionConfig, AdmissionController};

    let mut c = AdmissionController::new(AdmissionConfig::default());
    let h = Histogram::from_weights(8.0, 1.0, &[1.0, 2.0, 1.0, 1.0]);
    for app in 0..4u32 {
        c.seed_profile(ModelId(0), AppId(app), &h);
    }
    // Warm: first-seen app entries are the only growth on the decision
    // path; touch all four apps and all three fate bands.
    let backlog_for = |i: u64| match i % 3 {
        0 => 0.0,   // plenty of slack → admit
        1 => 91.0,  // marginal → downgrade
        _ => 99.0,  // hopeless → reject
    };
    let mut t = 0u64;
    for i in 0..200u64 {
        let r = Request::new(i, AppId((i % 4) as u32), t, ms_to_us(100.0), 10.0);
        let _ = c.decide(&r, backlog_for(i), t);
        t += ms_to_us(1.0);
    }
    // Measured: decisions across every app and every band, warm tables.
    let (allocs, _) = count_allocs(|| {
        for i in 0..1_000u64 {
            let r = Request::new(10_000 + i, AppId((i % 4) as u32), t, ms_to_us(100.0), 10.0);
            let _ = c.decide(&r, backlog_for(i), t);
            t += ms_to_us(1.0);
        }
    });
    assert_eq!(
        allocs, 0,
        "warm admission decide() must be allocation-free"
    );
    let s = c.stats();
    assert!(s.admitted > 0 && s.downgraded > 0 && s.early_rejected > 0);
}

#[test]
fn warm_ingress_ring_and_frame_codec_allocate_nothing() {
    // The wire arrival path (DESIGN.md §12): header bytes → `decode_frame`
    // → stack `Request` → `ArrivalRing::push`, and on the way back
    // `encode_reply` into a fixed buffer. The ring's slots are allocated
    // once at construction; a frame parse is pure stack work — so the
    // whole warm path must never touch the allocator.
    use orloj::serve::ingress::{
        decode_frame, encode_frame, encode_reply, Reply, ReqFrame, REQ_HEADER_LEN,
    };
    use orloj::serve::ring::ArrivalRing;

    let ring: ArrivalRing<Request> = ArrivalRing::new(256);
    let frame_bytes: [u8; REQ_HEADER_LEN] = encode_frame(&ReqFrame {
        seq: 9,
        app: 1,
        model: 0,
        slo_us: 250_000,
        exec_us: 5_000,
        payload_len: 0,
    });
    let (allocs, moved) = count_allocs(|| {
        let mut moved = 0usize;
        let mut reply_bytes = 0usize;
        for i in 0..1_000u64 {
            let f = decode_frame(&frame_bytes, 1 << 20).expect("valid frame");
            let req = Request::new(
                i,
                AppId(f.app),
                i * 100,
                u64::from(f.slo_us),
                f.exec_us as f64 / 1000.0,
            )
            .with_model(ModelId(f.model));
            ring.push(req).expect("ring has room");
            let popped = ring.pop().expect("we just pushed");
            moved += usize::from(popped.app == AppId(f.app));
            let out = encode_reply(&Reply {
                slot: 0,
                gen: 0,
                seq: f.seq,
                outcome: 0,
                best_effort: 0,
                batch_size: 1,
                latency_us: 1_000,
                done_at_us: i,
            });
            reply_bytes += out.len();
        }
        assert!(reply_bytes > 0);
        moved
    });
    assert_eq!(moved, 1_000);
    assert_eq!(
        allocs, 0,
        "warm ring transfer + frame parse/encode must be allocation-free"
    );
}

#[test]
fn warm_sharded_wire_path_allocates_nothing() {
    // The sharded pump's per-frame work (DESIGN.md §13): decode a wire
    // frame, build the stack `Request`, push/pop an arrival partition,
    // take a routing decision off the lock-free `LoadBoard`, note the
    // optimistic cross-shard bump, hop the Vyukov handoff ring, publish
    // the shard's refreshed loads, and encode the reply. Every structure
    // is allocated at shard start-up; the warm per-frame cycle must never
    // touch the allocator.
    use orloj::serve::ingress::{
        decode_frame, encode_frame, encode_reply, Reply, ReqFrame, REQ_HEADER_LEN,
    };
    use orloj::serve::ring::ArrivalRing;
    use orloj::serve::router::{BoardPolicy, BoardRouter, LoadBoard};
    use std::sync::Arc;

    let partition: ArrivalRing<Request> = ArrivalRing::new(256);
    let handoff: ArrivalRing<(usize, Request)> = ArrivalRing::new(256);
    let board = Arc::new(LoadBoard::new(4));
    let picker = BoardRouter::new(Arc::clone(&board), BoardPolicy::LeastLoaded);
    for w in 0..4 {
        board.publish(w, w, 1, 500 * w as u64);
    }
    let candidates: Vec<usize> = (0..4).collect();
    let frame_bytes: [u8; REQ_HEADER_LEN] = encode_frame(&ReqFrame {
        seq: 3,
        app: 0,
        model: 0,
        slo_us: 250_000,
        exec_us: 5_000,
        payload_len: 0,
    });
    let (allocs, routed) = count_allocs(|| {
        let mut routed = 0usize;
        let mut reply_bytes = 0usize;
        for i in 0..1_000u64 {
            let f = decode_frame(&frame_bytes, 1 << 20).expect("valid frame");
            let req = Request::new(
                i,
                AppId(f.app),
                i * 100,
                u64::from(f.slo_us),
                f.exec_us as f64 / 1000.0,
            )
            .with_model(ModelId(f.model));
            partition.push(req).expect("partition has room");
            let req = partition.pop().expect("we just pushed");
            let w = picker.pick(&candidates);
            board.note_routed(w);
            handoff.push((w, req)).expect("handoff has room");
            let (w, _req) = handoff.pop().expect("we just handed off");
            routed += usize::from(w < 4);
            board.publish(w, 1, 1, 2_000);
            let out = encode_reply(&Reply {
                slot: 0,
                gen: 0,
                seq: f.seq,
                outcome: 0,
                best_effort: 0,
                batch_size: 1,
                latency_us: 1_000,
                done_at_us: i,
            });
            reply_bytes += out.len();
        }
        assert!(reply_bytes > 0);
        routed
    });
    assert_eq!(routed, 1_000);
    assert_eq!(
        allocs, 0,
        "warm sharded wire path (partition + board + handoff) must be allocation-free"
    );
}

#[test]
fn dispatch_cycle_allocations_are_bounded_and_reported() {
    // Informational bound: a full arrival→dispatch cycle still allocates
    // (hull tree nodes, the returned batch Vec — see DESIGN.md §7), but
    // the refactor removed the per-decision hashing, schedule rebuilds and
    // candidate-sort allocations. Guard against gross regressions with a
    // deliberately loose ceiling and print the measurement for the bench
    // trajectory.
    let mut s = seeded_sched();
    let mut t = warm_and_drain(&mut s);
    let cycles = 200u64;
    let (allocs, served) = count_allocs(|| {
        let mut served = 0usize;
        for i in 0..cycles {
            s.on_arrival(
                Request::new(10_000 + i, AppId(0), t, ms_to_us(400.0), 10.0),
                t,
            );
            t += ms_to_us(3.0);
            if let Some(b) = s.next_batch(t) {
                served += b.len();
                s.on_batch_complete(&b, 10.0, t);
            }
        }
        served
    });
    assert!(served > 0);
    let per_cycle = allocs as f64 / cycles as f64;
    println!("dispatch cycle: {allocs} allocs / {cycles} cycles = {per_cycle:.1} per cycle");
    assert!(
        per_cycle < 500.0,
        "dispatch-cycle allocations exploded: {per_cycle:.1} per cycle"
    );
}
