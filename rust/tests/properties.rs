//! Property-based tests over the coordinator's invariants, using the
//! in-tree mini-proptest driver (`orloj::util::proptest`) — seeded random
//! cases with replayable failure seeds.

use orloj::clock::ms_to_us;
use orloj::core::batchmodel::BatchCostModel;
use orloj::core::histogram::Histogram;
use orloj::core::orderstats;
use orloj::core::priority::{reference_score, ScoreContext, ScoreSchedule};
use orloj::core::request::{AppId, ModelId, Request};
use orloj::ds::fibheap::FibHeap;
use orloj::ds::hull::point::{upper_hull_naive, Point};
use orloj::ds::hull::DynamicHull;
use orloj::prop_assert;
use orloj::scheduler::orloj::OrlojScheduler;
use orloj::scheduler::{Scheduler, SchedulerConfig};
use orloj::util::proptest::check;
use orloj::util::rng::Rng;

fn random_hist(rng: &mut Rng) -> Histogram {
    let nb = 1 + rng.index(10);
    let w: Vec<f64> = (0..nb).map(|_| rng.f64() + 0.01).collect();
    Histogram::from_weights(rng.f64() * 30.0 + 0.5, 0.5 + rng.f64() * 8.0, &w)
}

/// Hull query equals naive arg-max for any insert/delete interleaving.
#[test]
fn prop_hull_matches_naive() {
    check("hull-vs-naive", 0xB01, |rng| {
        let mut hull = DynamicHull::new();
        let mut pts: Vec<Point> = Vec::new();
        let n_ops = 40 + rng.index(120);
        for i in 0..n_ops {
            if pts.is_empty() || rng.f64() < 0.65 {
                let p = Point::new(
                    rng.normal() * 50.0,
                    rng.normal() * 50.0,
                    i as u64,
                );
                hull.insert(p);
                pts.push(p);
            } else {
                let idx = rng.index(pts.len());
                let p = pts.swap_remove(idx);
                prop_assert!(hull.delete(&p), "delete of existing point failed");
            }
        }
        for _ in 0..8 {
            let m = rng.f64() * 50.0;
            let naive_best = upper_hull_naive(&pts)
                .iter()
                .map(|p| p.eval(m))
                .fold(f64::MIN, f64::max);
            match hull.query_max(m) {
                Some(got) => {
                    prop_assert!(
                        (got.eval(m) - naive_best).abs() <= 1e-9 * (1.0 + naive_best.abs()),
                        "query m={m}: {} vs naive {naive_best}",
                        got.eval(m)
                    );
                }
                None => prop_assert!(pts.is_empty(), "empty query with points present"),
            }
        }
        Ok(())
    });
}

/// FibHeap min always equals the true minimum under mixed ops.
#[test]
fn prop_fibheap_min_invariant() {
    check("fibheap-min", 0xF1B, |rng| {
        let mut heap = FibHeap::new();
        let mut live: Vec<(orloj::ds::fibheap::Handle, u64)> = Vec::new();
        for _ in 0..200 {
            match rng.index(3) {
                0 | 1 => {
                    let k = rng.below(10_000);
                    let h = heap.insert(k, k);
                    live.push((h, k));
                }
                _ => {
                    if !live.is_empty() {
                        let idx = rng.index(live.len());
                        let (h, k) = live.swap_remove(idx);
                        let (_, v) = heap.delete(h);
                        prop_assert!(v == k, "deleted wrong payload");
                    }
                }
            }
            let want = live.iter().map(|&(_, k)| k).min();
            prop_assert!(
                heap.min_key() == want,
                "min {:?} != expected {:?}",
                heap.min_key(),
                want
            );
        }
        Ok(())
    });
}

/// E[max of k] is monotone in k and bounded by the distribution support.
#[test]
fn prop_orderstats_monotone_bounded() {
    check("orderstats-monotone", 0x0D5, |rng| {
        let h = random_hist(rng);
        let mut prev = h.mean();
        for k in 2..=12 {
            let m = orderstats::max_iid(&h, k);
            prop_assert!(m.is_normalized(), "mass lost at k={k}");
            let mean = m.mean();
            prop_assert!(
                mean + 1e-9 >= prev,
                "E[max] not monotone: k={k} {mean} < {prev}"
            );
            prop_assert!(
                mean <= h.hi() + 1e-9,
                "E[max] exceeds support: {mean} > {}",
                h.hi()
            );
            prev = mean;
        }
        Ok(())
    });
}

/// Non-iid max via the direct product rule equals Eq. 8 (Özbey).
#[test]
fn prop_ozbey_equals_direct() {
    check("ozbey-direct", 0x0E8, |rng| {
        let k = 2 + rng.index(3);
        let hs: Vec<Histogram> = (0..k).map(|_| random_hist(rng)).collect();
        let refs: Vec<&Histogram> = hs.iter().collect();
        let d = orderstats::max_inid_direct(&refs, 80);
        let o = orderstats::max_inid_ozbey(&refs, 80);
        for i in 0..80 {
            prop_assert!(
                (d.masses()[i] - o.masses()[i]).abs() < 1e-8,
                "bin {i}: {} vs {}",
                d.masses()[i],
                o.masses()[i]
            );
        }
        Ok(())
    });
}

/// The segment-compiled score equals the direct Eq. 2 evaluation at random
/// times, for random batch-latency distributions and deadlines.
#[test]
fn prop_score_schedule_equals_reference() {
    check("score-schedule", 0x5C0, |rng| {
        let ctx = ScoreContext::new(1e-4);
        let l_b = random_hist(rng);
        let d_ms = orloj::clock::us_to_ms(ms_to_us(20.0 + rng.f64() * 3_000.0));
        let c = 0.2 + rng.f64() * 3.0;
        let sched = ScoreSchedule::build(&ctx, ms_to_us(d_ms), c, &l_b);
        for _ in 0..16 {
            let t = rng.f64() * d_ms * 1.3 - 20.0;
            let fast = sched.score_at(1e-4, t);
            let slow = reference_score(1e-4, d_ms, c, &l_b, t);
            prop_assert!(
                (fast - slow).abs() < 1e-7 * (1.0 + slow.abs()),
                "t={t}: fast={fast} slow={slow}"
            );
        }
        Ok(())
    });
}

/// Scheduler conservation: arrivals = dispatched + dropped + still-pending,
/// and no request is ever dispatched twice.
#[test]
fn prop_scheduler_conservation() {
    check("scheduler-conservation", 0x5CED, |rng| {
        let cfg = SchedulerConfig {
            cost_model: BatchCostModel::calibrated(25.0),
            ..Default::default()
        };
        let mut s = OrlojScheduler::new(cfg, rng.next_u64());
        s.seed_profile(ModelId::DEFAULT, AppId(0), &Histogram::constant(25.0), 100);
        s.seed_profile(ModelId::DEFAULT, AppId(1), &Histogram::constant(80.0), 100);
        let n = 30 + rng.index(100) as u64;
        let mut dispatched = std::collections::BTreeSet::new();
        let mut dropped = 0usize;
        let mut t: u64 = 0;
        for i in 0..n {
            t += rng.below(20_000); // up to 20 ms apart
            let app = AppId(rng.index(2) as u32);
            let slo = ms_to_us(30.0 + rng.f64() * 600.0);
            s.on_arrival(Request::new(i, app, t, slo, 25.0), t);
            if rng.chance(0.5) {
                if let Some(batch) = s.next_batch(t) {
                    for r in &batch {
                        prop_assert!(
                            dispatched.insert(r.id.0),
                            "request {} dispatched twice",
                            r.id.0
                        );
                    }
                    t += rng.below(60_000);
                    s.on_batch_complete(&batch, 10.0, t);
                }
            }
            dropped += s.drain_dropped().len();
        }
        // Drain the rest.
        let mut guard = 0;
        loop {
            t += 50_000;
            if let Some(batch) = s.next_batch(t) {
                for r in &batch {
                    prop_assert!(dispatched.insert(r.id.0), "dup dispatch at drain");
                }
                s.on_batch_complete(&batch, 10.0, t);
            }
            dropped += s.drain_dropped().len();
            if s.pending() == 0 {
                break;
            }
            guard += 1;
            prop_assert!(guard < 10_000, "drain did not converge");
        }
        prop_assert!(
            dispatched.len() + dropped == n as usize,
            "conservation: {} + {} != {}",
            dispatched.len(),
            dropped,
            n
        );
        Ok(())
    });
}

/// Batch latency distribution scales linearly under Eq. 3's affine map.
#[test]
fn prop_batch_model_affine_consistency() {
    check("batch-affine", 0xBA7C, |rng| {
        let h = random_hist(rng);
        let m = BatchCostModel::new(rng.f64() * 5.0, 0.1 + rng.f64());
        let k = 1 + rng.index(8);
        let d = m.batch_latency_iid(&h, k);
        let max = orderstats::max_iid(&h, k);
        let want = m.c0 + m.c1 * k as f64 * max.mean();
        prop_assert!(
            (d.mean() - want).abs() < 1e-6 * (1.0 + want),
            "E[L_B] affine mismatch: {} vs {want}",
            d.mean()
        );
        Ok(())
    });
}
