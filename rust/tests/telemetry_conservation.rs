//! Telemetry conservation (DESIGN.md §9): the lifecycle recorder must
//! agree exactly with the run's own bookkeeping. Every request in a
//! seeded multi-model trace gets *exactly one* `Terminal` event, and the
//! per-outcome tallies reconcile with `RunReport` — across all five
//! systems × {1, 4} workers, so router fan-out, reaping and scheduler
//! shed paths are all covered. The calibration report is recomputed
//! independently from the raw `BatchFormed`/`BatchDone` pairs and must
//! match `Recorder::calibration` row for row (the acceptance check for
//! the estimator-calibration stream).

use orloj::baselines::ALL_SYSTEMS;
use orloj::core::batchmodel::BatchCostModel;
use orloj::core::request::{Outcome, RequestId};
use orloj::scheduler::SchedulerConfig;
use orloj::sim::runner::{self, ClusterSpec};
use orloj::telemetry::{calibration_table, EventKind, Recorder};
use orloj::util::json::Json;
use orloj::util::stats;
use orloj::workload::azure::AzureTraceConfig;
use orloj::workload::exectime::ExecTimeDist;
use orloj::workload::trace::{ModelTraffic, TraceSpec};
use std::collections::BTreeMap;

/// A seeded two-model mix: a fast constant-latency majority model plus a
/// multimodal minority one (the runner's multi-model unit mix, shortened).
fn multimodel_spec(duration_s: f64) -> TraceSpec {
    let mut spec = TraceSpec {
        name: "tel-conservation".into(),
        dists: Vec::new(),
        arrivals: AzureTraceConfig {
            apps: 1,
            rate_per_s: 0.0,
            duration_s,
            ..Default::default()
        },
        seed: 78,
        models: vec![
            ModelTraffic::new(0, 0.7, vec![ExecTimeDist::constant("fast", 8.0)]),
            ModelTraffic::new(
                1,
                0.3,
                vec![ExecTimeDist::multimodal("slow", 2, 15.0, 80.0, 1.0, None)],
            ),
        ],
    };
    spec.scale_rate_to_load(BatchCostModel::gpu_like(), 0.6, 8);
    spec
}

fn cfg() -> SchedulerConfig {
    SchedulerConfig {
        cost_model: BatchCostModel::gpu_like(),
        ..Default::default()
    }
}

/// Count terminal events per request and per outcome.
fn terminal_tallies(rec: &Recorder) -> (BTreeMap<RequestId, usize>, BTreeMap<&'static str, usize>) {
    let mut per_req: BTreeMap<RequestId, usize> = BTreeMap::new();
    let mut per_outcome: BTreeMap<&'static str, usize> = BTreeMap::new();
    for ev in rec.events() {
        if let EventKind::Terminal { req, outcome, .. } = ev.kind {
            *per_req.entry(req).or_default() += 1;
            let key = match outcome {
                Outcome::Finished => "finished",
                Outcome::Late => "late",
                Outcome::TimedOut => "timed_out",
                Outcome::Aborted => "aborted",
            };
            *per_outcome.entry(key).or_default() += 1;
        }
    }
    (per_req, per_outcome)
}

#[test]
fn every_request_has_exactly_one_terminal_event() {
    let spec = multimodel_spec(8.0);
    let trace = spec.generate();
    let total = trace.events.len();
    assert!(total > 100, "trace too small to exercise anything: {total}");
    for system in ALL_SYSTEMS {
        for workers in [1usize, 4] {
            let cluster = ClusterSpec::new(workers, "round_robin").with_telemetry();
            let cell = runner::run_one(system, &spec, &trace, 3.0, &cfg(), spec.seed, &cluster);
            let rec = cell
                .telemetry
                .as_ref()
                .unwrap_or_else(|| panic!("{system} x{workers}: no recorder came back"));
            assert_eq!(
                rec.dropped_events(),
                0,
                "{system} x{workers}: ring overflowed ({} recorded)",
                rec.recorded()
            );
            let (per_req, per_outcome) = terminal_tallies(rec);
            // Exactly one terminal span per request — none missing, none
            // double-terminated (the re-route and shed paths are the easy
            // ways to get this wrong).
            assert_eq!(
                per_req.len(),
                total,
                "{system} x{workers}: {} of {total} requests reached a terminal event",
                per_req.len()
            );
            for (req, n) in &per_req {
                assert_eq!(*n, 1, "{system} x{workers}: request {req:?} terminated {n} times");
            }
            // The recorder's outcome tallies are the report's, recomputed
            // from a completely separate stream.
            let r = &cell.report;
            let get = |k: &str| per_outcome.get(k).copied().unwrap_or(0);
            assert_eq!(
                (get("finished"), get("late"), get("timed_out"), get("aborted")),
                (r.finished, r.late, r.timed_out, r.aborted),
                "{system} x{workers}: terminal outcomes diverge from RunReport ({r})"
            );
            assert_eq!(r.total, total, "{system} x{workers}: completion conservation");
        }
    }
}

/// The conservation invariant extended to the admission paths (DESIGN.md
/// §10): with the gate on at 2× overload, every request still gets
/// exactly one `Terminal` event — including early-rejected arrivals
/// (paired `EarlyReject` + `Terminal { TimedOut }`) and downgraded
/// requests that live and die in the best-effort lane — and the
/// `EarlyReject`/`Downgraded` event counts reconcile with the run's
/// `AdmissionStats`, across all five systems × {1, 4} workers.
#[test]
fn admission_rejects_record_exactly_one_terminal() {
    let mut spec = multimodel_spec(8.0);
    // Re-scale the same mix to 2x capacity so all three admission fates
    // (admit / downgrade / early-reject) actually fire.
    spec.scale_rate_to_load(BatchCostModel::gpu_like(), 2.0, 8);
    let trace = spec.generate();
    let total = trace.events.len();
    for system in ALL_SYSTEMS {
        for workers in [1usize, 4] {
            let cluster = ClusterSpec::new(workers, "round_robin")
                .with_telemetry()
                .with_admission(0.5);
            let cell = runner::run_one(system, &spec, &trace, 2.0, &cfg(), spec.seed, &cluster);
            let rec = cell
                .telemetry
                .as_ref()
                .unwrap_or_else(|| panic!("{system} x{workers}: no recorder came back"));
            assert_eq!(
                rec.dropped_events(),
                0,
                "{system} x{workers}: ring overflowed ({} recorded)",
                rec.recorded()
            );
            let (per_req, _) = terminal_tallies(rec);
            assert_eq!(
                per_req.len(),
                total,
                "{system} x{workers}: {} of {total} requests reached a terminal event",
                per_req.len()
            );
            for (req, n) in &per_req {
                assert_eq!(
                    *n, 1,
                    "{system} x{workers}: request {req:?} terminated {n} times"
                );
            }
            let mut rejects: BTreeMap<RequestId, usize> = BTreeMap::new();
            let mut downgrades = 0usize;
            let mut terminal_outcome: BTreeMap<RequestId, Outcome> = BTreeMap::new();
            for ev in rec.events() {
                match ev.kind {
                    EventKind::EarlyReject { req, .. } => *rejects.entry(req).or_default() += 1,
                    EventKind::Downgraded { .. } => downgrades += 1,
                    EventKind::Terminal { req, outcome, .. } => {
                        terminal_outcome.insert(req, outcome);
                    }
                    _ => {}
                }
            }
            assert_eq!(
                rejects.values().sum::<usize>(),
                cell.admission.early_rejected,
                "{system} x{workers}: EarlyReject events diverge from AdmissionStats"
            );
            assert_eq!(
                downgrades, cell.admission.downgraded,
                "{system} x{workers}: Downgraded events diverge from AdmissionStats"
            );
            for req in rejects.keys() {
                assert_eq!(
                    terminal_outcome.get(req),
                    Some(&Outcome::TimedOut),
                    "{system} x{workers}: early-rejected {req:?} must terminate TimedOut"
                );
            }
        }
    }
}

#[test]
fn arrivals_are_recorded_once_per_request() {
    let spec = multimodel_spec(6.0);
    let trace = spec.generate();
    let total = trace.events.len();
    let cluster = ClusterSpec::new(2, "least_loaded").with_telemetry();
    let cell = runner::run_one("orloj", &spec, &trace, 3.0, &cfg(), spec.seed, &cluster);
    let rec = cell.telemetry.as_ref().expect("recorder");
    let mut arrivals: BTreeMap<RequestId, usize> = BTreeMap::new();
    for ev in rec.events() {
        if let EventKind::Arrival { req, .. } = ev.kind {
            *arrivals.entry(req).or_default() += 1;
        }
    }
    assert_eq!(arrivals.len(), total, "every request must arrive");
    assert!(
        arrivals.values().all(|&n| n == 1),
        "an arrival was recorded more than once (re-route must not re-arrive)"
    );
}

/// The acceptance check: run the seeded two-model *drifting* mix through
/// orloj with telemetry on, recompute the calibration report from the raw
/// prediction pairs, and require it to match `Recorder::calibration`
/// exactly; the Chrome trace export must round-trip through the JSON
/// parser with a non-empty event list.
#[test]
fn calibration_reconciles_with_prediction_pairs_on_drift_trace() {
    let spec = multimodel_spec(10.0).drift_rotating(2.0, 0.85);
    let trace = spec.generate();
    let cluster = ClusterSpec::new(2, "least_loaded").with_telemetry();
    let cell = runner::run_one("orloj", &spec, &trace, 3.0, &cfg(), spec.seed, &cluster);
    let rec = cell.telemetry.as_ref().expect("recorder");

    let pairs = rec.prediction_pairs();
    assert!(
        pairs.len() > 20,
        "drift run produced too few completed batches: {}",
        pairs.len()
    );
    // Orloj predicts every batch: a zero-width (0,0,0) prediction would
    // mean the formation hook lost the estimator's output.
    assert!(
        pairs.iter().all(|p| p.predicted_ms > 0.0 && p.hi_ms >= p.lo_ms),
        "batch formed without a usable prediction"
    );

    // Independent recomputation of the per-(model, app) report.
    let mut classes: BTreeMap<(u32, u32), (Vec<f64>, usize)> = BTreeMap::new();
    for p in &pairs {
        let (errs, covered) = classes.entry((p.model.0, p.app.0)).or_default();
        errs.push(p.realized_ms - p.predicted_ms);
        if p.realized_ms >= p.lo_ms && p.realized_ms <= p.hi_ms {
            *covered += 1;
        }
    }
    let rows = rec.calibration();
    assert_eq!(rows.len(), classes.len(), "one calibration row per class");
    for row in &rows {
        let (errs, covered) = &classes[&(row.model.0, row.app.0)];
        assert_eq!(row.n, errs.len());
        assert!((row.mean_err_ms - stats::mean(errs)).abs() < 1e-9);
        assert!((row.p10_ms - stats::percentile(errs, 10.0)).abs() < 1e-9);
        assert!((row.p50_ms - stats::percentile(errs, 50.0)).abs() < 1e-9);
        assert!((row.p90_ms - stats::percentile(errs, 90.0)).abs() < 1e-9);
        let cov = *covered as f64 / errs.len() as f64;
        assert!((row.coverage - cov).abs() < 1e-9);
        assert!((0.0..=1.0).contains(&row.coverage));
    }
    // Both models saw traffic under the rotation, so both must calibrate.
    let models: Vec<u32> = rows.iter().map(|r| r.model.0).collect();
    assert!(models.contains(&0) && models.contains(&1), "rows: {models:?}");
    let table = calibration_table(&rows);
    assert!(table.contains("coverage"), "{table}");

    // Chrome trace export: parses, and actually contains events.
    let parsed = Json::parse(&rec.chrome_trace().to_string()).expect("chrome trace parses");
    let events = parsed.get("traceEvents").as_arr().expect("traceEvents array");
    assert!(!events.is_empty(), "empty chrome trace");

    // Time-series export: windows exist and totals reconcile.
    let series = rec.time_series();
    let windows = series.get("windows").as_arr().expect("windows array");
    assert!(!windows.is_empty());
    let arrivals: f64 = windows
        .iter()
        .map(|w| w.get("arrivals").as_f64().unwrap_or(0.0))
        .sum();
    assert_eq!(arrivals as usize, trace.events.len(), "windowed arrivals conserve");
}
