//! End-to-end tests over the real PJRT runtime and AOT artifacts.
//!
//! These need `make artifacts` to have run; they are skipped (with a
//! message) when the artifact directory is absent so `cargo test` works in
//! a fresh checkout.

use orloj::core::request::{AppId, Request};
use orloj::runtime::executor::PjrtWorker;
use orloj::runtime::ModelRuntime;
use orloj::sim::worker::Worker;
use orloj::util::json::Json;
use std::path::{Path, PathBuf};
use std::sync::Arc;

fn artifact_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping end_to_end tests: run `make artifacts` first");
        None
    }
}

#[test]
fn runtime_loads_all_variants() {
    let Some(dir) = artifact_dir() else { return };
    let rt = ModelRuntime::load(&dir).expect("load");
    assert_eq!(
        rt.variant_count(),
        rt.manifest.model.max_depth * rt.manifest.batch_sizes.len()
    );
    assert_eq!(rt.platform(), "cpu");
}

/// Rust-side execution reproduces the golden logits python computed at AOT
/// time — numerics parity across the HLO-text interchange.
#[test]
fn numerics_match_python_golden() {
    let Some(dir) = artifact_dir() else { return };
    let rt = ModelRuntime::load(&dir).expect("load");
    let manifest_text = std::fs::read_to_string(dir.join("manifest.json")).unwrap();
    let manifest = Json::parse(&manifest_text).unwrap();
    let golden = manifest.get("golden");
    assert!(!golden.is_null(), "manifest missing golden outputs");
    let tokens: Vec<i32> = golden
        .get("tokens")
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_i64().unwrap() as i32)
        .collect();
    assert_eq!(tokens.len(), rt.manifest.model.seq);
    for case in golden.get("outputs").as_arr().unwrap() {
        let depth = case.get("depth").as_u64().unwrap() as usize;
        let want: Vec<f64> = case
            .get("logits")
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        let got = rt.execute(depth, 1, &tokens).expect("execute");
        assert_eq!(got.len(), want.len(), "depth {depth}: logit count");
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (*g as f64 - w).abs() < 1e-4 * (1.0 + w.abs()),
                "depth {depth} logit {i}: rust={g} python={w}"
            );
        }
    }
}

/// Batched execution at a padded size gives the same per-row logits as
/// solo execution (padding rows don't contaminate real rows).
#[test]
fn padding_preserves_per_row_outputs() {
    let Some(dir) = artifact_dir() else { return };
    let rt = ModelRuntime::load(&dir).expect("load");
    let seq = rt.manifest.model.seq;
    let classes = rt.manifest.model.classes;
    let tokens_a: Vec<i32> = (0..seq as i32).map(|i| (i * 3 + 1) % 32).collect();
    let tokens_b: Vec<i32> = (0..seq as i32).map(|i| (i * 5 + 2) % 32).collect();
    let solo_a = rt.execute(2, 1, &tokens_a).unwrap();
    let solo_b = rt.execute(2, 1, &tokens_b).unwrap();
    let mut both = tokens_a.clone();
    both.extend_from_slice(&tokens_b);
    let batch = rt.execute(2, 2, &both).unwrap();
    for i in 0..classes {
        assert!((batch[i] - solo_a[i]).abs() < 1e-4, "row 0 logit {i}");
        assert!(
            (batch[classes + i] - solo_b[i]).abs() < 1e-4,
            "row 1 logit {i}"
        );
    }
}

/// Latency grows with early-exit depth — the dynamic-DNN premise measured
/// on real execution.
#[test]
fn latency_grows_with_depth() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Arc::new(ModelRuntime::load(&dir).expect("load"));
    let mut worker = PjrtWorker::new(rt.clone());
    let calib = worker.calibrate(30);
    assert_eq!(calib.len(), rt.manifest.model.max_depth);
    let d1 = calib.first().unwrap().1;
    let dmax = calib.last().unwrap().1;
    assert!(
        dmax > 1.5 * d1,
        "deepest exit should be clearly slower: d1={d1:.3}ms dmax={dmax:.3}ms"
    );
}

/// The worker runs mixed-depth batches at the max depth and measures time.
#[test]
fn mixed_batch_runs_at_max_depth() {
    let Some(dir) = artifact_dir() else { return };
    let rt = Arc::new(ModelRuntime::load(&dir).expect("load"));
    let max_depth = rt.manifest.model.max_depth as u32;
    let mut worker = PjrtWorker::new(rt.clone());
    let shallow: Vec<Request> = (0..4)
        .map(|i| Request::new(i, AppId(0), 0, 1_000_000, 1.0).with_variant(1))
        .collect();
    let mixed: Vec<Request> = (0..4)
        .map(|i| {
            let d = if i == 0 { max_depth } else { 1 };
            Request::new(i, AppId(0), 0, 1_000_000, 1.0).with_variant(d)
        })
        .collect();
    // Warm both paths, then compare medians over several reps.
    let med = |w: &mut PjrtWorker, batch: &[Request]| {
        let mut xs: Vec<f64> = (0..15).map(|_| w.execute(batch)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        xs[xs.len() / 2]
    };
    let _ = med(&mut worker, &shallow);
    let t_shallow = med(&mut worker, &shallow);
    let t_mixed = med(&mut worker, &mixed);
    assert!(
        t_mixed > 1.3 * t_shallow,
        "one deep straggler should slow the whole batch: shallow={t_shallow:.3}ms mixed={t_mixed:.3}ms"
    );
}
