//! End-to-end telemetry: request-lifecycle tracing, windowed time-series
//! and estimator calibration (DESIGN.md §9).
//!
//! The [`Recorder`] is a pre-allocated ring buffer of fixed-size
//! [`TelemetryEvent`]s. It is threaded through the serve core as an
//! `Option<Box<Recorder>>`: with telemetry disabled (the default) every
//! hook is a single `if let Some(..)` on a `None` — branch-cheap and
//! allocation-free, so the PR 4 zero-alloc audit and the golden dispatch
//! snapshots hold bit-exactly. With telemetry enabled, recording an event
//! is a bounds-checked store into the pre-allocated ring; when the ring is
//! full the *oldest* event is overwritten and a dropped-events counter is
//! bumped — the recorder never blocks or grows on the pump's hot path.
//!
//! Timestamps are the crate-wide [`Micros`] tick, so the virtual-time
//! replay pump and the wall-clock realtime pump share one schema; a trace
//! recorded under `VirtualClock` loads in Perfetto exactly like one
//! recorded under `RealClock`.
//!
//! Post-hoc analysis (all allocation is after the run):
//! * [`Recorder::chrome_trace`] — Chrome trace-event JSON, loadable in
//!   Perfetto / `chrome://tracing`: one track per worker (batch execution
//!   and model load spans) plus one counter track per model queue.
//! * [`Recorder::time_series`] — windowed per-window arrivals, finish and
//!   shed rates, batch sizes, utilization, queue depth and per-model
//!   backlog, plus the calibration stream; this is what the CLI writes to
//!   `TELEMETRY_*.json`.
//! * [`Recorder::calibration`] — the estimator calibration report:
//!   predicted vs. realized batch exec time per (model, app), signed
//!   error quantiles, and coverage of the predicted [p10, p90] band
//!   (the paper's Eq. 1–2 machinery, measured).

use crate::clock::{us_to_ms, Micros};
use crate::core::request::{AppId, ModelId, Outcome, RequestId};
use crate::util::json::Json;
use crate::util::stats;
use std::collections::{BTreeMap, BTreeSet};

/// One recorded event: a clock-generic timestamp plus the payload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TelemetryEvent {
    pub at: Micros,
    pub kind: EventKind,
}

/// Fixed-size event payloads. Worker indices are narrowed to `u32`; batch
/// ids are assigned by [`Recorder::begin_batch`] and are monotone across
/// the whole run (unique across workers).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// Request entered the serving loop.
    Arrival {
        req: RequestId,
        model: ModelId,
        app: AppId,
    },
    /// Router picked a replica; the request is now in that worker's queue.
    Routed { req: RequestId, worker: u32 },
    /// Router found no replica for the request's model; it was shed.
    RouteDrop { req: RequestId },
    /// Scheduler formed a batch, with the estimator's prediction at
    /// formation time: mean `predicted_ms` and the [`lo_ms`, `hi_ms`]
    /// variance band (p10/p90 of the predicted distribution).
    BatchFormed {
        batch: u32,
        worker: u32,
        model: ModelId,
        app: AppId,
        size: u32,
        predicted_ms: f64,
        lo_ms: f64,
        hi_ms: f64,
    },
    /// Request → batch membership.
    InBatch { req: RequestId, batch: u32 },
    /// Batch began executing on its worker.
    ExecStart { batch: u32, worker: u32 },
    /// Batch finished; `batch_ms` is the realized execution time.
    BatchDone {
        batch: u32,
        worker: u32,
        batch_ms: f64,
    },
    /// Terminal state of a request (exactly one per request).
    Terminal {
        req: RequestId,
        outcome: Outcome,
        worker: Option<u32>,
    },
    /// The serving loop woke (timer or arrival) and polled schedulers.
    Wake,
    /// Scheduler-side reap of infeasible requests on a worker's queue.
    Reap { worker: u32 },
    /// Placement decision: start loading `model` onto `worker`.
    Load {
        worker: u32,
        model: ModelId,
        cost_ms: f64,
    },
    /// Placement decision: evict `model` from `worker`.
    Unload { worker: u32, model: ModelId },
    /// Cold start finished; the replica is live after `load_ms`.
    LoadDone {
        worker: u32,
        model: ModelId,
        load_ms: f64,
    },
    /// Windowed sample: requests pending on a worker's scheduler.
    QueueSample { worker: u32, pending: u32 },
    /// Windowed sample: cluster-wide backlog for one model.
    ModelBacklog { model: ModelId, pending: u32 },
    /// Admission gate passed (admission-control runs only); `p` is the
    /// estimated P(finish ≤ deadline) at arrival. The request continues
    /// down the normal routed path.
    Admitted { req: RequestId, p: f64 },
    /// Admission parked the request in the best-effort lane: it only
    /// executes when the SLO lane would leave a worker idle, and never
    /// counts toward the SLO finish rate.
    Downgraded { req: RequestId, p: f64 },
    /// Admission rejected the request at arrival as hopeless under the
    /// current backlog. Terminal — a `Terminal { outcome: TimedOut }`
    /// for the same request is recorded alongside.
    EarlyReject { req: RequestId, p: f64 },
    /// Request frame parsed off the wire by ingress `shard` (network
    /// serving path only; recorded at the shard's `release` stamp).
    WireIn { req: RequestId, shard: u16 },
    /// Reply frame queued back to ingress `shard` for the originating
    /// connection; together with `WireIn` this bounds the wire→wire
    /// lifecycle in chrome traces.
    WireOut { req: RequestId, shard: u16 },
}

/// Ring capacity and sampling window for a [`Recorder`].
#[derive(Debug, Clone, Copy)]
pub struct RecorderConfig {
    /// Maximum events held; once full, the oldest event is overwritten
    /// (drop-oldest) and [`Recorder::dropped_events`] counts the loss.
    pub capacity: usize,
    /// Width of the time-series sampling window, in microseconds.
    pub window_us: Micros,
}

impl Default for RecorderConfig {
    fn default() -> Self {
        RecorderConfig {
            capacity: 1 << 16,
            window_us: 100_000,
        }
    }
}

/// Pre-allocated ring-buffer event recorder. Construction allocates the
/// full ring up front; recording never allocates.
#[derive(Debug, Clone)]
pub struct Recorder {
    cfg: RecorderConfig,
    events: Vec<TelemetryEvent>,
    /// Total events ever recorded; `pos % capacity` is the write slot.
    pos: usize,
    dropped: u64,
    next_batch: u32,
    /// Last batch id formed per worker (pump looks this up at dispatch).
    last_batch: Vec<Option<u32>>,
    /// Models observed in arrivals, in first-seen order.
    models: Vec<ModelId>,
    next_sample_at: Micros,
}

impl Default for Recorder {
    fn default() -> Self {
        Recorder::new()
    }
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::with_config(RecorderConfig::default())
    }

    pub fn with_config(cfg: RecorderConfig) -> Recorder {
        Recorder {
            cfg,
            events: Vec::with_capacity(cfg.capacity.max(1)),
            pos: 0,
            dropped: 0,
            next_batch: 0,
            last_batch: Vec::new(),
            models: Vec::new(),
            next_sample_at: 0,
        }
    }

    /// Record one event. Never allocates: once the ring is full the oldest
    /// event is overwritten and the dropped counter is bumped.
    pub fn record(&mut self, at: Micros, kind: EventKind) {
        if let EventKind::Arrival { model, .. } = kind {
            if !self.models.contains(&model) {
                self.models.push(model);
            }
        }
        let ev = TelemetryEvent { at, kind };
        let cap = self.cfg.capacity.max(1);
        if self.events.len() < cap {
            self.events.push(ev);
        } else {
            if self.dropped == 0 {
                crate::log_trace!(
                    "telemetry",
                    "ring full at {} events; dropping oldest from here on",
                    cap
                );
            }
            self.events[self.pos % cap] = ev;
            self.dropped += 1;
        }
        self.pos += 1;
    }

    /// Assign the next batch id and remember it as `worker`'s most recent
    /// formation, so the pump can tag the imminent `ExecStart`.
    pub fn begin_batch(&mut self, worker: usize) -> u32 {
        let id = self.next_batch;
        self.next_batch += 1;
        if self.last_batch.len() <= worker {
            self.last_batch.resize(worker + 1, None);
        }
        self.last_batch[worker] = Some(id);
        id
    }

    /// The most recently formed batch id on `worker`, if any.
    pub fn last_batch_for(&self, worker: usize) -> Option<u32> {
        self.last_batch.get(worker).copied().flatten()
    }

    /// True once per sampling window: the caller should emit
    /// `QueueSample`/`ModelBacklog` events now. Advances the gate to the
    /// next window boundary.
    pub fn sample_due(&mut self, now: Micros) -> bool {
        if now < self.next_sample_at {
            return false;
        }
        let w = self.cfg.window_us.max(1);
        self.next_sample_at = (now / w + 1) * w;
        true
    }

    /// Number of distinct models seen in arrivals so far. Paired with
    /// [`Recorder::model_at`] so samplers can interleave reads with
    /// `record` calls without holding a borrow of the recorder.
    pub fn models_len(&self) -> usize {
        self.models.len()
    }

    pub fn model_at(&self, i: usize) -> ModelId {
        self.models[i]
    }

    /// Events currently held, oldest first.
    pub fn events(&self) -> impl Iterator<Item = &TelemetryEvent> {
        let cap = self.cfg.capacity.max(1);
        let split = if self.events.len() < cap {
            0
        } else {
            self.pos % cap
        };
        self.events[split..].iter().chain(self.events[..split].iter())
    }

    /// Events currently held in the ring.
    pub fn recorded(&self) -> usize {
        self.events.len()
    }

    /// Events lost to drop-oldest overwrites.
    pub fn dropped_events(&self) -> u64 {
        self.dropped
    }

    pub fn window_us(&self) -> Micros {
        self.cfg.window_us
    }

    /// Highest worker index mentioned by any event, plus one.
    fn worker_count(&self) -> usize {
        let mut max_w: Option<u32> = None;
        let mut bump = |w: u32| {
            max_w = Some(max_w.map_or(w, |m: u32| m.max(w)));
        };
        for ev in self.events() {
            match ev.kind {
                EventKind::Routed { worker, .. }
                | EventKind::BatchFormed { worker, .. }
                | EventKind::ExecStart { worker, .. }
                | EventKind::BatchDone { worker, .. }
                | EventKind::Reap { worker }
                | EventKind::Load { worker, .. }
                | EventKind::Unload { worker, .. }
                | EventKind::LoadDone { worker, .. }
                | EventKind::QueueSample { worker, .. } => bump(worker),
                EventKind::Terminal {
                    worker: Some(w), ..
                } => bump(w),
                _ => {}
            }
        }
        max_w.map_or(1, |m| m as usize + 1)
    }

    // ---- exporters (post-hoc; free to allocate) --------------------------

    /// Chrome trace-event JSON (load in Perfetto or `chrome://tracing`).
    ///
    /// Layout: pid 1 is the serving loop; tid 0 is the scheduler/router
    /// track (shed instants), tid `w + 1` is worker `w` (batch-execution
    /// and model-load spans). Each model queue gets its own counter track
    /// (`backlog m<id>`), each worker queue likewise (`queue w<id>`).
    /// `ts`/`dur` are microseconds, as the format requires.
    pub fn chrome_trace(&self) -> Json {
        let mut out: Vec<Json> = Vec::new();
        let meta = |tid: f64, name: &str| {
            Json::obj(vec![
                ("name", Json::str("thread_name")),
                ("ph", Json::str("M")),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(tid)),
                (
                    "args",
                    Json::obj(vec![("name", Json::str(name.to_string()))]),
                ),
            ])
        };
        out.push(Json::obj(vec![
            ("name", Json::str("process_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(1.0)),
            ("tid", Json::num(0.0)),
            ("args", Json::obj(vec![("name", Json::str("orloj"))])),
        ]));
        out.push(meta(0.0, "scheduler"));
        for w in 0..self.worker_count() {
            out.push(meta(w as f64 + 1.0, &format!("worker {w}")));
        }

        struct Formed {
            model: ModelId,
            app: AppId,
            size: u32,
            predicted_ms: f64,
            at: Micros,
            exec_at: Option<Micros>,
        }
        let mut formed: BTreeMap<u32, Formed> = BTreeMap::new();
        let mut loads: BTreeMap<(u32, u32), Micros> = BTreeMap::new();
        // Wire lifecycle (network serving path): WireIn start times joined
        // to WireOut, drawn on dedicated ingress tracks (tid 100 + shard,
        // clear of the worker tids).
        const INGRESS_TID_BASE: f64 = 100.0;
        let mut wire_in: BTreeMap<u64, Micros> = BTreeMap::new();
        let mut ingress_shards: BTreeSet<u16> = BTreeSet::new();
        let span = |name: String, cat: &str, tid: u32, ts: Micros, dur_us: f64, args: Json| {
            Json::obj(vec![
                ("name", Json::str(name)),
                ("cat", Json::str(cat.to_string())),
                ("ph", Json::str("X")),
                ("ts", Json::num(ts as f64)),
                ("dur", Json::num(dur_us.max(0.0))),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(tid as f64 + 1.0)),
                ("args", args),
            ])
        };
        let counter = |name: String, ts: Micros, key: &str, v: f64| {
            Json::obj(vec![
                ("name", Json::str(name)),
                ("ph", Json::str("C")),
                ("ts", Json::num(ts as f64)),
                ("pid", Json::num(1.0)),
                ("args", Json::obj(vec![(key, Json::num(v))])),
            ])
        };
        for ev in self.events() {
            match ev.kind {
                EventKind::BatchFormed {
                    batch,
                    model,
                    app,
                    size,
                    predicted_ms,
                    ..
                } => {
                    formed.insert(
                        batch,
                        Formed {
                            model,
                            app,
                            size,
                            predicted_ms,
                            at: ev.at,
                            exec_at: None,
                        },
                    );
                }
                EventKind::ExecStart { batch, .. } => {
                    if let Some(f) = formed.get_mut(&batch) {
                        f.exec_at = Some(ev.at);
                    }
                }
                EventKind::BatchDone {
                    batch,
                    worker,
                    batch_ms,
                } => {
                    if let Some(f) = formed.get(&batch) {
                        let start = f.exec_at.unwrap_or(f.at);
                        out.push(span(
                            format!("batch {} m{} ×{}", batch, f.model.0, f.size),
                            "exec",
                            worker,
                            start,
                            batch_ms * 1000.0,
                            Json::obj(vec![
                                ("model", Json::num(f.model.0 as f64)),
                                ("app", Json::num(f.app.0 as f64)),
                                ("size", Json::num(f.size as f64)),
                                ("predicted_ms", Json::num(f.predicted_ms)),
                                ("realized_ms", Json::num(batch_ms)),
                            ]),
                        ));
                    }
                }
                EventKind::Load { worker, model, .. } => {
                    loads.insert((worker, model.0), ev.at);
                }
                EventKind::LoadDone {
                    worker,
                    model,
                    load_ms,
                } => {
                    let start = loads
                        .remove(&(worker, model.0))
                        .unwrap_or_else(|| ev.at.saturating_sub(crate::clock::ms_to_us(load_ms)));
                    out.push(span(
                        format!("load m{}", model.0),
                        "placement",
                        worker,
                        start,
                        (ev.at.saturating_sub(start)) as f64,
                        Json::obj(vec![("load_ms", Json::num(load_ms))]),
                    ));
                }
                EventKind::Unload { worker, model } => {
                    out.push(Json::obj(vec![
                        ("name", Json::str(format!("unload m{}", model.0))),
                        ("cat", Json::str("placement")),
                        ("ph", Json::str("i")),
                        ("s", Json::str("t")),
                        ("ts", Json::num(ev.at as f64)),
                        ("pid", Json::num(1.0)),
                        ("tid", Json::num(worker as f64 + 1.0)),
                    ]));
                }
                EventKind::Terminal { req, outcome, .. } => {
                    if !matches!(outcome, Outcome::Finished | Outcome::Late) {
                        out.push(Json::obj(vec![
                            ("name", Json::str(format!("shed r{} {outcome:?}", req.0))),
                            ("cat", Json::str("shed")),
                            ("ph", Json::str("i")),
                            ("s", Json::str("t")),
                            ("ts", Json::num(ev.at as f64)),
                            ("pid", Json::num(1.0)),
                            ("tid", Json::num(0.0)),
                        ]));
                    }
                }
                EventKind::QueueSample { worker, pending } => {
                    out.push(counter(
                        format!("queue w{worker}"),
                        ev.at,
                        "pending",
                        pending as f64,
                    ));
                }
                EventKind::ModelBacklog { model, pending } => {
                    out.push(counter(
                        format!("backlog m{}", model.0),
                        ev.at,
                        "pending",
                        pending as f64,
                    ));
                }
                EventKind::Downgraded { req, p } | EventKind::EarlyReject { req, p } => {
                    let verb = if matches!(ev.kind, EventKind::Downgraded { .. }) {
                        "downgrade"
                    } else {
                        "early-reject"
                    };
                    out.push(Json::obj(vec![
                        ("name", Json::str(format!("{verb} r{} p={p:.2}", req.0))),
                        ("cat", Json::str("admission")),
                        ("ph", Json::str("i")),
                        ("s", Json::str("t")),
                        ("ts", Json::num(ev.at as f64)),
                        ("pid", Json::num(1.0)),
                        ("tid", Json::num(0.0)),
                    ]));
                }
                EventKind::WireIn { req, shard } => {
                    ingress_shards.insert(shard);
                    wire_in.insert(req.0, ev.at);
                }
                EventKind::WireOut { req, shard } => {
                    ingress_shards.insert(shard);
                    let start = wire_in.remove(&req.0).unwrap_or(ev.at);
                    out.push(Json::obj(vec![
                        ("name", Json::str(format!("wire r{}", req.0))),
                        ("cat", Json::str("ingress")),
                        ("ph", Json::str("X")),
                        ("ts", Json::num(start as f64)),
                        ("dur", Json::num(ev.at.saturating_sub(start) as f64)),
                        ("pid", Json::num(1.0)),
                        ("tid", Json::num(INGRESS_TID_BASE + shard as f64)),
                    ]));
                }
                EventKind::Arrival { .. }
                | EventKind::Routed { .. }
                | EventKind::RouteDrop { .. }
                | EventKind::InBatch { .. }
                | EventKind::Wake
                | EventKind::Admitted { .. }
                | EventKind::Reap { .. } => {}
            }
        }
        for &shard in &ingress_shards {
            out.push(meta(INGRESS_TID_BASE + shard as f64, &format!("ingress s{shard}")));
        }
        Json::obj(vec![
            ("traceEvents", Json::arr(out)),
            ("displayTimeUnit", Json::str("ms")),
        ])
    }

    /// Windowed time-series + calibration stream, as written to
    /// `TELEMETRY_*.json`. Utilization attributes a batch's realized exec
    /// time to the window its completion lands in (documented
    /// approximation; windows are much wider than batches).
    pub fn time_series(&self) -> Json {
        #[derive(Default)]
        struct Win {
            arrivals: u64,
            routed: u64,
            admitted: u64,
            downgraded: u64,
            early_reject: u64,
            finished: u64,
            late: u64,
            shed: u64,
            batches: u64,
            batched_reqs: u64,
            busy_ms: f64,
            wire_in: u64,
            wire_out: u64,
            queue: BTreeMap<u32, u32>,
            backlog: BTreeMap<u32, u32>,
        }
        let w_us = self.cfg.window_us.max(1);
        let workers = self.worker_count();
        let mut wins: BTreeMap<u64, Win> = BTreeMap::new();
        for ev in self.events() {
            let win = wins.entry(ev.at / w_us).or_default();
            match ev.kind {
                EventKind::Arrival { .. } => win.arrivals += 1,
                EventKind::Routed { .. } => win.routed += 1,
                EventKind::Admitted { .. } => win.admitted += 1,
                EventKind::Downgraded { .. } => win.downgraded += 1,
                // EarlyReject is always paired with a Terminal{TimedOut}
                // for the same request — the Terminal feeds the shed rate,
                // this counter isolates the admission-side cause.
                EventKind::EarlyReject { .. } => win.early_reject += 1,
                // RouteDrop is always followed by a Terminal{TimedOut} for
                // the same request — only the Terminal feeds the shed rate.
                EventKind::BatchFormed { size, .. } => {
                    win.batches += 1;
                    win.batched_reqs += size as u64;
                }
                EventKind::BatchDone { batch_ms, .. } => win.busy_ms += batch_ms,
                EventKind::Terminal { outcome, .. } => match outcome {
                    Outcome::Finished => win.finished += 1,
                    Outcome::Late => win.late += 1,
                    Outcome::TimedOut | Outcome::Aborted => win.shed += 1,
                },
                EventKind::QueueSample { worker, pending } => {
                    win.queue.insert(worker, pending);
                }
                EventKind::ModelBacklog { model, pending } => {
                    win.backlog.insert(model.0, pending);
                }
                EventKind::WireIn { .. } => win.wire_in += 1,
                EventKind::WireOut { .. } => win.wire_out += 1,
                _ => {}
            }
        }
        let window_ms = us_to_ms(w_us);
        let rows = wins.into_iter().map(|(idx, w)| {
            let mean_batch = if w.batches > 0 {
                w.batched_reqs as f64 / w.batches as f64
            } else {
                0.0
            };
            let queue_depth: u64 = w.queue.values().map(|&v| v as u64).sum();
            let backlog = Json::Obj(
                w.backlog
                    .into_iter()
                    .map(|(m, n)| (format!("m{m}"), Json::num(n as f64)))
                    .collect(),
            );
            Json::obj(vec![
                ("t_ms", Json::num(idx as f64 * window_ms)),
                ("arrivals", Json::num(w.arrivals as f64)),
                ("routed", Json::num(w.routed as f64)),
                ("admitted", Json::num(w.admitted as f64)),
                ("downgraded", Json::num(w.downgraded as f64)),
                ("early_reject", Json::num(w.early_reject as f64)),
                ("finished", Json::num(w.finished as f64)),
                ("late", Json::num(w.late as f64)),
                ("shed", Json::num(w.shed as f64)),
                ("batches", Json::num(w.batches as f64)),
                ("mean_batch", Json::num(mean_batch)),
                ("busy_ms", Json::num(w.busy_ms)),
                (
                    "utilization",
                    Json::num(w.busy_ms / (window_ms * workers as f64)),
                ),
                ("wire_in", Json::num(w.wire_in as f64)),
                ("wire_out", Json::num(w.wire_out as f64)),
                ("queue_depth", Json::num(queue_depth as f64)),
                ("backlog", backlog),
            ])
        });
        let cal = Json::arr(self.calibration().iter().map(CalibrationRow::to_json));
        Json::obj(vec![
            ("window_ms", Json::num(window_ms)),
            ("workers", Json::num(workers as f64)),
            ("recorded", Json::num(self.recorded() as f64)),
            ("dropped_events", Json::num(self.dropped as f64)),
            ("windows", Json::arr(rows)),
            ("calibration", cal),
        ])
    }

    /// Every (prediction, realization) pair recoverable from the ring:
    /// a `BatchFormed` joined to its `BatchDone` by batch id.
    pub fn prediction_pairs(&self) -> Vec<PredictionPair> {
        let mut formed: BTreeMap<u32, PredictionPair> = BTreeMap::new();
        let mut out = Vec::new();
        for ev in self.events() {
            match ev.kind {
                EventKind::BatchFormed {
                    batch,
                    model,
                    app,
                    size,
                    predicted_ms,
                    lo_ms,
                    hi_ms,
                    ..
                } => {
                    formed.insert(
                        batch,
                        PredictionPair {
                            batch,
                            model,
                            app,
                            size,
                            predicted_ms,
                            lo_ms,
                            hi_ms,
                            realized_ms: 0.0,
                        },
                    );
                }
                EventKind::BatchDone {
                    batch, batch_ms, ..
                } => {
                    if let Some(mut p) = formed.remove(&batch) {
                        p.realized_ms = batch_ms;
                        out.push(p);
                    }
                }
                _ => {}
            }
        }
        out
    }

    /// Estimator calibration per (model, app): signed error quantiles of
    /// realized − predicted batch exec time, and how often the realized
    /// time fell inside the predicted [lo, hi] variance band.
    pub fn calibration(&self) -> Vec<CalibrationRow> {
        let mut classes: BTreeMap<(u32, u32), (Vec<f64>, usize)> = BTreeMap::new();
        for p in self.prediction_pairs() {
            let (errs, covered) = classes.entry((p.model.0, p.app.0)).or_default();
            errs.push(p.realized_ms - p.predicted_ms);
            if p.realized_ms >= p.lo_ms && p.realized_ms <= p.hi_ms {
                *covered += 1;
            }
        }
        classes
            .into_iter()
            .map(|((m, a), (errs, covered))| CalibrationRow {
                model: ModelId(m),
                app: AppId(a),
                n: errs.len(),
                mean_err_ms: stats::mean(&errs),
                p10_ms: stats::percentile(&errs, 10.0),
                p50_ms: stats::percentile(&errs, 50.0),
                p90_ms: stats::percentile(&errs, 90.0),
                coverage: covered as f64 / errs.len() as f64,
            })
            .collect()
    }
}

/// One `BatchFormed`/`BatchDone` join (see [`Recorder::prediction_pairs`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PredictionPair {
    pub batch: u32,
    pub model: ModelId,
    pub app: AppId,
    pub size: u32,
    pub predicted_ms: f64,
    pub lo_ms: f64,
    pub hi_ms: f64,
    pub realized_ms: f64,
}

/// Calibration summary for one (model, app) class.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CalibrationRow {
    pub model: ModelId,
    pub app: AppId,
    /// Completed batches contributing to the class.
    pub n: usize,
    /// Mean signed error (realized − predicted), ms.
    pub mean_err_ms: f64,
    pub p10_ms: f64,
    pub p50_ms: f64,
    pub p90_ms: f64,
    /// Fraction of realized times inside the predicted [lo, hi] band.
    pub coverage: f64,
}

impl CalibrationRow {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("model", Json::num(self.model.0 as f64)),
            ("app", Json::num(self.app.0 as f64)),
            ("n", Json::num(self.n as f64)),
            ("mean_err_ms", Json::num(self.mean_err_ms)),
            ("p10_ms", Json::num(self.p10_ms)),
            ("p50_ms", Json::num(self.p50_ms)),
            ("p90_ms", Json::num(self.p90_ms)),
            ("coverage", Json::num(self.coverage)),
        ])
    }
}

/// Render the calibration report as the fixed-width table shown in
/// `experiment` output. Empty string when there is nothing to report.
pub fn calibration_table(rows: &[CalibrationRow]) -> String {
    if rows.is_empty() {
        return String::new();
    }
    let mut s = String::from(
        "  model  app      n  mean_err    p10     p50     p90  coverage\n",
    );
    for r in rows {
        s.push_str(&format!(
            "  m{:<5} a{:<3} {:>5}  {:>+7.2} {:>+7.2} {:>+7.2} {:>+7.2}    {:>5.1}%\n",
            r.model.0,
            r.app.0,
            r.n,
            r.mean_err_ms,
            r.p10_ms,
            r.p50_ms,
            r.p90_ms,
            r.coverage * 100.0,
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn formed(batch: u32, worker: u32, pred: f64, lo: f64, hi: f64) -> EventKind {
        EventKind::BatchFormed {
            batch,
            worker,
            model: ModelId(0),
            app: AppId(0),
            size: 4,
            predicted_ms: pred,
            lo_ms: lo,
            hi_ms: hi,
        }
    }

    #[test]
    fn ring_drops_oldest_and_counts() {
        let mut r = Recorder::with_config(RecorderConfig {
            capacity: 4,
            window_us: 100_000,
        });
        for i in 0..10u64 {
            r.record(i, EventKind::Wake);
        }
        assert_eq!(r.recorded(), 4);
        assert_eq!(r.dropped_events(), 6);
        let ts: Vec<Micros> = r.events().map(|e| e.at).collect();
        assert_eq!(ts, vec![6, 7, 8, 9], "oldest events dropped first");
    }

    #[test]
    fn batch_ids_are_monotone_and_tracked_per_worker() {
        let mut r = Recorder::new();
        assert_eq!(r.last_batch_for(0), None);
        assert_eq!(r.begin_batch(0), 0);
        assert_eq!(r.begin_batch(2), 1);
        assert_eq!(r.begin_batch(0), 2);
        assert_eq!(r.last_batch_for(0), Some(2));
        assert_eq!(r.last_batch_for(1), None);
        assert_eq!(r.last_batch_for(2), Some(1));
    }

    #[test]
    fn sample_gate_fires_once_per_window() {
        let mut r = Recorder::with_config(RecorderConfig {
            capacity: 16,
            window_us: 1_000,
        });
        assert!(r.sample_due(0));
        assert!(!r.sample_due(999));
        assert!(r.sample_due(1_000));
        assert!(!r.sample_due(1_500));
        // A long idle gap skips straight to the current window.
        assert!(r.sample_due(10_500));
        assert!(!r.sample_due(10_900));
        assert!(r.sample_due(11_000));
    }

    #[test]
    fn calibration_joins_predictions_to_realizations() {
        let mut r = Recorder::new();
        r.record(0, formed(0, 0, 10.0, 8.0, 12.0));
        r.record(
            1_000,
            EventKind::BatchDone {
                batch: 0,
                worker: 0,
                batch_ms: 11.0,
            },
        );
        r.record(2_000, formed(1, 0, 10.0, 8.0, 12.0));
        r.record(
            3_000,
            EventKind::BatchDone {
                batch: 1,
                worker: 0,
                batch_ms: 15.0,
            },
        );
        // A formed-but-never-completed batch contributes nothing.
        r.record(4_000, formed(2, 0, 10.0, 8.0, 12.0));
        let pairs = r.prediction_pairs();
        assert_eq!(pairs.len(), 2);
        let cal = r.calibration();
        assert_eq!(cal.len(), 1);
        let row = &cal[0];
        assert_eq!(row.n, 2);
        assert!((row.mean_err_ms - 3.0).abs() < 1e-9, "errors +1 and +5");
        assert!((row.coverage - 0.5).abs() < 1e-9, "11 in band, 15 out");
        assert!(!calibration_table(&cal).is_empty());
    }

    #[test]
    fn chrome_trace_parses_and_has_tracks() {
        let mut r = Recorder::new();
        r.record(
            0,
            EventKind::Arrival {
                req: RequestId(1),
                model: ModelId(0),
                app: AppId(0),
            },
        );
        let b = r.begin_batch(1);
        r.record(10, formed(b, 1, 5.0, 4.0, 6.0));
        r.record(
            20,
            EventKind::ExecStart { batch: b, worker: 1 },
        );
        r.record(
            5_020,
            EventKind::BatchDone {
                batch: b,
                worker: 1,
                batch_ms: 5.0,
            },
        );
        r.record(
            5_020,
            EventKind::Terminal {
                req: RequestId(1),
                outcome: Outcome::Finished,
                worker: Some(1),
            },
        );
        r.record(
            6_000,
            EventKind::ModelBacklog {
                model: ModelId(0),
                pending: 3,
            },
        );
        let json = r.chrome_trace().to_string();
        let parsed = Json::parse(&json).expect("chrome trace must be valid JSON");
        let evs = parsed.get("traceEvents").as_arr().expect("traceEvents");
        assert!(!evs.is_empty());
        // One exec span with the prediction attached.
        let exec: Vec<&Json> = evs
            .iter()
            .filter(|e| e.get("cat").as_str() == Some("exec"))
            .collect();
        assert_eq!(exec.len(), 1);
        assert_eq!(exec[0].get("ts").as_u64(), Some(20));
        assert_eq!(
            exec[0].get("args").get("predicted_ms").as_f64(),
            Some(5.0)
        );
        // Counter track for the model queue.
        assert!(evs
            .iter()
            .any(|e| e.get("ph").as_str() == Some("C")
                && e.get("name").as_str() == Some("backlog m0")));
    }

    #[test]
    fn time_series_buckets_by_window() {
        let mut r = Recorder::with_config(RecorderConfig {
            capacity: 64,
            window_us: 1_000,
        });
        for i in 0..3u64 {
            r.record(
                i * 100,
                EventKind::Arrival {
                    req: RequestId(i),
                    model: ModelId(0),
                    app: AppId(0),
                },
            );
        }
        r.record(
            1_500,
            EventKind::Terminal {
                req: RequestId(0),
                outcome: Outcome::Finished,
                worker: Some(0),
            },
        );
        r.record(
            1_600,
            EventKind::Terminal {
                req: RequestId(1),
                outcome: Outcome::TimedOut,
                worker: None,
            },
        );
        let ts = r.time_series();
        let wins = ts.get("windows").as_arr().expect("windows");
        assert_eq!(wins.len(), 2);
        assert_eq!(wins[0].get("arrivals").as_u64(), Some(3));
        assert_eq!(wins[1].get("finished").as_u64(), Some(1));
        assert_eq!(wins[1].get("shed").as_u64(), Some(1));
        assert_eq!(ts.get("dropped_events").as_u64(), Some(0));
    }
}
