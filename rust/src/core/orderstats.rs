//! Max order statistics of execution-time distributions (paper §4.2).
//!
//! Batching means all requests in a batch finish together, so the batch's
//! effective per-request length is `max_{r∈B} l_r` (Eq. 4). This module
//! computes the distribution of that max:
//!
//! * **iid case** (Eq. 6): `F_(k)(l) = F(l)^k` — all k requests share one
//!   distribution (e.g. the model-wide mixture of §4.3).
//! * **non-iid case** (Eq. 8, Özbey et al.): the polarization-identity
//!   expansion over subsets `s ⊆ B` with averaged CDFs
//!   `F^s = (1/n_s) Σ_{i∈s} F_i`:
//!
//!   `f_(k) = Σ_{κ=1..k} (-1)^{k-κ} (κ^k / k!) Σ_{n_s=κ} k [F^s]^{k-1} f^s`
//!
//!   We implement Eq. 8 faithfully *and* the direct product rule
//!   `f_max = Σ_i f_i Π_{j≠i} F_j` (mathematically identical, O(k²·bins)
//!   instead of O(2^k·bins)); tests assert they agree and the scheduler
//!   uses the direct form on larger batches.
//!
//! All computation is bin-wise on a shared uniform grid, producing the
//! quantities Eq. (5) needs: `E[max]` and the max's histogram.

use super::histogram::Histogram;

/// Distribution of `max` of k iid draws from `h` (Eq. 6).
///
/// Bin masses of the max: `F(e_{i+1})^k − F(e_i)^k` using exact edge CDFs.
pub fn max_iid(h: &Histogram, k: usize) -> Histogram {
    assert!(k >= 1);
    if k == 1 {
        return h.clone();
    }
    let n = h.num_bins();
    let mut weights = vec![0.0; n];
    let mut prev = 0.0f64; // F(lo)^k = 0
    let mut cum = 0.0f64;
    for i in 0..n {
        cum += h.masses()[i];
        let cur = cum.min(1.0).powi(k as i32);
        weights[i] = (cur - prev).max(0.0);
        prev = cur;
    }
    Histogram::from_weights(h.lo(), h.bin_width(), &weights)
}

/// Re-bin a set of histograms onto one common uniform grid so bin-wise
/// arithmetic is valid. Returns (lo, width, masses-per-input).
fn common_grid(hs: &[&Histogram], bins: usize) -> (f64, f64, Vec<Vec<f64>>) {
    let lo = hs.iter().map(|h| h.lo()).fold(f64::INFINITY, f64::min);
    let hi = hs.iter().map(|h| h.hi()).fold(f64::NEG_INFINITY, f64::max);
    let width = ((hi - lo) / bins as f64).max(1e-12);
    let grids = hs
        .iter()
        .map(|h| {
            let mut w = vec![0.0; bins];
            for i in 0..h.num_bins() {
                let (a, b, m) = h.bin(i);
                if m == 0.0 {
                    continue;
                }
                let t0 = ((a - lo) / width).max(0.0);
                let t1 = ((b - lo) / width).min(bins as f64);
                let i0 = t0 as usize;
                let i1 = (t1.ceil() as usize).min(bins);
                for j in i0..i1.max(i0 + 1).min(bins) {
                    let seg_lo = (j as f64).max(t0);
                    let seg_hi = ((j + 1) as f64).min(t1);
                    let overlap = ((seg_hi - seg_lo) / (t1 - t0).max(1e-12)).max(0.0);
                    w[j] += m * overlap;
                }
            }
            w
        })
        .collect();
    (lo, width, grids)
}

/// Direct product rule for the max of independent, non-identically
/// distributed variables: mass of max in bin i =
/// `Π_j F_j(e_{i+1}) − Π_j F_j(e_i)`.
pub fn max_inid_direct(hs: &[&Histogram], bins: usize) -> Histogram {
    assert!(!hs.is_empty());
    if hs.len() == 1 {
        return hs[0].clone();
    }
    let (lo, width, grids) = common_grid(hs, bins);
    let k = grids.len();
    // Edge CDFs per distribution.
    let mut cdfs: Vec<Vec<f64>> = Vec::with_capacity(k);
    for g in &grids {
        let mut c = Vec::with_capacity(bins + 1);
        c.push(0.0);
        let mut acc = 0.0;
        for m in g {
            acc += m;
            c.push(acc.min(1.0));
        }
        cdfs.push(c);
    }
    let mut weights = vec![0.0; bins];
    let mut prev = 0.0;
    for i in 0..bins {
        let mut prod = 1.0;
        for c in &cdfs {
            prod *= c[i + 1];
        }
        weights[i] = (prod - prev).max(0.0);
        prev = prod;
    }
    Histogram::from_weights(lo, width, &weights)
}

/// Eq. (8) of the paper (Özbey et al.): polarization-identity expansion of
/// the max PDF over subsets of B. Exponential in k — kept for fidelity and
/// as the differential-testing oracle for `max_inid_direct`. Panics for
/// k > 20 (subset enumeration would be unreasonable).
pub fn max_inid_ozbey(hs: &[&Histogram], bins: usize) -> Histogram {
    let k = hs.len();
    assert!(k >= 1 && k <= 20, "Eq. 8 enumeration limited to k<=20");
    if k == 1 {
        return hs[0].clone();
    }
    let (lo, width, grids) = common_grid(hs, bins);
    // Edge CDFs per distribution (same convention as direct form).
    let cdfs: Vec<Vec<f64>> = grids
        .iter()
        .map(|g| {
            let mut c = Vec::with_capacity(bins + 1);
            c.push(0.0);
            let mut acc = 0.0;
            for m in g {
                acc += m;
                c.push(acc.min(1.0));
            }
            c
        })
        .collect();

    // k! as f64 (k <= 20 so exact in f64 up to 2^63 > 20!).
    let kfact: f64 = (1..=k as u64).map(|x| x as f64).product();

    // Accumulate the signed subset contributions on the *CDF of the max*:
    // F_max = Σ_κ (-1)^{k-κ} (κ^k / k!) Σ_{|s|=κ} [F^s]^k
    // then take per-bin differences (equivalent to integrating Eq. 8's pdf
    // over each bin, but exact on the grid).
    let mut f_max_edges = vec![0.0f64; bins + 1];
    for mask in 1u32..(1u32 << k) {
        let ns = mask.count_ones() as usize;
        let sign = if (k - ns) % 2 == 0 { 1.0 } else { -1.0 };
        let coeff = sign * (ns as f64).powi(k as i32) / kfact;
        for e in 0..=bins {
            let mut fsum = 0.0;
            for (j, c) in cdfs.iter().enumerate() {
                if mask & (1 << j) != 0 {
                    fsum += c[e];
                }
            }
            let favg = fsum / ns as f64;
            f_max_edges[e] += coeff * favg.powi(k as i32);
        }
    }
    let mut weights = vec![0.0; bins];
    for i in 0..bins {
        weights[i] = (f_max_edges[i + 1] - f_max_edges[i]).max(0.0);
    }
    Histogram::from_weights(lo, width, &weights)
}

/// Max of a batch drawn as: `counts[j]` iid draws from `hs[j]` for each j.
/// This is the form the estimator actually needs (k requests, few distinct
/// app distributions): `F_max = Π_j F_j^{counts[j]}`.
pub fn max_grouped(hs: &[&Histogram], counts: &[usize], bins: usize) -> Histogram {
    assert_eq!(hs.len(), counts.len());
    assert!(counts.iter().all(|&c| c > 0));
    let (lo, width, grids) = common_grid(hs, bins);
    let mut cdf_edges: Vec<Vec<f64>> = Vec::with_capacity(grids.len());
    for g in &grids {
        let mut c = Vec::with_capacity(bins + 1);
        c.push(0.0);
        let mut acc = 0.0;
        for m in g {
            acc += m;
            c.push(acc.min(1.0));
        }
        cdf_edges.push(c);
    }
    let mut weights = vec![0.0; bins];
    let mut prev = 0.0;
    for i in 0..bins {
        let mut prod = 1.0;
        for (j, c) in cdf_edges.iter().enumerate() {
            prod *= c[i + 1].powi(counts[j] as i32);
        }
        weights[i] = (prod - prev).max(0.0);
        prev = prod;
    }
    Histogram::from_weights(lo, width, &weights)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn max_iid_k1_is_identity() {
        let h = Histogram::from_weights(0.0, 1.0, &[1.0, 2.0, 1.0]);
        assert_eq!(max_iid(&h, 1), h);
    }

    #[test]
    fn max_iid_shifts_right() {
        let h = Histogram::from_weights(0.0, 1.0, &[1.0, 1.0, 1.0, 1.0]);
        let m2 = max_iid(&h, 2);
        let m8 = max_iid(&h, 8);
        assert!(m2.mean() > h.mean());
        assert!(m8.mean() > m2.mean());
        assert!(m8.mean() < h.hi());
        assert!(m2.is_normalized() && m8.is_normalized());
    }

    #[test]
    fn max_iid_matches_monte_carlo() {
        let mut rng = Rng::new(42);
        let samples: Vec<f64> = (0..40_000).map(|_| rng.lognormal(2.0, 0.8)).collect();
        let h = Histogram::from_samples(&samples, 250);
        for k in [2usize, 4, 8] {
            let analytic = max_iid(&h, k).mean();
            // Monte Carlo from the same histogram (sample via quantile).
            let mc: f64 = (0..20_000)
                .map(|_| {
                    (0..k)
                        .map(|_| h.quantile(rng.f64()))
                        .fold(f64::NEG_INFINITY, f64::max)
                })
                .sum::<f64>()
                / 20_000.0;
            assert!(
                close(analytic, mc, 0.02),
                "k={k} analytic={analytic} mc={mc}"
            );
        }
    }

    #[test]
    fn direct_equals_ozbey_two_distributions() {
        let a = Histogram::from_weights(0.0, 1.0, &[3.0, 1.0]);
        let b = Histogram::from_weights(1.0, 1.0, &[1.0, 1.0, 2.0]);
        let d = max_inid_direct(&[&a, &b], 64);
        let o = max_inid_ozbey(&[&a, &b], 64);
        for i in 0..64 {
            assert!(
                (d.masses()[i] - o.masses()[i]).abs() < 1e-9,
                "bin {i}: {} vs {}",
                d.masses()[i],
                o.masses()[i]
            );
        }
    }

    #[test]
    fn direct_equals_ozbey_random_mix() {
        let mut rng = Rng::new(7);
        for trial in 0..10 {
            let k = 2 + (trial % 3); // 2..4 distributions
            let hs: Vec<Histogram> = (0..k)
                .map(|_| {
                    let w: Vec<f64> = (0..6).map(|_| rng.f64() + 0.01).collect();
                    Histogram::from_weights(rng.f64() * 5.0, 0.5 + rng.f64(), &w)
                })
                .collect();
            let refs: Vec<&Histogram> = hs.iter().collect();
            let d = max_inid_direct(&refs, 96);
            let o = max_inid_ozbey(&refs, 96);
            for i in 0..96 {
                assert!(
                    (d.masses()[i] - o.masses()[i]).abs() < 1e-8,
                    "trial {trial} bin {i}"
                );
            }
            assert!(close(d.mean(), o.mean(), 1e-9));
        }
    }

    #[test]
    fn inid_reduces_to_iid_when_same() {
        let h = Histogram::from_weights(0.0, 0.5, &[1.0, 2.0, 3.0, 2.0]);
        let via_iid = max_iid(&h, 3);
        let via_inid = max_inid_direct(&[&h, &h, &h], h.num_bins());
        assert!(close(via_iid.mean(), via_inid.mean(), 1e-6));
    }

    #[test]
    fn grouped_equals_direct() {
        let a = Histogram::from_weights(0.0, 1.0, &[1.0, 1.0]);
        let b = Histogram::from_weights(0.5, 1.0, &[1.0, 3.0]);
        let g = max_grouped(&[&a, &b], &[2, 1], 64);
        let d = max_inid_direct(&[&a, &a, &b], 64);
        assert!(close(g.mean(), d.mean(), 1e-6), "{} vs {}", g.mean(), d.mean());
    }

    #[test]
    fn grouped_matches_monte_carlo() {
        let mut rng = Rng::new(99);
        let a = Histogram::from_samples(
            &(0..20_000).map(|_| rng.lognormal(1.0, 0.4)).collect::<Vec<_>>(),
            150,
        );
        let b = Histogram::from_samples(
            &(0..20_000).map(|_| rng.lognormal(2.0, 0.6)).collect::<Vec<_>>(),
            150,
        );
        let g = max_grouped(&[&a, &b], &[3, 2], 300);
        let mc: f64 = (0..20_000)
            .map(|_| {
                let ma = (0..3)
                    .map(|_| a.quantile(rng.f64()))
                    .fold(f64::NEG_INFINITY, f64::max);
                let mb = (0..2)
                    .map(|_| b.quantile(rng.f64()))
                    .fold(f64::NEG_INFINITY, f64::max);
                ma.max(mb)
            })
            .sum::<f64>()
            / 20_000.0;
        assert!(close(g.mean(), mc, 0.02), "analytic={} mc={}", g.mean(), mc);
    }

    #[test]
    fn toy_example_fig6_shape() {
        // Paper Fig. 6: dist 1 concentrated at mean l; dist 2 bimodal
        // (very early or very late), same mean. Batch-of-2 max skews right.
        let d1 = Histogram::from_weights(4.0, 1.0, &[0.05, 0.9, 0.05]); // ~5
        let d2 = Histogram::from_weights(1.0, 1.0, &[0.5, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.5]); // 1.5 or 8.5
        let batch = max_inid_direct(&[&d1, &d2], 64);
        assert!(batch.mean() > d1.mean());
        assert!(batch.mean() > d2.mean());
        // Short mode of d2 can never be the batch max: no mass below d1's lo.
        assert!(batch.cdf(3.9) < 1e-9, "cdf(3.9)={}", batch.cdf(3.9));
    }
}
