//! Empirical execution-time distributions (paper §3.2, §4.1).
//!
//! Orloj "does not assume any pre-defined distribution for its input and
//! only tracks empirical distributions": a fixed-width histogram over
//! milliseconds. This module provides the distribution algebra the
//! scheduler needs — pdf/cdf, mean, quantiles, mixtures, affine scaling —
//! and is the representation on which the order-statistics and priority
//! math (Eq. 2, 5–9) operates bin-by-bin.

/// An empirical distribution over execution time in milliseconds,
/// represented as a normalized histogram with uniform bin width.
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    /// Left edge of the first bin (ms).
    lo: f64,
    /// Bin width (ms), > 0.
    width: f64,
    /// Normalized bin masses; sum == 1 (unless empty).
    mass: Vec<f64>,
}

impl Histogram {
    /// Build from bin range and *unnormalized* weights.
    pub fn from_weights(lo: f64, width: f64, weights: &[f64]) -> Histogram {
        assert!(width > 0.0, "bin width must be positive");
        assert!(!weights.is_empty(), "histogram needs at least one bin");
        let total: f64 = weights.iter().sum();
        let mass = if total > 0.0 {
            weights.iter().map(|w| w / total).collect()
        } else {
            vec![0.0; weights.len()]
        };
        Histogram { lo, width, mass }
    }

    /// Build from raw samples with `bins` uniform bins spanning the sample
    /// range (slightly widened so the max lands inside the last bin).
    pub fn from_samples(samples: &[f64], bins: usize) -> Histogram {
        assert!(!samples.is_empty(), "cannot build histogram from no samples");
        assert!(bins > 0);
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for &s in samples {
            lo = lo.min(s);
            hi = hi.max(s);
        }
        if hi <= lo {
            // Degenerate: all samples equal. One spike bin of small width.
            let width = (lo.abs() * 1e-3).max(1e-6);
            return Histogram {
                lo,
                width,
                mass: vec![1.0],
            };
        }
        let span = (hi - lo) * 1.0000001; // ensure max falls inside
        let width = span / bins as f64;
        let mut weights = vec![0.0; bins];
        for &s in samples {
            let idx = (((s - lo) / width) as usize).min(bins - 1);
            weights[idx] += 1.0;
        }
        Histogram::from_weights(lo, width, &weights)
    }

    /// A distribution with all mass at `value` (static-DNN case: constant
    /// execution time). Width is kept tiny so E and quantiles are exact to
    /// within a microsecond.
    pub fn constant(value: f64) -> Histogram {
        Histogram {
            lo: value,
            width: (value.abs() * 1e-4).max(1e-4),
            mass: vec![1.0],
        }
    }

    pub fn num_bins(&self) -> usize {
        self.mass.len()
    }

    pub fn bin_width(&self) -> f64 {
        self.width
    }

    pub fn lo(&self) -> f64 {
        self.lo
    }

    pub fn hi(&self) -> f64 {
        self.lo + self.width * self.mass.len() as f64
    }

    /// Left edge of bin `i`.
    #[inline]
    pub fn edge(&self, i: usize) -> f64 {
        self.lo + self.width * i as f64
    }

    /// (l1, l2, h): bin range and mass — the quantities Eq. (2) consumes.
    #[inline]
    pub fn bin(&self, i: usize) -> (f64, f64, f64) {
        (self.edge(i), self.edge(i + 1), self.mass[i])
    }

    pub fn masses(&self) -> &[f64] {
        &self.mass
    }

    /// Midpoint-rule expectation.
    pub fn mean(&self) -> f64 {
        self.mass
            .iter()
            .enumerate()
            .map(|(i, m)| m * (self.edge(i) + 0.5 * self.width))
            .sum()
    }

    /// Variance (midpoint rule).
    pub fn variance(&self) -> f64 {
        let mu = self.mean();
        self.mass
            .iter()
            .enumerate()
            .map(|(i, m)| {
                let x = self.edge(i) + 0.5 * self.width;
                m * (x - mu) * (x - mu)
            })
            .sum()
    }

    /// CDF evaluated at `x`, linearly interpolated within bins.
    pub fn cdf(&self, x: f64) -> f64 {
        if x <= self.lo {
            return 0.0;
        }
        if x >= self.hi() {
            return 1.0;
        }
        let pos = (x - self.lo) / self.width;
        let idx = pos as usize;
        let frac = pos - idx as f64;
        let below: f64 = self.mass[..idx].iter().sum();
        below + self.mass[idx] * frac
    }

    /// CDF at the right edge of bin `i` (exact, no interpolation).
    pub fn cdf_at_edge(&self, i: usize) -> f64 {
        self.mass[..=i.min(self.mass.len() - 1)].iter().sum()
    }

    /// Quantile (inverse CDF), q in [0,1]; linear interpolation.
    pub fn quantile(&self, q: f64) -> f64 {
        let q = q.clamp(0.0, 1.0);
        let mut acc = 0.0;
        for (i, m) in self.mass.iter().enumerate() {
            if acc + m >= q {
                let frac = if *m > 0.0 { (q - acc) / m } else { 0.0 };
                return self.edge(i) + frac * self.width;
            }
            acc += m;
        }
        self.hi()
    }

    /// P99 in ms — the paper's SLO reference point (§5.2 Metrics).
    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Mixture of distributions with the given unnormalized weights,
    /// re-binned onto a common uniform grid of `bins` bins. Used for the
    /// model-wide "all applications" distribution of §4.3.
    pub fn mixture(parts: &[(&Histogram, f64)], bins: usize) -> Histogram {
        assert!(!parts.is_empty());
        let wsum: f64 = parts.iter().map(|(_, w)| *w).sum();
        assert!(wsum > 0.0, "mixture weights must be positive");
        let lo = parts.iter().map(|(h, _)| h.lo()).fold(f64::INFINITY, f64::min);
        let hi = parts
            .iter()
            .map(|(h, _)| h.hi())
            .fold(f64::NEG_INFINITY, f64::max);
        let width = ((hi - lo) / bins as f64).max(1e-9);
        let mut weights = vec![0.0; bins];
        for (h, w) in parts {
            let scale = w / wsum;
            for i in 0..h.num_bins() {
                // Spread bin mass across overlapping target bins.
                let (a, b, m) = h.bin(i);
                if m == 0.0 {
                    continue;
                }
                let t0 = ((a - lo) / width).max(0.0);
                let t1 = ((b - lo) / width).min(bins as f64);
                let i0 = t0 as usize;
                let i1 = (t1.ceil() as usize).min(bins);
                for j in i0..i1.max(i0 + 1).min(bins) {
                    let seg_lo = (j as f64).max(t0);
                    let seg_hi = ((j + 1) as f64).min(t1);
                    let overlap = ((seg_hi - seg_lo) / (t1 - t0).max(1e-12)).max(0.0);
                    weights[j] += scale * m * overlap;
                }
            }
        }
        Histogram::from_weights(lo, width, &weights)
    }

    /// Re-bin to `bins` uniform bins over the same support (coarsening for
    /// the priority-score schedules: fewer bins → fewer milestones → less
    /// hull churn, §Perf).
    pub fn coarsen(&self, bins: usize) -> Histogram {
        assert!(bins > 0);
        if bins >= self.num_bins() {
            return self.clone();
        }
        let width = (self.hi() - self.lo()) / bins as f64;
        let mut weights = vec![0.0; bins];
        for i in 0..self.num_bins() {
            let (a, b, m) = self.bin(i);
            if m == 0.0 {
                continue;
            }
            let t0 = (a - self.lo()) / width;
            let t1 = ((b - self.lo()) / width).min(bins as f64);
            let i0 = t0 as usize;
            let i1 = (t1.ceil() as usize).min(bins);
            for j in i0..i1.max(i0 + 1).min(bins) {
                let seg_lo = (j as f64).max(t0);
                let seg_hi = ((j + 1) as f64).min(t1);
                let overlap = ((seg_hi - seg_lo) / (t1 - t0).max(1e-12)).max(0.0);
                weights[j] += m * overlap;
            }
        }
        Histogram::from_weights(self.lo(), width, &weights)
    }

    /// Affine map of the random variable: Y = a·X + b (a > 0). Used by the
    /// batch cost model (Eq. 9): L_B = c0 + c1·k·max.
    pub fn affine(&self, a: f64, b: f64) -> Histogram {
        assert!(a > 0.0, "affine scale must be positive");
        Histogram {
            lo: a * self.lo + b,
            width: a * self.width,
            mass: self.mass.clone(),
        }
    }

    /// Multiply all x-coordinates by `s` (> 0) — used by the Fig. 14
    /// overhead sweep ("scale the whole execution time distribution down").
    pub fn scaled(&self, s: f64) -> Histogram {
        self.affine(s, 0.0)
    }

    /// Check total mass ≈ 1.
    pub fn is_normalized(&self) -> bool {
        (self.mass.iter().sum::<f64>() - 1.0).abs() < 1e-9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn from_weights_normalizes() {
        let h = Histogram::from_weights(0.0, 1.0, &[1.0, 3.0]);
        assert_eq!(h.masses(), &[0.25, 0.75]);
        assert!(h.is_normalized());
    }

    #[test]
    fn from_samples_covers_range() {
        let samples = [1.0, 2.0, 3.0, 4.0, 5.0];
        let h = Histogram::from_samples(&samples, 4);
        assert!(h.lo() <= 1.0 && h.hi() >= 5.0);
        assert!(h.is_normalized());
        assert!((h.mean() - 3.0).abs() < 0.6); // midpoint-rule tolerance
    }

    #[test]
    fn degenerate_samples() {
        let h = Histogram::from_samples(&[7.0, 7.0, 7.0], 10);
        assert_eq!(h.num_bins(), 1);
        assert!((h.mean() - 7.0).abs() < 0.01);
    }

    #[test]
    fn constant_histogram() {
        let h = Histogram::constant(5.0);
        assert!((h.mean() - 5.0).abs() < 1e-3);
        assert!((h.quantile(0.99) - 5.0).abs() < 1e-3);
    }

    #[test]
    fn cdf_properties() {
        let h = Histogram::from_weights(0.0, 1.0, &[1.0, 1.0, 2.0]);
        assert_eq!(h.cdf(-1.0), 0.0);
        assert_eq!(h.cdf(10.0), 1.0);
        assert!((h.cdf(1.0) - 0.25).abs() < 1e-12);
        assert!((h.cdf(2.0) - 0.5).abs() < 1e-12);
        assert!((h.cdf(1.5) - 0.375).abs() < 1e-12);
        assert!((h.cdf_at_edge(1) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn quantile_inverts_cdf() {
        let h = Histogram::from_weights(0.0, 2.0, &[1.0, 2.0, 1.0]);
        for q in [0.1, 0.25, 0.5, 0.75, 0.9, 0.99] {
            let x = h.quantile(q);
            assert!((h.cdf(x) - q).abs() < 1e-9, "q={q} x={x} cdf={}", h.cdf(x));
        }
    }

    #[test]
    fn mean_matches_sample_mean() {
        let mut rng = Rng::new(5);
        let samples: Vec<f64> = (0..50_000).map(|_| rng.lognormal(3.0, 0.5)).collect();
        let h = Histogram::from_samples(&samples, 200);
        let sm = samples.iter().sum::<f64>() / samples.len() as f64;
        assert!(
            (h.mean() - sm).abs() / sm < 0.01,
            "hist={} sample={}",
            h.mean(),
            sm
        );
    }

    #[test]
    fn mixture_mass_and_mean() {
        let a = Histogram::from_weights(0.0, 1.0, &[1.0]); // U-ish on [0,1]
        let b = Histogram::from_weights(10.0, 1.0, &[1.0]); // on [10,11]
        let m = Histogram::mixture(&[(&a, 1.0), (&b, 1.0)], 22);
        assert!(m.is_normalized());
        // mean = (0.5 + 10.5)/2
        assert!((m.mean() - 5.5).abs() < 0.3, "mean={}", m.mean());
        // bimodal: mass near 0 and near 10, nothing in the middle
        assert!(m.cdf(5.0) > 0.49 && m.cdf(5.0) < 0.51);
    }

    #[test]
    fn mixture_weighted() {
        let a = Histogram::constant(1.0);
        let b = Histogram::constant(3.0);
        let m = Histogram::mixture(&[(&a, 3.0), (&b, 1.0)], 50);
        assert!((m.mean() - 1.5).abs() < 0.1, "mean={}", m.mean());
    }

    #[test]
    fn affine_map() {
        let h = Histogram::from_weights(1.0, 1.0, &[1.0, 1.0]);
        let g = h.affine(2.0, 3.0); // y = 2x+3, x in [1,3] -> y in [5,9]
        assert!((g.lo() - 5.0).abs() < 1e-12);
        assert!((g.hi() - 9.0).abs() < 1e-12);
        assert!((g.mean() - (2.0 * h.mean() + 3.0)).abs() < 1e-9);
    }

    #[test]
    fn scaled_p99() {
        let mut rng = Rng::new(6);
        let samples: Vec<f64> = (0..20_000).map(|_| rng.lognormal(2.0, 1.0)).collect();
        let h = Histogram::from_samples(&samples, 300);
        let s = h.scaled(0.1);
        assert!((s.p99() - 0.1 * h.p99()).abs() < 1e-9);
    }
}
