//! Time-varying priority score (paper Eq. 1–2, §4.4).
//!
//! For a request with deadline `D`, miss penalty `c`, and batch execution
//! time `L` described by a histogram with bins `[l1_i, l2_i)` of mass `h_i`,
//! the Shepherd-style score is `p(t) = Σ_i p_i(t)` with
//!
//! ```text
//! p_i(t) = (h_i c / (E[L] b)) (e^{b l2_i} − e^{b l1_i}) e^{−bD} e^{bt}   t < D−l2_i
//!        = h_i c/(E[L] b) − (h_i c/(E[L] b)) e^{b l1_i} e^{−bD} e^{bt}   D−l2_i ≤ t < D−l1_i
//!        = 0                                                             D−l1_i ≤ t
//! ```
//!
//! **Normalization note.** The paper's Eq. (2) writes the bin weight as the
//! raw frequency `h`, which is only dimensionally consistent when every
//! histogram in the system shares one bin width. Deriving Eq. (1) directly
//! (`E[C_delay]−E[C_now] = c·∫_{l≤D−t} f_L(l) e^{−b(D−t−l)} dl` with the
//! bin's density `h/(l2−l1)`) yields the same three regimes with `h`
//! replaced by `h/(l2−l1)`; this also makes the score converge to the
//! correct point-mass limit `(c/E[L]) e^{b l} e^{−bD} e^{bt}` as the bin
//! narrows. We implement the density-normalized form.
//!
//! Between *milestones* (the times `D−l2_i`, `D−l1_i` where a bin changes
//! regime) the score is exactly `p(t) = α·e^{bt} + β` with constant (α, β)
//! — the 2-D point the dynamic convex hull stores (§4.4). This module
//! computes the per-request (α, β) pair, its milestone schedule, and the
//! relative-timestamp bookkeeping that avoids `e^{bt}` overflow: all times
//! entering the exponentials are milliseconds relative to a shared
//! [`ScoreContext`] base that the scheduler resets periodically
//! (Algorithm 1 lines 2–4).
//!
//! **Templates (§Perf).** Every term of Eq. 2 depends on the deadline `D`
//! only through (a) a uniform factor `e^{−bD}` on the α coefficients and
//! (b) a uniform shift `D` of the milestone times; the miss penalty `c`
//! scales both α and β uniformly. A [`ScoreTemplate`] therefore bakes all
//! of the per-bin exponential math (the expensive part) once per
//! `(model, app, batch-size)` latency distribution — the estimator owns it,
//! shared via `Arc` — and [`ScoreSchedule::instantiate`] produces a
//! request's [`ScoreSchedule`] with two multiplies and one `exp`, no
//! per-bin work and no allocation. That is what makes `on_arrival` and the
//! Algorithm-1 base-time reset O(segments) instead of O(bins·exp).

use super::histogram::Histogram;
use crate::clock::{us_to_ms, Micros};
use std::sync::Arc;

/// Shared scoring parameters: `b` (1/ms) of the anticipated-delay
/// exponential, and the current base time for relative timestamps.
#[derive(Debug, Clone, Copy)]
pub struct ScoreContext {
    /// Anticipated-delay distribution parameter (paper: 1e-4 per ms).
    pub b: f64,
    /// Base timestamp; all exponentials see `t − base`.
    pub base: Micros,
}

/// When `b · (t − base)` exceeds this, the scheduler must reset the base
/// and recompute scores. e^40 ≈ 2.4e17 leaves ample headroom below f64
/// overflow (e^709) while keeping e^{−bD} comfortably above underflow.
pub const RESET_THRESHOLD: f64 = 40.0;

impl ScoreContext {
    pub fn new(b: f64) -> Self {
        assert!(b > 0.0);
        ScoreContext { b, base: 0 }
    }

    /// Relative milliseconds for a timestamp.
    #[inline]
    pub fn rel_ms(&self, t: Micros) -> f64 {
        us_to_ms(t.saturating_sub(self.base)) - us_to_ms(self.base.saturating_sub(t))
    }

    /// The query multiplier `e^{bt}` for the hull.
    #[inline]
    pub fn multiplier(&self, t: Micros) -> f64 {
        (self.b * self.rel_ms(t)).exp()
    }

    /// Does scoring need a base reset at time `t`? (paper §4.4: "about
    /// 1000 s of scheduling before ... having to reset the relative
    /// timestamps' reference point")
    pub fn needs_reset(&self, t: Micros) -> bool {
        self.b * self.rel_ms(t) > RESET_THRESHOLD
    }

    /// Reset the base to `t`. Existing scores must be recomputed.
    pub fn reset(&mut self, t: Micros) {
        self.base = t;
    }
}

/// Piecewise-constant (α, β) pair for `p(t) = α e^{bt} + β`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Coeffs {
    pub alpha: f64,
    pub beta: f64,
}

impl Coeffs {
    pub const ZERO: Coeffs = Coeffs {
        alpha: 0.0,
        beta: 0.0,
    };

    /// Evaluate the score given the precomputed multiplier `e^{bt}`.
    #[inline]
    pub fn eval(&self, multiplier: f64) -> f64 {
        self.alpha * multiplier + self.beta
    }
}

/// Deadline-independent score schedule of one latency distribution: (α, β)
/// segments separated by *deadline-relative* milestone offsets (offset 0 is
/// the deadline itself, so every offset is ≤ 0). Built once per
/// `(model, app, batch-size)` by the estimator and shared via `Arc`; a
/// request's concrete [`ScoreSchedule`] is an O(1) instantiation.
///
/// The template stores the unit form (`c = 1`, `D = base`): instantiating
/// at deadline `D` with penalty `c` scales every α by `c·e^{−bD}`, every β
/// by `c`, and shifts every milestone by `D` (all relative ms).
#[derive(Debug, Clone)]
pub struct ScoreTemplate {
    /// Score parameter `b` (1/ms) the per-bin exponentials were baked
    /// with; instantiation must use a [`ScoreContext`] with the same `b`.
    b: f64,
    /// Segment boundaries as deadline-relative ms offsets, strictly
    /// increasing. Segment `i` covers `[offsets[i-1], offsets[i])`
    /// (segment 0 starts at −∞); after the last offset the score is
    /// identically 0.
    offsets: Vec<f64>,
    /// `coeffs[i]` applies to segment `i` (len == offsets.len() + 1; the
    /// final entry is always ZERO).
    coeffs: Vec<Coeffs>,
}

impl ScoreTemplate {
    /// Precompute the unit schedule of latency distribution `l_b` under
    /// score parameter `b` (the expensive per-bin exponential math; §4.3
    /// off-critical-path work).
    pub fn new(b: f64, l_b: &Histogram) -> ScoreTemplate {
        assert!(b > 0.0);
        let e_l = l_b.mean().max(1e-9);
        let scale = 1.0 / (e_l * b);

        // Histogram bins are contiguous with uniform width (`l1_i =
        // edge_i`, `l2_i = edge_{i+1}`), so as t advances exactly one bin
        // occupies regime B at a time: for t ∈ [D−edge_{j+1}, D−edge_j),
        // bins 0..j are in regime A, bin j is in B, the rest in C. That
        // turns schedule construction into prefix sums — O(bins), no
        // incremental-delta drift (§Perf: this replaced an O(bins²) exact
        // recomputation).
        let nb = l_b.num_bins();
        let mut a_coef = vec![0.0f64; nb];
        let mut b_coef = vec![0.0f64; nb];
        let mut beta_b = vec![0.0f64; nb];
        for i in 0..nb {
            let (l1, l2, h) = l_b.bin(i);
            if h <= 0.0 {
                continue;
            }
            let dens = h / (l2 - l1).max(1e-12);
            a_coef[i] = scale * dens * ((b * l2).exp() - (b * l1).exp());
            b_coef[i] = -scale * dens * (b * l1).exp();
            beta_b[i] = scale * dens;
        }
        // prefix_a[j] = Σ_{i<j} a_coef[i].
        let mut prefix_a = vec![0.0f64; nb + 1];
        for i in 0..nb {
            prefix_a[i + 1] = prefix_a[i] + a_coef[i];
        }
        let mut offsets = Vec::with_capacity(nb + 1);
        let mut coeffs = Vec::with_capacity(nb + 2);
        // Segment before the first boundary: all bins in regime A.
        coeffs.push(Coeffs {
            alpha: prefix_a[nb],
            beta: 0.0,
        });
        // Walk boundaries in increasing t: offset = −edge_{nb−s}.
        for s in 1..=nb {
            let j = nb - s; // the single regime-B bin in this segment
            let seg = Coeffs {
                alpha: prefix_a[j] + b_coef[j],
                beta: beta_b[j],
            };
            // Merge runs of identical segments (zero-mass bins) so the
            // milestone machinery doesn't fire on empty transitions.
            if *coeffs.last().unwrap() == seg {
                continue;
            }
            offsets.push(-l_b.edge(j + 1));
            coeffs.push(seg);
        }
        // Terminal segment: everything past D − edge_0 scores zero.
        if *coeffs.last().unwrap() != Coeffs::ZERO {
            offsets.push(-l_b.edge(0));
            coeffs.push(Coeffs::ZERO);
        }
        ScoreTemplate { b, offsets, coeffs }
    }

    /// Number of (α, β) segments (milestone count + 1).
    pub fn num_segments(&self) -> usize {
        self.coeffs.len()
    }

    /// Unit coefficients active at deadline-relative offset `local`.
    fn segment_at(&self, local: f64) -> Coeffs {
        let idx = self.offsets.partition_point(|&m| m <= local);
        self.coeffs[idx]
    }
}

/// The full score schedule of one request (for one batch-size queue): a
/// shared [`ScoreTemplate`] plus the request's deadline shift and penalty
/// scaling. All queries are O(log segments) and allocation-free.
#[derive(Debug, Clone)]
pub struct ScoreSchedule {
    template: Arc<ScoreTemplate>,
    /// The request's deadline in relative ms (per the `ScoreContext` base
    /// active at build time).
    shift: f64,
    /// `c · e^{−bD}` applied to every template α.
    alpha_scale: f64,
    /// `c` applied to every template β.
    beta_scale: f64,
}

impl ScoreSchedule {
    /// Instantiate a shared template for one request: O(1), no per-bin
    /// math, no allocation beyond the `Arc` refcount bump. This is the
    /// hot-path constructor — the estimator owns one template per
    /// `(model, app, bs)`.
    pub fn instantiate(
        template: &Arc<ScoreTemplate>,
        ctx: &ScoreContext,
        deadline: Micros,
        c: f64,
    ) -> ScoreSchedule {
        debug_assert!(
            template.b == ctx.b,
            "template built for b={} instantiated under a context with b={}",
            template.b,
            ctx.b
        );
        let d_rel = ctx.rel_ms(deadline);
        ScoreSchedule {
            template: Arc::clone(template),
            shift: d_rel,
            alpha_scale: c * (-ctx.b * d_rel).exp(),
            beta_scale: c,
        }
    }

    /// Build from the request's deadline (absolute Micros), its miss
    /// penalty `c`, and the estimated batch latency distribution `l_b`.
    ///
    /// Constructs a private template — the hot path instead shares one
    /// template per `(model, app, bs)` via the estimator and calls
    /// [`ScoreSchedule::instantiate`] directly.
    ///
    /// Within the schedule all times are relative ms (per `ctx.base`).
    pub fn build(ctx: &ScoreContext, deadline: Micros, c: f64, l_b: &Histogram) -> ScoreSchedule {
        ScoreSchedule::instantiate(&Arc::new(ScoreTemplate::new(ctx.b, l_b)), ctx, deadline, c)
    }

    /// Appendix B: schedule for a piecewise-step cost function — the sum
    /// of the single-step schedules of its decomposition (deadline `d_i`
    /// with incremental penalty `c_i − c_{i−1}`).
    pub fn build_piecewise(
        ctx: &ScoreContext,
        cost: &crate::core::cost::PiecewiseStepCost,
        l_b: &Histogram,
    ) -> ScoreSchedule {
        let parts: Vec<ScoreSchedule> = cost
            .decompose()
            .into_iter()
            .map(|step| ScoreSchedule::build(ctx, step.deadline, step.penalty, l_b))
            .collect();
        if parts.len() == 1 {
            return parts.into_iter().next().unwrap();
        }
        // Merge: union of boundaries; coefficients sum segment-wise. The
        // merged schedule is materialized as its own (identity-scaled)
        // template. Each part's boundaries are materialized in absolute
        // relative-ms form once, and the segment lookups partition on those
        // exact values — re-deriving `rep − shift` per query could round to
        // the wrong side of a part's own boundary.
        let abs_bounds: Vec<Vec<f64>> = parts
            .iter()
            .map(|p| p.template.offsets.iter().map(|&o| o + p.shift).collect())
            .collect();
        let mut boundaries: Vec<f64> = abs_bounds.iter().flatten().copied().collect();
        boundaries.sort_by(|a, b| a.partial_cmp(b).unwrap());
        boundaries.dedup_by(|a, b| (*a - *b).abs() < 1e-12);
        let mut coeffs = Vec::with_capacity(boundaries.len() + 1);
        for seg in 0..=boundaries.len() {
            let rep = if seg == 0 {
                boundaries.first().map(|&m| m - 1.0).unwrap_or(0.0)
            } else {
                boundaries[seg - 1]
            };
            let mut alpha = 0.0;
            let mut beta = 0.0;
            for (p, ab) in parts.iter().zip(&abs_bounds) {
                let idx = ab.partition_point(|&m| m <= rep);
                let unit = p.template.coeffs[idx];
                alpha += unit.alpha * p.alpha_scale;
                beta += unit.beta * p.beta_scale;
            }
            coeffs.push(Coeffs { alpha, beta });
        }
        ScoreSchedule {
            template: Arc::new(ScoreTemplate {
                b: ctx.b,
                offsets: boundaries,
                coeffs,
            }),
            shift: 0.0,
            alpha_scale: 1.0,
            beta_scale: 1.0,
        }
    }

    /// The shared template backing this schedule.
    pub fn template(&self) -> &Arc<ScoreTemplate> {
        &self.template
    }

    /// Coefficients active at relative time `t_rel` (ms).
    pub fn coeffs_at(&self, t_rel: f64) -> Coeffs {
        let seg = self.template.segment_at(t_rel - self.shift);
        Coeffs {
            alpha: seg.alpha * self.alpha_scale,
            beta: seg.beta * self.beta_scale,
        }
    }

    /// Next milestone strictly after `t_rel`, if any (Algorithm 1 line 6's
    /// `Milestone(r)`).
    pub fn next_milestone(&self, t_rel: f64) -> Option<f64> {
        let local = t_rel - self.shift;
        let idx = self.template.offsets.partition_point(|&m| m <= local);
        self.template.offsets.get(idx).map(|&m| m + self.shift)
    }

    /// Evaluate `p(t)` at relative ms `t_rel` (for testing/plotting; the
    /// hot path uses `coeffs_at` + the shared multiplier).
    pub fn score_at(&self, b: f64, t_rel: f64) -> f64 {
        self.coeffs_at(t_rel).eval((b * t_rel).exp())
    }

    /// Whether the score is identically zero from `t_rel` on.
    pub fn exhausted(&self, t_rel: f64) -> bool {
        let local = t_rel - self.shift;
        self.template
            .offsets
            .last()
            .map(|&m| local >= m)
            .unwrap_or(true)
    }
}

/// Reference (slow) implementation of Eq. 2, used by tests to validate the
/// segment construction: evaluates each bin's regime directly.
pub fn reference_score(
    b: f64,
    deadline_rel_ms: f64,
    c: f64,
    l_b: &Histogram,
    t_rel: f64,
) -> f64 {
    let e_l = l_b.mean().max(1e-9);
    let scale = c / (e_l * b);
    let mut p = 0.0;
    for i in 0..l_b.num_bins() {
        let (l1, l2, h) = l_b.bin(i);
        if h <= 0.0 {
            continue;
        }
        let d = deadline_rel_ms;
        let dens = h / (l2 - l1).max(1e-12);
        if t_rel < d - l2 {
            p += scale * dens * ((b * l2).exp() - (b * l1).exp()) * (-b * d).exp()
                * (b * t_rel).exp();
        } else if t_rel < d - l1 {
            p += scale * dens
                - scale * dens * (b * l1).exp() * (-b * d).exp() * (b * t_rel).exp();
        }
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::ms_to_us;
    use crate::util::rng::Rng;

    const B: f64 = 1e-4;

    fn ctx() -> ScoreContext {
        ScoreContext::new(B)
    }

    #[test]
    fn schedule_matches_reference() {
        let c = ctx();
        let l_b = Histogram::from_weights(5.0, 5.0, &[1.0, 2.0, 1.0]); // [5,20) ms
        let deadline = ms_to_us(100.0);
        let s = ScoreSchedule::build(&c, deadline, 1.0, &l_b);
        for t in [-50.0, 0.0, 40.0, 79.9, 80.1, 85.0, 90.1, 94.9, 95.1, 200.0] {
            let fast = s.score_at(B, t);
            let slow = reference_score(B, 100.0, 1.0, &l_b, t);
            assert!(
                (fast - slow).abs() < 1e-9 * (1.0 + slow.abs()),
                "t={t}: fast={fast} slow={slow}"
            );
        }
    }

    #[test]
    fn score_rises_then_falls_to_zero() {
        let c = ctx();
        let l_b = Histogram::from_weights(5.0, 5.0, &[1.0, 1.0]);
        let s = ScoreSchedule::build(&c, ms_to_us(200.0), 1.0, &l_b);
        // Rising while waiting (regime A: positive α, e^{bt} grows).
        assert!(s.score_at(B, 50.0) > s.score_at(B, 0.0));
        // Zero after the last milestone (t ≥ D − l1_min = 195).
        assert_eq!(s.score_at(B, 196.0), 0.0);
        assert!(s.exhausted(195.0));
        assert!(!s.exhausted(100.0));
    }

    #[test]
    fn milestones_are_bin_edges() {
        let c = ctx();
        // Unequal bin masses → the coefficients change at every edge.
        let l_b = Histogram::from_weights(10.0, 10.0, &[1.0, 3.0]); // bins [10,20),[20,30)
        let s = ScoreSchedule::build(&c, ms_to_us(100.0), 1.0, &l_b);
        // Boundaries at D−edge: D−30=70, D−20=80, D−10=90.
        assert_eq!(s.next_milestone(0.0), Some(70.0));
        assert_eq!(s.next_milestone(70.0), Some(80.0));
        assert_eq!(s.next_milestone(80.0), Some(90.0));
        assert_eq!(s.next_milestone(90.0), None);
    }

    #[test]
    fn equal_density_bins_merge_milestones() {
        // p(t) is continuous across an edge between equal-mass bins, so no
        // milestone (hull re-insert) is needed there.
        let c = ctx();
        let l_b = Histogram::from_weights(10.0, 10.0, &[1.0, 1.0]);
        let s = ScoreSchedule::build(&c, ms_to_us(100.0), 1.0, &l_b);
        assert_eq!(s.next_milestone(0.0), Some(70.0));
        // The D−20=80 boundary is a no-op and is merged away.
        assert_eq!(s.next_milestone(70.0), Some(90.0));
        assert_eq!(s.next_milestone(90.0), None);
        // The score still matches the reference everywhere.
        for t in [60.0, 75.0, 79.9, 80.1, 85.0, 95.0] {
            let slow = reference_score(B, 100.0, 1.0, &l_b, t);
            assert!((s.score_at(B, t) - slow).abs() < 1e-9 * (1.0 + slow.abs()));
        }
    }

    #[test]
    fn urgency_ordering_near_deadline() {
        // Two identical requests, different deadlines: the one with the
        // nearer deadline scores higher "now" (more cost reduction).
        let c = ctx();
        let l_b = Histogram::from_weights(5.0, 5.0, &[1.0, 1.0]);
        let near = ScoreSchedule::build(&c, ms_to_us(50.0), 1.0, &l_b);
        let far = ScoreSchedule::build(&c, ms_to_us(500.0), 1.0, &l_b);
        let t = 10.0;
        assert!(near.score_at(B, t) > far.score_at(B, t));
    }

    #[test]
    fn shorter_expected_latency_scores_higher() {
        // 1/E[L] weighting: cheaper batches win, all else equal.
        let c = ctx();
        let short = Histogram::constant(5.0);
        let long = Histogram::constant(50.0);
        let s_short = ScoreSchedule::build(&c, ms_to_us(500.0), 1.0, &short);
        let s_long = ScoreSchedule::build(&c, ms_to_us(500.0), 1.0, &long);
        assert!(s_short.score_at(B, 0.0) > s_long.score_at(B, 0.0));
    }

    #[test]
    fn random_schedules_match_reference() {
        let mut rng = Rng::new(31);
        for _ in 0..50 {
            let c = ctx();
            let nb = 1 + rng.index(8);
            let w: Vec<f64> = (0..nb).map(|_| rng.f64() + 0.01).collect();
            let l_b = Histogram::from_weights(rng.f64() * 20.0, 1.0 + rng.f64() * 10.0, &w);
            // Quantize the deadline to whole µs the way the scheduler's
            // clock does, so the reference sees the same value.
            let d_ms = crate::clock::us_to_ms(ms_to_us(50.0 + rng.f64() * 2000.0));
            let cost = 0.5 + rng.f64() * 2.0;
            let s = ScoreSchedule::build(&c, ms_to_us(d_ms), cost, &l_b);
            for _ in 0..20 {
                let t = rng.f64() * d_ms * 1.2 - 10.0;
                let fast = s.score_at(B, t);
                let slow = reference_score(B, d_ms, cost, &l_b, t);
                assert!(
                    (fast - slow).abs() < 1e-7 * (1.0 + slow.abs()),
                    "t={t}: {fast} vs {slow}"
                );
            }
        }
    }

    #[test]
    fn piecewise_cost_schedule_is_sum_of_steps() {
        // Appendix B: p(t) of the multi-step cost equals the sum of the
        // single-step scores of the decomposition, at every t.
        use crate::core::cost::PiecewiseStepCost;
        let c = ctx();
        let l_b = Histogram::from_weights(5.0, 5.0, &[1.0, 2.0, 1.0]);
        let cost = PiecewiseStepCost::new(vec![
            (ms_to_us(100.0), 1.0),
            (ms_to_us(200.0), 3.0),
            (ms_to_us(400.0), 7.0),
        ]);
        let multi = ScoreSchedule::build_piecewise(&c, &cost, &l_b);
        for t in [-20.0, 0.0, 50.0, 85.0, 95.0, 150.0, 185.0, 250.0, 390.0, 500.0] {
            let want = reference_score(B, 100.0, 1.0, &l_b, t)
                + reference_score(B, 200.0, 2.0, &l_b, t)
                + reference_score(B, 400.0, 4.0, &l_b, t);
            let got = multi.score_at(B, t);
            assert!(
                (got - want).abs() < 1e-9 * (1.0 + want.abs()),
                "t={t}: {got} vs {want}"
            );
        }
        // Still exhibits milestones from every step's deadline.
        assert!(multi.next_milestone(0.0).is_some());
        assert!(multi.exhausted(396.0));
    }

    #[test]
    fn piecewise_single_step_equals_plain_build() {
        use crate::core::cost::PiecewiseStepCost;
        let c = ctx();
        let l_b = Histogram::from_weights(2.0, 3.0, &[1.0, 1.0, 2.0]);
        let cost = PiecewiseStepCost::single(ms_to_us(150.0), 2.5);
        let multi = ScoreSchedule::build_piecewise(&c, &cost, &l_b);
        let single = ScoreSchedule::build(&c, ms_to_us(150.0), 2.5, &l_b);
        for t in [0.0, 80.0, 120.0, 140.0, 160.0] {
            assert_eq!(multi.score_at(B, t), single.score_at(B, t));
        }
    }

    #[test]
    fn context_reset_detection() {
        let mut c = ScoreContext::new(1e-4);
        // b·t > 40 → t > 400,000 ms = 400 s.
        assert!(!c.needs_reset(ms_to_us(399_000.0)));
        assert!(c.needs_reset(ms_to_us(400_001.0)));
        c.reset(ms_to_us(400_001.0));
        assert!(!c.needs_reset(ms_to_us(500_000.0)));
        // Scores survive rebasing: same score at same absolute time.
        let l_b = Histogram::from_weights(5.0, 5.0, &[1.0, 1.0]);
        let c0 = ScoreContext::new(1e-4);
        let mut c1 = ScoreContext::new(1e-4);
        c1.reset(ms_to_us(100_000.0));
        let d = ms_to_us(100_500.0);
        let t = ms_to_us(100_100.0);
        let s0 = ScoreSchedule::build(&c0, d, 1.0, &l_b);
        let s1 = ScoreSchedule::build(&c1, d, 1.0, &l_b);
        let p0 = s0.coeffs_at(c0.rel_ms(t)).eval(c0.multiplier(t));
        let p1 = s1.coeffs_at(c1.rel_ms(t)).eval(c1.multiplier(t));
        assert!((p0 - p1).abs() < 1e-6 * (1.0 + p0.abs()), "{p0} vs {p1}");
    }

    #[test]
    fn shared_template_instantiation_matches_direct_build() {
        // The hot path instantiates one shared template per (model, app,
        // bs); every instantiation must equal an independent build at that
        // deadline — bit-for-bit, since `build` routes through the same
        // template math.
        let c = ctx();
        let l_b = Histogram::from_weights(3.0, 2.5, &[1.0, 4.0, 2.0, 0.0, 1.0]);
        let tpl = std::sync::Arc::new(ScoreTemplate::new(B, &l_b));
        for d_ms in [40.0, 120.0, 333.25, 1_000.0, 9_999.5] {
            let d = ms_to_us(d_ms);
            let inst = ScoreSchedule::instantiate(&tpl, &c, d, 1.0);
            let direct = ScoreSchedule::build(&c, d, 1.0, &l_b);
            for t in [-10.0, 0.0, d_ms * 0.5, d_ms - 4.0, d_ms - 0.1, d_ms + 5.0] {
                let a = inst.coeffs_at(t);
                let b = direct.coeffs_at(t);
                assert_eq!(a.alpha.to_bits(), b.alpha.to_bits(), "alpha at t={t} d={d_ms}");
                assert_eq!(a.beta.to_bits(), b.beta.to_bits(), "beta at t={t} d={d_ms}");
                assert_eq!(inst.next_milestone(t), direct.next_milestone(t));
                assert_eq!(inst.exhausted(t), direct.exhausted(t));
            }
        }
    }

    #[test]
    fn template_is_shared_not_copied() {
        let c = ctx();
        let l_b = Histogram::from_weights(5.0, 5.0, &[1.0, 2.0, 1.0]);
        let tpl = std::sync::Arc::new(ScoreTemplate::new(B, &l_b));
        let s1 = ScoreSchedule::instantiate(&tpl, &c, ms_to_us(100.0), 1.0);
        let s2 = ScoreSchedule::instantiate(&tpl, &c, ms_to_us(700.0), 2.0);
        assert!(std::sync::Arc::ptr_eq(s1.template(), s2.template()));
        assert!(std::sync::Arc::ptr_eq(s1.template(), &tpl));
        assert!(tpl.num_segments() >= 2);
    }

    #[test]
    fn penalty_scales_score_linearly() {
        // c multiplies both α and β uniformly, so p_c(t) = c · p_1(t).
        let c = ctx();
        let l_b = Histogram::from_weights(5.0, 5.0, &[1.0, 3.0]);
        let tpl = std::sync::Arc::new(ScoreTemplate::new(B, &l_b));
        let s1 = ScoreSchedule::instantiate(&tpl, &c, ms_to_us(200.0), 1.0);
        let s3 = ScoreSchedule::instantiate(&tpl, &c, ms_to_us(200.0), 3.0);
        for t in [0.0, 100.0, 185.0, 192.0] {
            let p1 = s1.score_at(B, t);
            let p3 = s3.score_at(B, t);
            assert!((p3 - 3.0 * p1).abs() < 1e-12 * (1.0 + p3.abs()), "t={t}");
        }
    }

    #[test]
    fn relative_ordering_invariant_to_b() {
        // §5.6: for requests sharing a latency distribution, the nearer
        // deadline scores higher at every b (the b-sensitivity experiment's
        // underlying invariant).
        let l_b = Histogram::from_weights(2.0, 2.0, &[1.0, 3.0]);
        for b in [1e-6, 1e-5, 1e-4, 1e-3, 1e-2] {
            let c = ScoreContext::new(b);
            let s1 = ScoreSchedule::build(&c, ms_to_us(80.0), 1.0, &l_b);
            let s2 = ScoreSchedule::build(&c, ms_to_us(120.0), 1.0, &l_b);
            assert!(
                s1.score_at(b, 0.0) > s2.score_at(b, 0.0),
                "ordering flipped at b={b}"
            );
        }
    }
}
