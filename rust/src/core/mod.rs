//! Core domain math of Orloj: requests, empirical distributions, order
//! statistics, the batch cost model, SLO cost functions, and the
//! time-varying priority score (paper §3–4).

pub mod batchmodel;
pub mod cost;
pub mod histogram;
pub mod orderstats;
pub mod priority;
pub mod request;
