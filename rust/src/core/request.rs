//! Inference request model (paper §3.1).
//!
//! Each request is defined by its *release time* and *deadline* (release +
//! SLO) and has a hidden minimum *execution time* — the time it takes when
//! executed alone at batch size 1. The scheduler never sees `exec_ms`; it is
//! carried on the struct so the simulator / worker can realize the actual
//! execution, and so the online profiler can learn the distribution the way
//! the real system would (paper: finished requests are sampled and profiled
//! asynchronously).

use crate::clock::Micros;

/// Application identity. Requests are tagged per application (paper §3.2,
/// step 2a); the profiler keeps one execution-time distribution per
/// (model, app) pair.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct AppId(pub u32);

/// Model identity. A cluster multiplexes many models across its workers
/// (Clockwork-style per-model placement); every request names the model it
/// must execute on, and a batch never mixes models.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ModelId(pub u32);

impl ModelId {
    /// The single-model deployments' implicit model.
    pub const DEFAULT: ModelId = ModelId(0);
}

/// Unique request id.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RequestId(pub u64);

/// An inference request as seen by the coordinator.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub app: AppId,
    /// Which model this request must execute on. Routing only considers
    /// workers hosting it; schedulers never batch across models.
    pub model: ModelId,
    /// Arrival time.
    pub release: Micros,
    /// Deadline = release + SLO.
    pub deadline: Micros,
    /// Ground-truth solo execution time in milliseconds (hidden from the
    /// scheduler; used by the worker/simulator and post-hoc profiling).
    pub exec_ms: f64,
    /// Opaque payload selector for the real-model path: which model variant
    /// this request "needs" (e.g. early-exit depth). 0 for simulated runs.
    pub variant: u32,
}

impl Request {
    pub fn new(id: u64, app: AppId, release: Micros, slo: Micros, exec_ms: f64) -> Self {
        Request {
            id: RequestId(id),
            app,
            model: ModelId::DEFAULT,
            release,
            deadline: release + slo,
            exec_ms,
            variant: 0,
        }
    }

    pub fn with_variant(mut self, variant: u32) -> Self {
        self.variant = variant;
        self
    }

    pub fn with_model(mut self, model: ModelId) -> Self {
        self.model = model;
        self
    }

    /// SLO budget of this request.
    pub fn slo(&self) -> Micros {
        self.deadline - self.release
    }

    /// Remaining time before the deadline at time `t` (0 if past due).
    pub fn slack(&self, t: Micros) -> Micros {
        self.deadline.saturating_sub(t)
    }

    /// Whether the deadline has passed at time `t`.
    pub fn expired(&self, t: Micros) -> bool {
        t >= self.deadline
    }
}

/// Terminal state of a request, for metrics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// Completed at or before its deadline.
    Finished,
    /// Completed, but after the deadline.
    Late,
    /// Dropped by the scheduler (infeasible before execution).
    TimedOut,
    /// Failed because the executing system aborted the batch (Clockwork's
    /// timeout-abort behaviour, §2.3).
    Aborted,
}

impl Outcome {
    pub fn met_slo(self) -> bool {
        matches!(self, Outcome::Finished)
    }
}

/// A completed request with its terminal state.
#[derive(Debug, Clone)]
pub struct Completion {
    pub request: Request,
    pub outcome: Outcome,
    /// Completion time (for Finished/Late) or drop time.
    pub at: Micros,
    /// Size of the batch it executed in (0 if never executed).
    pub batch_size: usize,
    /// Worker that executed the batch (None for scheduler-side drops).
    pub worker: Option<usize>,
    /// Served from the admission controller's best-effort lane (DESIGN.md
    /// §10): its outcome never counts toward the SLO finish rate. Always
    /// false when admission control is off.
    pub best_effort: bool,
}

impl Completion {
    /// End-to-end latency in milliseconds (completion − release).
    pub fn latency_ms(&self) -> f64 {
        crate::clock::us_to_ms(self.at.saturating_sub(self.request.release))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadline_math() {
        let r = Request::new(1, AppId(0), 1_000, 5_000, 3.0);
        assert_eq!(r.deadline, 6_000);
        assert_eq!(r.slo(), 5_000);
        assert_eq!(r.slack(2_000), 4_000);
        assert_eq!(r.slack(9_000), 0);
        assert!(!r.expired(5_999));
        assert!(r.expired(6_000));
    }

    #[test]
    fn model_tag_defaults_and_overrides() {
        let r = Request::new(1, AppId(0), 0, 1_000, 1.0);
        assert_eq!(r.model, ModelId::DEFAULT);
        let r = r.with_model(ModelId(3));
        assert_eq!(r.model, ModelId(3));
    }

    #[test]
    fn outcome_slo() {
        assert!(Outcome::Finished.met_slo());
        assert!(!Outcome::Late.met_slo());
        assert!(!Outcome::TimedOut.met_slo());
        assert!(!Outcome::Aborted.met_slo());
    }

    #[test]
    fn completion_latency() {
        let r = Request::new(1, AppId(0), 1_000, 5_000, 3.0);
        let c = Completion {
            request: r,
            outcome: Outcome::Finished,
            at: 4_500,
            batch_size: 4,
            worker: Some(0),
            best_effort: false,
        };
        assert!((c.latency_ms() - 3.5).abs() < 1e-12);
    }
}
