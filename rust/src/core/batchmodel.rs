//! Batch execution-time model (paper Eq. 3, 5, 9).
//!
//! For a batch B of k requests with (padded) per-request length `l`,
//! `l_B = c0 + c1·k·l`: a fixed launch overhead plus work linear in the
//! batch's total padded volume. `c0`/`c1` are model+hardware constants —
//! profiled from the real PJRT worker on the serving path, configured per
//! synthetic model in the simulator.
//!
//! `batch_latency` composes this with the order-statistics module: given
//! the member distributions, the batch latency is the affine image of the
//! max distribution (Eq. 9), and `E[L_B]` follows (Eq. 5).

use super::histogram::Histogram;
use super::orderstats;

/// Linear batch cost model: `l_B(k, l) = c0 + c1 · k · l` (ms).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchCostModel {
    /// Fixed per-batch overhead (ms).
    pub c0: f64,
    /// Marginal cost factor per request-millisecond. c1 < 1 expresses the
    /// batching gain (k requests cost less than k sequential runs).
    pub c1: f64,
}

impl BatchCostModel {
    pub fn new(c0: f64, c1: f64) -> Self {
        assert!(c0 >= 0.0 && c1 > 0.0);
        BatchCostModel { c0, c1 }
    }

    /// A model calibrated to a typical GPU batching profile: batch of 8
    /// costs ~2–3× a batch of 1 rather than 8× (the Fig. 1 premise). The
    /// non-scalable fraction `c0` must be sized relative to the workload's
    /// typical solo latency — use [`BatchCostModel::calibrated`] per
    /// workload; this constant version assumes ~10 ms solo latencies.
    pub fn gpu_like() -> Self {
        BatchCostModel::new(8.0, 0.20)
    }

    /// Calibrate to a workload whose mean solo execution time is `mean_ms`:
    /// `c0 = 0.8·mean` (kernel-launch + non-batched fraction), `c1 = 0.2`.
    /// Properties: bs=1 latency ≈ solo latency for typical requests;
    /// bs=8 on constant inputs ≈ 2.4× bs=1 (≈3.3× throughput gain);
    /// dynamic inputs erode the gain through the max order statistic —
    /// the paper's straggler effect.
    pub fn calibrated(mean_ms: f64) -> Self {
        assert!(mean_ms > 0.0);
        BatchCostModel::new(0.8 * mean_ms, 0.20)
    }

    /// Ideal linear scaling without batching gain (used in ablations).
    pub fn linear() -> Self {
        BatchCostModel::new(0.0, 1.0)
    }

    /// Deterministic batch latency for a known padded length (ms).
    #[inline]
    pub fn latency(&self, k: usize, l: f64) -> f64 {
        self.c0 + self.c1 * k as f64 * l
    }

    /// Batch latency *distribution* for k iid draws from `h` (Eq. 6 + 9).
    pub fn batch_latency_iid(&self, h: &Histogram, k: usize) -> Histogram {
        let max = orderstats::max_iid(h, k);
        max.affine(self.c1 * k as f64, self.c0)
    }

    /// Batch latency distribution for a grouped composition: `counts[j]`
    /// requests from distribution `hs[j]` (Eq. 8 + 9).
    pub fn batch_latency_grouped(
        &self,
        hs: &[&Histogram],
        counts: &[usize],
        bins: usize,
    ) -> Histogram {
        let k: usize = counts.iter().sum();
        assert!(k >= 1);
        let max = orderstats::max_grouped(hs, counts, bins);
        max.affine(self.c1 * k as f64, self.c0)
    }

    /// E[L_B] for k iid draws (Eq. 5).
    pub fn expected_batch_latency_iid(&self, h: &Histogram, k: usize) -> f64 {
        self.batch_latency_iid(h, k).mean()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_formula() {
        let m = BatchCostModel::new(1.0, 0.5);
        assert!((m.latency(1, 10.0) - 6.0).abs() < 1e-12);
        assert!((m.latency(4, 10.0) - 21.0).abs() < 1e-12);
    }

    #[test]
    fn constant_distribution_matches_formula() {
        // Static-DNN degenerate case: Eq. 5 reduces to Eq. 3.
        let m = BatchCostModel::new(2.0, 0.4);
        let h = Histogram::constant(10.0);
        for k in [1usize, 2, 8] {
            let d = m.batch_latency_iid(&h, k);
            assert!(
                (d.mean() - m.latency(k, 10.0)).abs() < 0.05,
                "k={k}: {} vs {}",
                d.mean(),
                m.latency(k, 10.0)
            );
        }
    }

    #[test]
    fn expected_latency_grows_with_k() {
        let m = BatchCostModel::gpu_like();
        let h = Histogram::from_weights(1.0, 1.0, &[1.0, 1.0, 1.0, 1.0]);
        let mut prev = 0.0;
        for k in 1..=16 {
            let e = m.expected_batch_latency_iid(&h, k);
            assert!(e > prev, "k={k}");
            prev = e;
        }
    }

    #[test]
    fn batching_gain_beats_sequential() {
        // Total time for k requests in one batch < k sequential batches of 1.
        let m = BatchCostModel::gpu_like();
        let h = Histogram::constant(10.0);
        let k = 8;
        let batched = m.expected_batch_latency_iid(&h, k);
        let sequential = k as f64 * m.expected_batch_latency_iid(&h, 1);
        assert!(batched < sequential);
    }

    #[test]
    fn grouped_reduces_to_iid() {
        let m = BatchCostModel::new(0.3, 0.5);
        let h = Histogram::from_weights(2.0, 0.5, &[1.0, 3.0, 1.0]);
        let a = m.batch_latency_iid(&h, 3);
        let b = m.batch_latency_grouped(&[&h], &[3], h.num_bins());
        assert!((a.mean() - b.mean()).abs() < 1e-6);
    }

    #[test]
    fn straggler_effect() {
        // A batch mixing a short-app and a long-app inherits the long tail:
        // the short app's solo latency is much smaller than its batch
        // latency — the §2.2 motivation.
        let m = BatchCostModel::new(0.0, 1.0);
        let short = Histogram::constant(2.0);
        let long = Histogram::constant(20.0);
        let solo_short = m.batch_latency_iid(&short, 1).mean();
        let mixed = m
            .batch_latency_grouped(&[&short, &long], &[1, 1], 64)
            .mean();
        // mixed ≈ c1 · 2 · 20 = 40 ≫ 2
        assert!(mixed > 10.0 * solo_short, "solo={solo_short} mixed={mixed}");
    }
}
