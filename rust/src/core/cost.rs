//! SLO cost functions (paper §4.1 Fig. 5, Appendix B).
//!
//! The scheduler models SLOs with a step cost: finishing at or before the
//! deadline costs 0, finishing after costs `c`. Appendix B generalizes to
//! piecewise-step functions (multiple deadlines with increasing penalties)
//! by decomposing them into a sum of single steps — the priority score of
//! the multi-step function is the sum of the single-step scores.

use crate::clock::Micros;

/// A single-step SLO cost: 0 before `deadline`, `penalty` after.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StepCost {
    pub deadline: Micros,
    pub penalty: f64,
}

impl StepCost {
    pub fn new(deadline: Micros, penalty: f64) -> Self {
        assert!(penalty >= 0.0);
        StepCost { deadline, penalty }
    }

    /// Cost of finishing at time `t`.
    pub fn at(&self, t: Micros) -> f64 {
        if t > self.deadline {
            self.penalty
        } else {
            0.0
        }
    }
}

/// A piecewise-step cost function: non-decreasing penalties at increasing
/// deadlines. `C(t) = max penalty among steps with deadline < t` — i.e.
/// cumulative as t passes each deadline.
#[derive(Debug, Clone, PartialEq)]
pub struct PiecewiseStepCost {
    /// (deadline, cumulative penalty after it), sorted by deadline strictly
    /// increasing, penalties strictly increasing.
    steps: Vec<(Micros, f64)>,
}

impl PiecewiseStepCost {
    pub fn new(steps: Vec<(Micros, f64)>) -> Self {
        assert!(!steps.is_empty());
        for w in steps.windows(2) {
            assert!(w[0].0 < w[1].0, "deadlines must be strictly increasing");
            assert!(
                w[0].1 < w[1].1,
                "cumulative penalties must be strictly increasing"
            );
        }
        assert!(steps[0].1 > 0.0);
        PiecewiseStepCost { steps }
    }

    pub fn single(deadline: Micros, penalty: f64) -> Self {
        PiecewiseStepCost::new(vec![(deadline, penalty)])
    }

    /// Cost of finishing at time `t`.
    pub fn at(&self, t: Micros) -> f64 {
        let mut cost = 0.0;
        for &(d, c) in &self.steps {
            if t > d {
                cost = c;
            } else {
                break;
            }
        }
        cost
    }

    /// Appendix B: decompose into single-step components whose costs sum to
    /// this function. Deadlines d1<d2<d3 with cumulative costs c1<c2<c3
    /// decompose as (d1,c1), (d2,c2−c1), (d3,c3−c2).
    pub fn decompose(&self) -> Vec<StepCost> {
        let mut out = Vec::with_capacity(self.steps.len());
        let mut prev = 0.0;
        for &(d, c) in &self.steps {
            out.push(StepCost::new(d, c - prev));
            prev = c;
        }
        out
    }

    /// Final (largest) deadline.
    pub fn last_deadline(&self) -> Micros {
        self.steps.last().unwrap().0
    }

    pub fn steps(&self) -> &[(Micros, f64)] {
        &self.steps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_cost_basic() {
        let s = StepCost::new(100, 5.0);
        assert_eq!(s.at(99), 0.0);
        assert_eq!(s.at(100), 0.0);
        assert_eq!(s.at(101), 5.0);
    }

    #[test]
    fn piecewise_evaluation() {
        let p = PiecewiseStepCost::new(vec![(10, 1.0), (20, 3.0), (30, 7.0)]);
        assert_eq!(p.at(5), 0.0);
        assert_eq!(p.at(10), 0.0);
        assert_eq!(p.at(15), 1.0);
        assert_eq!(p.at(25), 3.0);
        assert_eq!(p.at(100), 7.0);
    }

    #[test]
    fn decomposition_sums_to_original() {
        // Appendix B: sum of single-step costs == piecewise cost, everywhere.
        let p = PiecewiseStepCost::new(vec![(10, 1.0), (20, 3.0), (30, 7.0)]);
        let parts = p.decompose();
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[1].penalty, 2.0);
        assert_eq!(parts[2].penalty, 4.0);
        for t in [0u64, 10, 11, 20, 21, 30, 31, 1000] {
            let sum: f64 = parts.iter().map(|s| s.at(t)).sum();
            assert_eq!(sum, p.at(t), "t={t}");
        }
    }

    #[test]
    #[should_panic]
    fn rejects_non_increasing_penalties() {
        PiecewiseStepCost::new(vec![(10, 3.0), (20, 2.0)]);
    }

    #[test]
    fn single_matches_step() {
        let p = PiecewiseStepCost::single(50, 2.0);
        let s = StepCost::new(50, 2.0);
        for t in [0u64, 50, 51, 99] {
            assert_eq!(p.at(t), s.at(t));
        }
        assert_eq!(p.last_deadline(), 50);
    }
}
