//! Time abstraction: the same scheduler code runs against real wall-clock
//! time (PJRT serving path) and virtual time (discrete-event simulator /
//! evaluation sweeps).
//!
//! All times in Orloj are `Micros` — microseconds relative to a process- or
//! simulation-local epoch. The paper's overflow discussion (Section 4.4)
//! is exactly about *not* using absolute UNIX timestamps inside e^{bt};
//! using a local epoch is the first half of that mitigation, the score
//! base-time reset in `core::priority` is the second half.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Microseconds since the local epoch.
pub type Micros = u64;

/// Convert milliseconds (f64, the paper's natural unit) to Micros.
#[inline]
pub fn ms_to_us(ms: f64) -> Micros {
    (ms * 1000.0).round().max(0.0) as Micros
}

/// Convert Micros to milliseconds.
#[inline]
pub fn us_to_ms(us: Micros) -> f64 {
    us as f64 / 1000.0
}

/// Clock interface used by schedulers, profilers and the serving loop.
pub trait Clock: Send + Sync {
    /// Current time in microseconds since this clock's epoch.
    fn now(&self) -> Micros;
}

/// Shared handles are clocks too, so one timeline can be read from the
/// serving loop and its worker threads alike (`serve::realtime`).
impl<C: Clock + ?Sized> Clock for Arc<C> {
    fn now(&self) -> Micros {
        (**self).now()
    }
}

impl<C: Clock + ?Sized> Clock for &C {
    fn now(&self) -> Micros {
        (**self).now()
    }
}

/// Wall clock anchored at construction time. `Copy` (an `Instant` is just
/// a timestamp), so the ingress shards and the serving core can stamp
/// against the same epoch without sharing a handle.
#[derive(Clone, Copy)]
pub struct RealClock {
    start: Instant,
}

impl RealClock {
    pub fn new() -> Self {
        RealClock {
            start: Instant::now(),
        }
    }
}

impl Default for RealClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for RealClock {
    fn now(&self) -> Micros {
        self.start.elapsed().as_micros() as Micros
    }
}

/// Virtual clock for the simulator: time advances only when the engine says
/// so. Cloneable handle (Arc inside) so the engine, scheduler and workers
/// share one timeline.
#[derive(Clone)]
pub struct VirtualClock {
    now: Arc<AtomicU64>,
}

impl VirtualClock {
    pub fn new() -> Self {
        VirtualClock {
            now: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Advance to an absolute time; must be monotonic (panics on regress in
    /// debug builds; saturates in release).
    pub fn advance_to(&self, t: Micros) {
        let prev = self.now.swap(t, Ordering::SeqCst);
        debug_assert!(prev <= t, "virtual clock moved backwards: {prev} -> {t}");
    }
}

impl Default for VirtualClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> Micros {
        self.now.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_conversions() {
        assert_eq!(ms_to_us(1.5), 1500);
        assert_eq!(ms_to_us(0.0), 0);
        assert!((us_to_ms(2500) - 2.5).abs() < 1e-12);
        assert_eq!(ms_to_us(-1.0), 0); // clamped
    }

    #[test]
    fn real_clock_monotonic() {
        let c = RealClock::new();
        let a = c.now();
        let b = c.now();
        assert!(b >= a);
    }

    #[test]
    fn shared_handles_read_the_same_timeline() {
        fn read<C: Clock>(c: C) -> Micros {
            c.now()
        }
        let c = Arc::new(VirtualClock::new());
        c.advance_to(42);
        assert_eq!(read(c.clone()), 42); // Arc<C> impl
        assert_eq!(read(&*c), 42); // &C impl
    }

    #[test]
    fn virtual_clock_advances() {
        let c = VirtualClock::new();
        assert_eq!(c.now(), 0);
        c.advance_to(100);
        assert_eq!(c.now(), 100);
        let c2 = c.clone();
        c2.advance_to(250);
        assert_eq!(c.now(), 250); // shared timeline
    }
}
