//! Dynamic upper convex hull — Overmars & van Leeuwen (1981), the priority
//! queue at the heart of Orloj (paper §4.4, §5.1, Fig. 12).
//!
//! Requests map to 2-D points `(α, β)` whose score at time `t` is
//! `α·e^{bt} + β`; the top-priority request is the point maximizing that
//! linear functional, which lies on the upper hull. The hull must support
//! online insertion *and deletion* as requests arrive, get scheduled, or
//! time out.
//!
//! Structure: a weight-balanced (scapegoat-style, rebuild-on-imbalance)
//! binary tree over points in chain order. Each node `v` owns a
//! concatenable queue `Q(v)` holding the part of its subtree's hull that is
//! *not* on its parent's hull; the root owns the full hull. Descending
//! (`down`) re-materializes children's hulls by splitting `Q(v)` at the
//! bridge; ascending (`up`) finds the bridge between the children's hulls
//! (nested binary search over both chains) and passes the outer parts up.
//! Updates touch O(log n) nodes; each bridge search costs O(log² n), giving
//! O(log³ n) worst-case per update with the simple tangent search (the
//! original paper's 9-case simultaneous descent achieves O(log² n); at the
//! queue depths the serving workloads reach — 10⁴ requests, Fig. 12 — the
//! measured difference is constant-factor noise, see the bench).

pub mod cqueue;
pub mod point;

use cqueue::{CQueue, Step};
use point::{cross, Point};

/// Weight-balance threshold: rebuild a subtree when one child exceeds this
/// fraction of the subtree size.
const ALPHA: f64 = 0.72;

#[derive(Debug)]
enum Kind {
    Leaf(Point),
    Internal {
        left: Box<HNode>,
        right: Box<HNode>,
        /// Max point of the left subtree (routing key).
        split_key: Point,
        /// Number of points of this node's hull contributed by the left
        /// child (the split position used by `down`).
        left_cnt: usize,
    },
}

#[derive(Debug)]
struct HNode {
    size: usize,
    /// The materialized part of this subtree's hull (full hull when this
    /// node is the "highest materialized" node on its path).
    q: CQueue,
    kind: Kind,
}

impl HNode {
    fn leaf(p: Point) -> Box<HNode> {
        Box::new(HNode {
            size: 1,
            q: CQueue::singleton(p),
            kind: Kind::Leaf(p),
        })
    }

    fn max_leaf(&self) -> Point {
        match &self.kind {
            Kind::Leaf(p) => *p,
            Kind::Internal { right, .. } => right.max_leaf(),
        }
    }

    fn collect_points(&self, out: &mut Vec<Point>) {
        match &self.kind {
            Kind::Leaf(p) => out.push(*p),
            Kind::Internal { left, right, .. } => {
                left.collect_points(out);
                right.collect_points(out);
            }
        }
    }
}

/// Upper common tangent point on chain `v_chain` as seen from external
/// point `p` (p lies strictly left or right of the chain in x): the point
/// `q` such that no chain point is strictly above line(p, q).
fn tangent_from(p: &Point, chain: &CQueue) -> Point {
    chain
        .descend(|v, prev, next| {
            if let Some(s) = next {
                if cross(p, v, s) > 0.0 {
                    return Step::Right;
                }
            }
            if let Some(q) = prev {
                if cross(p, v, q) > 0.0 {
                    return Step::Left;
                }
            }
            Step::Stop
        })
        .expect("tangent_from on empty chain")
}

/// Find the upper bridge between two x-ordered hull chains
/// (all points of `u_chain` precede all points of `v_chain`).
fn find_bridge(u_chain: &CQueue, v_chain: &CQueue) -> (Point, Point) {
    let u = u_chain
        .descend(|u, prev, next| {
            let q = tangent_from(u, v_chain);
            if let Some(s) = next {
                if cross(u, &q, s) > 0.0 {
                    return Step::Right;
                }
            }
            if let Some(p) = prev {
                if cross(u, &q, p) > 0.0 {
                    return Step::Left;
                }
            }
            Step::Stop
        })
        .expect("find_bridge on empty left chain");
    let v = tangent_from(&u, v_chain);
    (u, v)
}

/// Materialize both children's hulls from a node in "up" state.
fn down(v: &mut HNode) {
    if let Kind::Internal {
        left,
        right,
        left_cnt,
        ..
    } = &mut v.kind
    {
        let q = std::mem::take(&mut v.q);
        let (a, b) = q.split_at(*left_cnt);
        let lq = std::mem::take(&mut left.q);
        left.q = a.join(lq);
        let rq = std::mem::take(&mut right.q);
        right.q = rq.join(b);
    }
}

/// Recompute this node's hull from its (materialized) children.
fn up(v: &mut HNode) {
    if let Kind::Internal {
        left,
        right,
        left_cnt,
        ..
    } = &mut v.kind
    {
        let hl = std::mem::take(&mut left.q);
        let hr = std::mem::take(&mut right.q);
        debug_assert!(!hl.is_empty() && !hr.is_empty(), "children must be materialized");
        let (bl, br) = find_bridge(&hl, &hr);
        let (a, a_rest) = hl.split_by(&bl, true);
        let (b_rest, b) = hr.split_by(&br, false);
        left.q = a_rest;
        right.q = b_rest;
        *left_cnt = a.len();
        v.q = a.join(b);
    }
}

/// Rebuild a subtree into perfect balance. The node must be in "up" state
/// (owning its full hull); descendants' queues are recomputed from scratch.
fn rebuild(v: Box<HNode>) -> Box<HNode> {
    let mut pts = Vec::with_capacity(v.size);
    v.collect_points(&mut pts);
    build_balanced(&pts)
}

fn build_balanced(pts: &[Point]) -> Box<HNode> {
    debug_assert!(!pts.is_empty());
    if pts.len() == 1 {
        return HNode::leaf(pts[0]);
    }
    let mid = pts.len() / 2;
    let left = build_balanced(&pts[..mid]);
    let right = build_balanced(&pts[mid..]);
    let mut node = Box::new(HNode {
        size: pts.len(),
        q: CQueue::new(),
        kind: Kind::Internal {
            split_key: pts[mid - 1],
            left,
            right,
            left_cnt: 0,
        },
    });
    up(&mut node);
    node
}

fn unbalanced(v: &HNode) -> bool {
    if let Kind::Internal { left, right, .. } = &v.kind {
        let n = v.size as f64;
        n > 4.0 && (left.size as f64 > ALPHA * n || right.size as f64 > ALPHA * n)
    } else {
        false
    }
}

/// The dynamic upper hull / kinetic priority queue.
#[derive(Debug, Default)]
pub struct DynamicHull {
    root: Option<Box<HNode>>,
}

impl DynamicHull {
    pub fn new() -> DynamicHull {
        DynamicHull { root: None }
    }

    pub fn len(&self) -> usize {
        self.root.as_ref().map(|r| r.size).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.root.is_none()
    }

    /// Insert a point. Points must be unique in (x, y, id); the caller
    /// (the request priority queue) guarantees unique ids.
    pub fn insert(&mut self, p: Point) {
        self.root = Some(match self.root.take() {
            None => HNode::leaf(p),
            Some(r) => Self::insert_rec(r, p),
        });
    }

    fn insert_rec(mut v: Box<HNode>, p: Point) -> Box<HNode> {
        match v.kind {
            Kind::Leaf(old) => {
                let (first, second) = if p.key_cmp(&old) == std::cmp::Ordering::Less {
                    (p, old)
                } else {
                    (old, p)
                };
                let mut node = Box::new(HNode {
                    size: 2,
                    q: CQueue::new(),
                    kind: Kind::Internal {
                        split_key: first,
                        left: HNode::leaf(first),
                        right: HNode::leaf(second),
                        left_cnt: 0,
                    },
                });
                up(&mut node);
                node
            }
            Kind::Internal { .. } => {
                down(&mut v);
                if let Kind::Internal {
                    left,
                    right,
                    split_key,
                    ..
                } = &mut v.kind
                {
                    if p.key_cmp(split_key) != std::cmp::Ordering::Greater {
                        let l = std::mem::replace(left, HNode::leaf(p));
                        *left = Self::insert_rec(l, p);
                    } else {
                        let r = std::mem::replace(right, HNode::leaf(p));
                        *right = Self::insert_rec(r, p);
                    }
                    v.size = left.size + right.size;
                }
                up(&mut v);
                if unbalanced(&v) {
                    v = rebuild(v);
                }
                v
            }
        }
    }

    /// Delete a point (exact (x, y, id) match). Returns whether it was
    /// found.
    pub fn delete(&mut self, p: &Point) -> bool {
        let mut found = false;
        self.root = match self.root.take() {
            None => None,
            Some(r) => Self::delete_rec(r, p, &mut found),
        };
        found
    }

    fn delete_rec(mut v: Box<HNode>, p: &Point, found: &mut bool) -> Option<Box<HNode>> {
        match v.kind {
            Kind::Leaf(pt) => {
                if pt.key_cmp(p) == std::cmp::Ordering::Equal {
                    *found = true;
                    None
                } else {
                    Some(v)
                }
            }
            Kind::Internal { .. } => {
                down(&mut v);
                let mut replaced: Option<Box<HNode>> = None;
                if let Kind::Internal {
                    left,
                    right,
                    split_key,
                    ..
                } = &mut v.kind
                {
                    if p.key_cmp(split_key) != std::cmp::Ordering::Greater {
                        let l = std::mem::replace(left, HNode::leaf(*p));
                        match Self::delete_rec(l, p, found) {
                            None => {
                                // Left child vanished: promote right (it is
                                // materialized after `down`).
                                let r = std::mem::replace(right, HNode::leaf(*p));
                                replaced = Some(r);
                            }
                            Some(nl) => {
                                *left = nl;
                                *split_key = left.max_leaf();
                            }
                        }
                    } else {
                        let r = std::mem::replace(right, HNode::leaf(*p));
                        match Self::delete_rec(r, p, found) {
                            None => {
                                let l = std::mem::replace(left, HNode::leaf(*p));
                                replaced = Some(l);
                            }
                            Some(nr) => {
                                *right = nr;
                            }
                        }
                    }
                    if replaced.is_none() {
                        v.size = left.size + right.size;
                    }
                }
                match replaced {
                    Some(child) => Some(child),
                    None => {
                        up(&mut v);
                        if unbalanced(&v) {
                            v = rebuild(v);
                        }
                        Some(v)
                    }
                }
            }
        }
    }

    /// The point maximizing `m·x + y` (the highest-priority request when
    /// `m = e^{bt}`), in O(log n).
    pub fn query_max(&self, m: f64) -> Option<Point> {
        let root = self.root.as_ref()?;
        root.q.descend(|p, prev, next| {
            let f = p.eval(m);
            if let Some(nx) = next {
                if nx.eval(m) > f {
                    return Step::Right;
                }
            }
            if let Some(pv) = prev {
                if pv.eval(m) > f {
                    return Step::Left;
                }
            }
            Step::Stop
        })
    }

    /// Current hull chain (root's queue), for tests and diagnostics.
    pub fn hull_points(&self) -> Vec<Point> {
        self.root.as_ref().map(|r| r.q.to_vec()).unwrap_or_default()
    }

    /// All stored points in chain order (O(n)).
    pub fn all_points(&self) -> Vec<Point> {
        let mut out = Vec::with_capacity(self.len());
        if let Some(r) = &self.root {
            r.collect_points(&mut out);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::point::upper_hull_naive;
    use super::*;
    use crate::util::rng::Rng;

    fn assert_matches_naive(hull: &DynamicHull, pts: &[Point], ms: &[f64]) {
        if pts.is_empty() {
            assert!(hull.is_empty());
            return;
        }
        let naive = upper_hull_naive(pts);
        for &m in ms {
            let best_naive = naive.iter().map(|p| p.eval(m)).fold(f64::MIN, f64::max);
            let got = hull.query_max(m).expect("hull nonempty");
            let diff = (got.eval(m) - best_naive).abs();
            assert!(
                diff <= 1e-9 * (1.0 + best_naive.abs()),
                "m={m}: got {} want {} (n={})",
                got.eval(m),
                best_naive,
                pts.len()
            );
        }
    }

    const QUERY_SLOPES: &[f64] = &[0.0, 0.001, 0.1, 0.5, 1.0, 2.0, 10.0, 1000.0];

    #[test]
    fn insert_only_matches_naive() {
        let mut rng = Rng::new(11);
        let mut hull = DynamicHull::new();
        let mut pts = Vec::new();
        for i in 0..300u64 {
            let p = Point::new(rng.f64() * 100.0 - 50.0, rng.f64() * 100.0 - 50.0, i);
            hull.insert(p);
            pts.push(p);
            if i % 17 == 0 {
                assert_matches_naive(&hull, &pts, QUERY_SLOPES);
            }
        }
        assert_eq!(hull.len(), 300);
        assert_matches_naive(&hull, &pts, QUERY_SLOPES);
    }

    #[test]
    fn insert_delete_stress() {
        let mut rng = Rng::new(13);
        for trial in 0..8 {
            let mut hull = DynamicHull::new();
            let mut pts: Vec<Point> = Vec::new();
            let mut next_id = 0u64;
            for op in 0..600 {
                if pts.is_empty() || rng.f64() < 0.6 {
                    let p = Point::new(
                        rng.f64() * 200.0 - 100.0,
                        rng.f64() * 200.0 - 100.0,
                        next_id,
                    );
                    next_id += 1;
                    hull.insert(p);
                    pts.push(p);
                } else {
                    let idx = rng.index(pts.len());
                    let p = pts.swap_remove(idx);
                    assert!(hull.delete(&p), "trial {trial} op {op}: delete failed");
                }
                assert_eq!(hull.len(), pts.len());
                if op % 37 == 0 {
                    assert_matches_naive(&hull, &pts, QUERY_SLOPES);
                }
            }
            assert_matches_naive(&hull, &pts, QUERY_SLOPES);
        }
    }

    #[test]
    fn delete_to_empty_and_reuse() {
        let mut hull = DynamicHull::new();
        let pts: Vec<Point> = (0..20)
            .map(|i| Point::new(i as f64, (i as f64 * 0.7).sin() * 5.0, i as u64))
            .collect();
        for p in &pts {
            hull.insert(*p);
        }
        for p in &pts {
            assert!(hull.delete(p));
        }
        assert!(hull.is_empty());
        assert_eq!(hull.query_max(1.0), None);
        hull.insert(Point::new(3.0, 4.0, 99));
        assert_eq!(hull.query_max(1.0).unwrap().id, 99);
    }

    #[test]
    fn delete_missing_returns_false() {
        let mut hull = DynamicHull::new();
        hull.insert(Point::new(1.0, 1.0, 1));
        assert!(!hull.delete(&Point::new(1.0, 1.0, 2)));
        assert_eq!(hull.len(), 1);
    }

    #[test]
    fn collinear_and_duplicate_coordinates() {
        let mut hull = DynamicHull::new();
        let mut pts = Vec::new();
        // Grid with many collinear triples and repeated x.
        let mut id = 0u64;
        for i in 0..10 {
            for j in 0..5 {
                let p = Point::new(i as f64, j as f64, id);
                id += 1;
                hull.insert(p);
                pts.push(p);
            }
        }
        assert_matches_naive(&hull, &pts, QUERY_SLOPES);
        // Delete the top row; hull should fall to the next row.
        let mut remaining = Vec::new();
        for p in &pts {
            if p.y == 4.0 {
                assert!(hull.delete(p));
            } else {
                remaining.push(*p);
            }
        }
        assert_matches_naive(&hull, &remaining, QUERY_SLOPES);
    }

    #[test]
    fn clustered_points_stress() {
        // Near-identical α values (requests with identical deadlines) are
        // the degenerate case the scheduler actually produces.
        let mut rng = Rng::new(17);
        let mut hull = DynamicHull::new();
        let mut pts = Vec::new();
        for i in 0..400u64 {
            let cluster = (i % 5) as f64;
            let p = Point::new(
                cluster + rng.f64() * 1e-9,
                rng.f64() * 10.0,
                i,
            );
            hull.insert(p);
            pts.push(p);
        }
        assert_matches_naive(&hull, &pts, QUERY_SLOPES);
        for i in (0..pts.len()).rev().step_by(3) {
            let p = pts.swap_remove(i);
            assert!(hull.delete(&p));
        }
        assert_matches_naive(&hull, &pts, QUERY_SLOPES);
    }

    #[test]
    fn hull_points_are_a_superset_maximizers() {
        // Every maximizer over a sweep of slopes must be on the reported
        // hull chain.
        let mut rng = Rng::new(23);
        let mut hull = DynamicHull::new();
        let mut pts = Vec::new();
        for i in 0..200u64 {
            let p = Point::new(rng.normal() * 10.0, rng.normal() * 10.0, i);
            hull.insert(p);
            pts.push(p);
        }
        let chain = hull.hull_points();
        for &m in QUERY_SLOPES {
            let q = hull.query_max(m).unwrap();
            assert!(
                chain.iter().any(|c| c.id == q.id),
                "maximizer for m={m} not on chain"
            );
        }
        // Chain must be in strictly increasing key order.
        for w in chain.windows(2) {
            assert_eq!(w[0].key_cmp(&w[1]), std::cmp::Ordering::Less);
        }
    }

    #[test]
    fn sorted_insertion_order() {
        // Monotone insertion (common when deadlines arrive in order) must
        // stay balanced (implicitly: this would blow the stack / time out
        // if the scapegoat rebuilds were broken).
        let mut hull = DynamicHull::new();
        let mut pts = Vec::new();
        for i in 0..2000u64 {
            let p = Point::new(i as f64, ((i * 7919) % 100) as f64, i);
            hull.insert(p);
            pts.push(p);
        }
        assert_matches_naive(&hull, &pts, QUERY_SLOPES);
    }
}
