//! Points and orientation predicates for the dynamic hull (paper §4.4).
//!
//! A request's priority segment `p(t) = α e^{bt} + β` maps to the 2-D point
//! `(α, β)`; the highest-priority request at time `t` is the point
//! maximizing the linear functional `e^{bt}·x + y`, which always lies on
//! the upper convex hull.

use std::cmp::Ordering;

/// A hull point: coordinates plus a stable id (the request id) so
//// duplicates are distinguishable and deletions are exact.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Point {
    pub x: f64,
    pub y: f64,
    pub id: u64,
}

impl Point {
    pub fn new(x: f64, y: f64, id: u64) -> Point {
        debug_assert!(x.is_finite() && y.is_finite());
        Point { x, y, id }
    }

    /// Total chain order: (x, y, id) lexicographic. The outer hull tree and
    /// the hull chains share this order.
    pub fn key_cmp(&self, other: &Point) -> Ordering {
        self.x
            .total_cmp(&other.x)
            .then(self.y.total_cmp(&other.y))
            .then(self.id.cmp(&other.id))
    }

    /// Value of the query functional `m·x + y`.
    #[inline]
    pub fn eval(&self, m: f64) -> f64 {
        m * self.x + self.y
    }
}

/// Cross product (a−o) × (b−o): > 0 iff o→a→b turns counter-clockwise,
/// i.e. b lies strictly above the directed line o→a (for o.x < a.x).
#[inline]
pub fn cross(o: &Point, a: &Point, b: &Point) -> f64 {
    (a.x - o.x) * (b.y - o.y) - (a.y - o.y) * (b.x - o.x)
}

/// Is `w` strictly above the line through `u` and `v` (u before v in chain
/// order)?
#[inline]
pub fn above(u: &Point, v: &Point, w: &Point) -> bool {
    cross(u, v, w) > 0.0
}

/// Build the upper hull of a point set by monotone chain — the O(n log n)
/// reference implementation used by tests and rebuilds. Input order is
/// arbitrary; output is in increasing chain order. Collinear interior
/// points are dropped.
pub fn upper_hull_naive(points: &[Point]) -> Vec<Point> {
    let mut pts = points.to_vec();
    pts.sort_by(Point::key_cmp);
    pts.dedup_by(|a, b| a.key_cmp(b) == Ordering::Equal);
    let mut hull: Vec<Point> = Vec::new();
    for p in pts {
        while hull.len() >= 2 {
            let a = hull[hull.len() - 2];
            let b = hull[hull.len() - 1];
            // Keep b only if it turns strictly right (clockwise) at b:
            // cross(a, b, p) < 0. Drop collinear (== 0).
            if cross(&a, &b, &p) >= 0.0 {
                hull.pop();
            } else {
                break;
            }
        }
        // Equal-x handling: upper hull keeps only the highest point per x.
        if let Some(last) = hull.last() {
            if last.x == p.x {
                if last.y <= p.y {
                    hull.pop();
                } else {
                    continue;
                }
            }
        }
        hull.push(p);
    }
    hull
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p(x: f64, y: f64) -> Point {
        Point::new(x, y, (x.to_bits() >> 1) ^ y.to_bits())
    }

    #[test]
    fn cross_signs() {
        let o = p(0.0, 0.0);
        let a = p(1.0, 0.0);
        assert!(cross(&o, &a, &p(0.5, 1.0)) > 0.0); // above
        assert!(cross(&o, &a, &p(0.5, -1.0)) < 0.0); // below
        assert_eq!(cross(&o, &a, &p(2.0, 0.0)), 0.0); // collinear
    }

    #[test]
    fn key_cmp_total_order() {
        let a = Point::new(1.0, 2.0, 1);
        let b = Point::new(1.0, 2.0, 2);
        assert_eq!(a.key_cmp(&b), Ordering::Less);
        assert_eq!(a.key_cmp(&a), Ordering::Equal);
        assert_eq!(Point::new(0.5, 9.0, 9).key_cmp(&a), Ordering::Less);
    }

    #[test]
    fn naive_hull_square() {
        let pts = vec![p(0.0, 0.0), p(1.0, 1.0), p(2.0, 0.0), p(1.0, 0.5)];
        let hull = upper_hull_naive(&pts);
        let xs: Vec<f64> = hull.iter().map(|q| q.x).collect();
        assert_eq!(xs, vec![0.0, 1.0, 2.0]);
        assert_eq!(hull[1].y, 1.0);
    }

    #[test]
    fn naive_hull_collinear_dropped() {
        let pts = vec![p(0.0, 0.0), p(1.0, 1.0), p(2.0, 2.0), p(3.0, 3.0)];
        let hull = upper_hull_naive(&pts);
        assert_eq!(hull.len(), 2);
        assert_eq!(hull[0].x, 0.0);
        assert_eq!(hull[1].x, 3.0);
    }

    #[test]
    fn naive_hull_equal_x_keeps_highest() {
        let pts = vec![p(1.0, 0.0), p(1.0, 5.0), p(1.0, 2.0)];
        let hull = upper_hull_naive(&pts);
        assert_eq!(hull.len(), 1);
        assert_eq!(hull[0].y, 5.0);
    }

    #[test]
    fn hull_maximizes_functional() {
        let pts: Vec<Point> = (0..30)
            .map(|i| {
                let x = (i as f64 * 0.37).sin() * 10.0;
                let y = (i as f64 * 0.73).cos() * 10.0;
                Point::new(x, y, i)
            })
            .collect();
        let hull = upper_hull_naive(&pts);
        for m in [0.0, 0.1, 1.0, 5.0, 100.0] {
            let best_all = pts.iter().map(|q| q.eval(m)).fold(f64::MIN, f64::max);
            let best_hull = hull.iter().map(|q| q.eval(m)).fold(f64::MIN, f64::max);
            assert!(
                (best_all - best_hull).abs() < 1e-9 * (1.0 + best_all.abs()),
                "m={m}"
            );
        }
    }
}
