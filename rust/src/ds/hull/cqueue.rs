//! Concatenable queue for hull chains (paper §5.1: "we implemented the
//! inner concatenate queue as a 2-3 tree ...").
//!
//! A hull chain is a sequence of points in chain order supporting
//! `split`/`join` in O(log n) — the operations the Overmars–van Leeuwen
//! hull tree needs to pass sub-chains up and down. We implement it as a
//! join-based balanced tree (a treap with deterministic priorities derived
//! from the point id — functionally equivalent to the paper's 2-3 tree:
//! O(log n) expected split/join with seeded determinism, which record/
//! replay requires). Descent helpers expose each visited node's chain
//! neighbors, which the tangent searches need.

use super::point::Point;
use std::cmp::Ordering;

fn prio(p: &Point) -> u64 {
    // SplitMix64 over the id and coordinate bits: deterministic, well mixed.
    let mut z = p
        .id
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add(p.x.to_bits())
        .wrapping_add(p.y.to_bits().rotate_left(17));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[derive(Debug, Clone)]
struct Node {
    pt: Point,
    prio: u64,
    size: usize,
    /// Cached subtree extremes: O(1) chain-neighbor lookup during descents
    /// (§Perf: replaced per-step spine walks, which made every tangent
    /// search O(log² n) instead of O(log n)).
    min_pt: Point,
    max_pt: Point,
    left: Option<Box<Node>>,
    right: Option<Box<Node>>,
}

impl Node {
    fn new(pt: Point) -> Box<Node> {
        Box::new(Node {
            prio: prio(&pt),
            pt,
            size: 1,
            min_pt: pt,
            max_pt: pt,
            left: None,
            right: None,
        })
    }

    fn update(&mut self) {
        self.size = 1 + size(&self.left) + size(&self.right);
        self.min_pt = self.left.as_ref().map(|l| l.min_pt).unwrap_or(self.pt);
        self.max_pt = self.right.as_ref().map(|r| r.max_pt).unwrap_or(self.pt);
    }
}

fn size(n: &Option<Box<Node>>) -> usize {
    n.as_ref().map(|b| b.size).unwrap_or(0)
}

fn merge(a: Option<Box<Node>>, b: Option<Box<Node>>) -> Option<Box<Node>> {
    match (a, b) {
        (None, b) => b,
        (a, None) => a,
        (Some(mut a), Some(mut b)) => {
            if a.prio >= b.prio {
                a.right = merge(a.right.take(), Some(b));
                a.update();
                Some(a)
            } else {
                b.left = merge(Some(a), b.left.take());
                b.update();
                Some(b)
            }
        }
    }
}

/// Split by count: first `n` elements vs rest.
fn split_count(node: Option<Box<Node>>, n: usize) -> (Option<Box<Node>>, Option<Box<Node>>) {
    match node {
        None => (None, None),
        Some(mut t) => {
            let ls = size(&t.left);
            if n <= ls {
                let (a, b) = split_count(t.left.take(), n);
                t.left = b;
                t.update();
                (a, Some(t))
            } else {
                let (a, b) = split_count(t.right.take(), n - ls - 1);
                t.right = a;
                t.update();
                (Some(t), b)
            }
        }
    }
}

/// Split by key: elements ≤ key (or < key if `inclusive` is false) vs rest.
fn split_key(
    node: Option<Box<Node>>,
    key: &Point,
    inclusive: bool,
) -> (Option<Box<Node>>, Option<Box<Node>>) {
    match node {
        None => (None, None),
        Some(mut t) => {
            let goes_left = match t.pt.key_cmp(key) {
                Ordering::Less => true,
                Ordering::Equal => inclusive,
                Ordering::Greater => false,
            };
            if goes_left {
                let (a, b) = split_key(t.right.take(), key, inclusive);
                t.right = a;
                t.update();
                (Some(t), b)
            } else {
                let (a, b) = split_key(t.left.take(), key, inclusive);
                t.left = b;
                t.update();
                (a, Some(t))
            }
        }
    }
}

/// Direction for a guided descent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Step {
    Left,
    Stop,
    Right,
}

/// A concatenable queue of points in strict chain order.
#[derive(Debug, Clone, Default)]
pub struct CQueue {
    root: Option<Box<Node>>,
}

impl CQueue {
    pub fn new() -> CQueue {
        CQueue { root: None }
    }

    pub fn singleton(pt: Point) -> CQueue {
        CQueue {
            root: Some(Node::new(pt)),
        }
    }

    /// Build from points already in chain order (O(n)).
    pub fn from_sorted(pts: &[Point]) -> CQueue {
        fn build(pts: &[Point]) -> Option<Box<Node>> {
            if pts.is_empty() {
                return None;
            }
            // Treap from sorted order: the max-priority element is the root.
            let mut root_idx = 0;
            let mut best = prio(&pts[0]);
            for (i, p) in pts.iter().enumerate().skip(1) {
                let pr = prio(p);
                if pr > best {
                    best = pr;
                    root_idx = i;
                }
            }
            let mut n = Node::new(pts[root_idx]);
            n.left = build(&pts[..root_idx]);
            n.right = build(&pts[root_idx + 1..]);
            n.update();
            Some(n)
        }
        CQueue { root: build(pts) }
    }

    pub fn len(&self) -> usize {
        size(&self.root)
    }

    pub fn is_empty(&self) -> bool {
        self.root.is_none()
    }

    /// Concatenate: all points of `self` precede all points of `other`.
    pub fn join(self, other: CQueue) -> CQueue {
        debug_assert!(
            self.root.is_none()
                || other.root.is_none()
                || self.last().unwrap().key_cmp(&other.first().unwrap()) == Ordering::Less,
            "join requires disjoint ordered queues"
        );
        CQueue {
            root: merge(self.root, other.root),
        }
    }

    /// Split into (first n, rest).
    pub fn split_at(self, n: usize) -> (CQueue, CQueue) {
        let (a, b) = split_count(self.root, n);
        (CQueue { root: a }, CQueue { root: b })
    }

    /// Split into (≤ key, > key) when inclusive, (< key, ≥ key) otherwise.
    pub fn split_by(self, key: &Point, inclusive: bool) -> (CQueue, CQueue) {
        let (a, b) = split_key(self.root, key, inclusive);
        (CQueue { root: a }, CQueue { root: b })
    }

    pub fn first(&self) -> Option<Point> {
        self.root.as_deref().map(|n| n.min_pt)
    }

    pub fn last(&self) -> Option<Point> {
        self.root.as_deref().map(|n| n.max_pt)
    }

    /// Guided binary-search descent. At each node the callback sees the
    /// node's point and its chain neighbors *within the whole queue*
    /// (predecessor, successor) and returns which way to go. Returns the
    /// point where the descent stopped (or the last node visited if it
    /// runs off a nil edge — the chain is convex so this is the optimum for
    /// monotone predicates).
    pub fn descend<F>(&self, mut f: F) -> Option<Point>
    where
        F: FnMut(&Point, Option<&Point>, Option<&Point>) -> Step,
    {
        let mut cur = self.root.as_deref()?;
        // Inherited neighbors from ancestors.
        let mut inh_pred: Option<Point> = None;
        let mut inh_succ: Option<Point> = None;
        loop {
            let local_pred = cur.left.as_deref().map(|l| l.max_pt).or(inh_pred);
            let local_succ = cur.right.as_deref().map(|r| r.min_pt).or(inh_succ);
            match f(&cur.pt, local_pred.as_ref(), local_succ.as_ref()) {
                Step::Stop => return Some(cur.pt),
                Step::Left => match cur.left.as_deref() {
                    Some(l) => {
                        inh_succ = Some(cur.pt);
                        cur = l;
                    }
                    None => return Some(cur.pt),
                },
                Step::Right => match cur.right.as_deref() {
                    Some(r) => {
                        inh_pred = Some(cur.pt);
                        cur = r;
                    }
                    None => return Some(cur.pt),
                },
            }
        }
    }

    /// In-order contents (for tests / rebuilds).
    pub fn to_vec(&self) -> Vec<Point> {
        fn walk(n: &Option<Box<Node>>, out: &mut Vec<Point>) {
            if let Some(b) = n {
                walk(&b.left, out);
                out.push(b.pt);
                walk(&b.right, out);
            }
        }
        let mut out = Vec::with_capacity(self.len());
        walk(&self.root, &mut out);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn pts(n: usize, seed: u64) -> Vec<Point> {
        let mut rng = Rng::new(seed);
        let mut v: Vec<Point> = (0..n)
            .map(|i| Point::new(rng.f64() * 100.0, rng.f64() * 100.0, i as u64))
            .collect();
        v.sort_by(Point::key_cmp);
        v
    }

    #[test]
    fn from_sorted_roundtrip() {
        let v = pts(100, 1);
        let q = CQueue::from_sorted(&v);
        assert_eq!(q.len(), 100);
        assert_eq!(q.to_vec(), v);
        assert_eq!(q.first().unwrap().key_cmp(&v[0]), Ordering::Equal);
        assert_eq!(q.last().unwrap().key_cmp(&v[99]), Ordering::Equal);
    }

    #[test]
    fn split_at_and_join() {
        let v = pts(50, 2);
        let q = CQueue::from_sorted(&v);
        for n in [0usize, 1, 10, 25, 49, 50] {
            let (a, b) = q.clone().split_at(n);
            assert_eq!(a.len(), n);
            assert_eq!(b.len(), 50 - n);
            assert_eq!(a.to_vec(), &v[..n]);
            assert_eq!(b.to_vec(), &v[n..]);
            let joined = a.join(b);
            assert_eq!(joined.to_vec(), v);
        }
    }

    #[test]
    fn split_by_key() {
        let v = pts(60, 3);
        let q = CQueue::from_sorted(&v);
        let key = v[30];
        let (a, b) = q.clone().split_by(&key, true);
        assert_eq!(a.len(), 31);
        assert_eq!(b.len(), 29);
        let (c, d) = q.clone().split_by(&key, false);
        assert_eq!(c.len(), 30);
        assert_eq!(d.len(), 30);
        // Key absent from the queue: splits around it.
        let ghost = Point::new(key.x, key.y, u64::MAX);
        let (e, f) = q.clone().split_by(&ghost, true);
        assert_eq!(e.len() + f.len(), 60);
    }

    #[test]
    fn descend_finds_maximum_of_unimodal() {
        // A concave sequence of y values: descend should find the peak.
        let v: Vec<Point> = (0..101)
            .map(|i| {
                let x = i as f64;
                Point::new(x, -(x - 37.0) * (x - 37.0), i as u64)
            })
            .collect();
        let q = CQueue::from_sorted(&v);
        let peak = q
            .descend(|p, _prev, next| {
                if let Some(nx) = next {
                    if nx.y > p.y {
                        return Step::Right;
                    }
                }
                // move left if prev is better
                Step::Stop
            })
            .unwrap();
        // one-sided walk may stop early at a local right-edge; use both sides
        let peak2 = q
            .descend(|p, prev, next| {
                if let Some(nx) = next {
                    if nx.y > p.y {
                        return Step::Right;
                    }
                }
                if let Some(pv) = prev {
                    if pv.y > p.y {
                        return Step::Left;
                    }
                }
                Step::Stop
            })
            .unwrap();
        assert_eq!(peak2.x, 37.0, "two-sided descent finds the peak");
        let _ = peak;
    }

    #[test]
    fn descend_neighbors_are_chain_neighbors() {
        let v = pts(64, 5);
        let q = CQueue::from_sorted(&v);
        // Stop at every element via split-points and verify neighbor pair.
        for (i, target) in v.iter().enumerate() {
            let mut seen = None;
            q.descend(|p, prev, next| {
                match p.key_cmp(target) {
                    Ordering::Equal => {
                        seen = Some((prev.copied(), next.copied()));
                        Step::Stop
                    }
                    Ordering::Less => Step::Right,
                    Ordering::Greater => Step::Left,
                }
            });
            let (prev, next) = seen.expect("target found");
            if i == 0 {
                assert!(prev.is_none());
            } else {
                assert_eq!(prev.unwrap().key_cmp(&v[i - 1]), Ordering::Equal);
            }
            if i == 63 {
                assert!(next.is_none());
            } else {
                assert_eq!(next.unwrap().key_cmp(&v[i + 1]), Ordering::Equal);
            }
        }
    }

    #[test]
    fn randomized_split_join_stress() {
        let mut rng = Rng::new(9);
        let v = pts(200, 10);
        let mut q = CQueue::from_sorted(&v);
        for _ in 0..100 {
            let n = rng.index(q.len() + 1);
            let (a, b) = q.split_at(n);
            assert_eq!(a.len(), n);
            q = a.join(b);
            assert_eq!(q.len(), 200);
        }
        assert_eq!(q.to_vec(), v);
    }

    #[test]
    fn empty_queue_behaviour() {
        let q = CQueue::new();
        assert!(q.is_empty());
        assert_eq!(q.first(), None);
        assert_eq!(q.descend(|_, _, _| Step::Stop), None);
        let (a, b) = q.split_at(0);
        assert!(a.is_empty() && b.is_empty());
    }
}
