//! Data-structure substrates: the Fibonacci heap (per-batch-size deadline
//! tracking) and the Overmars–van Leeuwen dynamic convex hull (the
//! time-varying priority queue), plus a naive scan-based queue used as a
//! correctness oracle and benchmark baseline.

pub mod fibheap;
pub mod hull;
pub mod naive;
