//! Naive O(n) priority queue over (α, β) points — the correctness oracle
//! for the dynamic hull and the "re-sort every iteration" baseline the
//! paper argues against (§4.4: "the naive implementation is not scalable").
//! Used in differential tests and as the comparison series in the Fig. 12
//! bench.

use super::hull::point::Point;

#[derive(Debug, Default)]
pub struct NaiveMaxQueue {
    points: Vec<Point>,
}

impl NaiveMaxQueue {
    pub fn new() -> Self {
        NaiveMaxQueue { points: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn insert(&mut self, p: Point) {
        self.points.push(p);
    }

    /// O(n) delete by id.
    pub fn delete(&mut self, p: &Point) -> bool {
        match self.points.iter().position(|q| q.id == p.id) {
            Some(i) => {
                self.points.swap_remove(i);
                true
            }
            None => false,
        }
    }

    /// O(n) arg-max of `m·x + y`.
    pub fn query_max(&self, m: f64) -> Option<Point> {
        self.points
            .iter()
            .copied()
            .max_by(|a, b| a.eval(m).partial_cmp(&b.eval(m)).unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_ops() {
        let mut q = NaiveMaxQueue::new();
        assert!(q.is_empty());
        q.insert(Point::new(0.0, 5.0, 1));
        q.insert(Point::new(2.0, 0.0, 2));
        assert_eq!(q.query_max(0.1).unwrap().id, 1); // 0.2 vs 5
        assert_eq!(q.query_max(10.0).unwrap().id, 2); // 20 vs 5
        assert!(q.delete(&Point::new(2.0, 0.0, 2)));
        assert!(!q.delete(&Point::new(2.0, 0.0, 2)));
        assert_eq!(q.len(), 1);
    }
}
