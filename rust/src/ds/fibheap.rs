//! Fibonacci heap (paper §3.2: "The earliest deadline for requests in
//! `Q_bs` is tracked by an additional Fibonacci heap to allow online
//! deletion").
//!
//! Arena-based implementation with stable handles: `insert` O(1),
//! `min` O(1), `pop_min` O(log n) amortized, `decrease_key` O(1) amortized,
//! `delete(handle)` O(log n) amortized. Keys are `u64` (deadlines in µs);
//! payloads are generic.

/// Stable handle to a heap entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Handle(usize);

struct Node<T> {
    key: u64,
    value: Option<T>,
    parent: Option<usize>,
    child: Option<usize>,
    left: usize,
    right: usize,
    degree: u32,
    marked: bool,
    /// In-use flag; freed nodes go on the free list.
    live: bool,
}

pub struct FibHeap<T> {
    nodes: Vec<Node<T>>,
    free: Vec<usize>,
    min: Option<usize>,
    len: usize,
    /// Reusable consolidate scratch (§Perf): the root list snapshot and the
    /// by-degree table were previously allocated fresh on every `pop_min` /
    /// `delete`; keeping them on the heap makes warm pops allocation-free.
    scratch_roots: Vec<usize>,
    scratch_by_deg: Vec<Option<usize>>,
}

impl<T> Default for FibHeap<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> FibHeap<T> {
    pub fn new() -> Self {
        FibHeap {
            nodes: Vec::new(),
            free: Vec::new(),
            min: None,
            len: 0,
            scratch_roots: Vec::new(),
            scratch_by_deg: Vec::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    fn alloc(&mut self, key: u64, value: T) -> usize {
        let node = Node {
            key,
            value: Some(value),
            parent: None,
            child: None,
            left: 0,
            right: 0,
            degree: 0,
            marked: false,
            live: true,
        };
        match self.free.pop() {
            Some(i) => {
                self.nodes[i] = node;
                i
            }
            None => {
                self.nodes.push(node);
                self.nodes.len() - 1
            }
        }
    }

    /// Insert a (key, value); returns a stable handle for later delete.
    pub fn insert(&mut self, key: u64, value: T) -> Handle {
        let i = self.alloc(key, value);
        self.nodes[i].left = i;
        self.nodes[i].right = i;
        self.splice_into_roots(i);
        if self.nodes[self.min.unwrap()].key > key {
            self.min = Some(i);
        }
        self.len += 1;
        Handle(i)
    }

    /// Current minimum (key, &value).
    pub fn min(&self) -> Option<(u64, &T)> {
        self.min
            .map(|i| (self.nodes[i].key, self.nodes[i].value.as_ref().unwrap()))
    }

    /// Minimum key only.
    pub fn min_key(&self) -> Option<u64> {
        self.min.map(|i| self.nodes[i].key)
    }

    /// Splice node `i` (a valid 1-element or larger circular list root)
    /// into the root list. Sets min if heap was empty.
    fn splice_into_roots(&mut self, i: usize) {
        match self.min {
            None => {
                self.nodes[i].left = i;
                self.nodes[i].right = i;
                self.min = Some(i);
            }
            Some(m) => {
                // Insert i to the right of m.
                let r = self.nodes[m].right;
                self.nodes[i].left = m;
                self.nodes[i].right = r;
                self.nodes[m].right = i;
                self.nodes[r].left = i;
            }
        }
        self.nodes[i].parent = None;
    }

    /// Remove node i from its sibling ring (does not touch parent.child
    /// unless instructed).
    fn unlink(&mut self, i: usize) {
        let l = self.nodes[i].left;
        let r = self.nodes[i].right;
        self.nodes[l].right = r;
        self.nodes[r].left = l;
        self.nodes[i].left = i;
        self.nodes[i].right = i;
    }

    /// Pop the minimum entry.
    pub fn pop_min(&mut self) -> Option<(u64, T)> {
        let m = self.min?;
        // Promote children to roots.
        if let Some(c) = self.nodes[m].child {
            let mut cur = c;
            loop {
                let next = self.nodes[cur].right;
                self.nodes[cur].parent = None;
                self.nodes[cur].marked = false;
                if next == cur {
                    // single child: will exit after splice
                    self.unlink(cur);
                    self.splice_into_roots(cur);
                    break;
                }
                self.unlink(cur);
                self.splice_into_roots(cur);
                if next == c {
                    break;
                }
                cur = next;
            }
        }
        self.nodes[m].child = None;
        // Remove m from root list.
        let only = self.nodes[m].right == m;
        let succ = self.nodes[m].right;
        self.unlink(m);
        if only {
            self.min = None;
        } else {
            self.min = Some(succ);
            self.consolidate();
        }
        self.len -= 1;
        let key = self.nodes[m].key;
        let value = self.nodes[m].value.take().unwrap();
        self.nodes[m].live = false;
        self.free.push(m);
        Some((key, value))
    }

    // Index loops: the body mutates `self.nodes` while walking the scratch
    // buffers, which iterators would hold borrowed.
    #[allow(clippy::needless_range_loop)]
    fn consolidate(&mut self) {
        let max_deg = (64 - (self.len.max(1) as u64).leading_zeros()) as usize + 2;
        // Collect roots first (the ring is mutated during linking), into
        // the reusable scratch buffers — no allocation once warm.
        let start = match self.min {
            Some(m) => m,
            None => return,
        };
        self.scratch_by_deg.clear();
        self.scratch_by_deg.resize(max_deg + 2, None);
        self.scratch_roots.clear();
        let mut cur = start;
        loop {
            self.scratch_roots.push(cur);
            cur = self.nodes[cur].right;
            if cur == start {
                break;
            }
        }
        for ri in 0..self.scratch_roots.len() {
            let mut x = self.scratch_roots[ri];
            // x may have been linked under another root already.
            if self.nodes[x].parent.is_some() {
                continue;
            }
            let mut d = self.nodes[x].degree as usize;
            while let Some(y) = self.scratch_by_deg[d] {
                if y == x {
                    break;
                }
                let (hi, lo) = if self.nodes[x].key <= self.nodes[y].key {
                    (x, y)
                } else {
                    (y, x)
                };
                // Link lo under hi.
                self.unlink(lo);
                self.nodes[lo].parent = Some(hi);
                self.nodes[lo].marked = false;
                match self.nodes[hi].child {
                    None => {
                        self.nodes[hi].child = Some(lo);
                        self.nodes[lo].left = lo;
                        self.nodes[lo].right = lo;
                    }
                    Some(c) => {
                        let r = self.nodes[c].right;
                        self.nodes[lo].left = c;
                        self.nodes[lo].right = r;
                        self.nodes[c].right = lo;
                        self.nodes[r].left = lo;
                    }
                }
                self.nodes[hi].degree += 1;
                self.scratch_by_deg[d] = None;
                x = hi;
                d = self.nodes[x].degree as usize;
            }
            self.scratch_by_deg[d] = Some(x);
        }
        // Recompute min over remaining roots.
        let mut min_idx = None;
        for di in 0..self.scratch_by_deg.len() {
            let Some(root) = self.scratch_by_deg[di] else {
                continue;
            };
            if self.nodes[root].parent.is_none() {
                min_idx = match min_idx {
                    None => Some(root),
                    Some(m) if self.nodes[root].key < self.nodes[m].key => Some(root),
                    keep => keep,
                };
            }
        }
        self.min = min_idx;
    }

    /// Decrease the key of `h` to `new_key` (must be ≤ current key).
    pub fn decrease_key(&mut self, h: Handle, new_key: u64) {
        let i = h.0;
        assert!(self.nodes[i].live, "decrease_key on dead handle");
        assert!(
            new_key <= self.nodes[i].key,
            "decrease_key must not increase the key"
        );
        self.nodes[i].key = new_key;
        if let Some(p) = self.nodes[i].parent {
            if self.nodes[i].key < self.nodes[p].key {
                self.cut(i, p);
                self.cascading_cut(p);
            }
        }
        if self.nodes[i].key < self.nodes[self.min.unwrap()].key {
            self.min = Some(i);
        }
    }

    fn cut(&mut self, i: usize, parent: usize) {
        // Remove i from parent's child ring.
        if self.nodes[parent].child == Some(i) {
            let r = self.nodes[i].right;
            self.nodes[parent].child = if r == i { None } else { Some(r) };
        }
        self.unlink(i);
        self.nodes[parent].degree -= 1;
        self.nodes[i].marked = false;
        self.splice_into_roots(i);
    }

    fn cascading_cut(&mut self, i: usize) {
        if let Some(p) = self.nodes[i].parent {
            if !self.nodes[i].marked {
                self.nodes[i].marked = true;
            } else {
                self.cut(i, p);
                self.cascading_cut(p);
            }
        }
    }

    /// Delete an arbitrary entry by handle (paper: "online deletion").
    pub fn delete(&mut self, h: Handle) -> (u64, T) {
        let i = h.0;
        assert!(self.nodes[i].live, "delete on dead handle");
        // Cut to the root list unconditionally (decrease-to-minus-infinity
        // semantics without relying on key comparisons, which break on
        // ties at the minimum key).
        if let Some(p) = self.nodes[i].parent {
            self.cut(i, p);
            self.cascading_cut(p);
        }
        self.min = Some(i);
        self.pop_min().unwrap()
    }

    /// Key of a live handle.
    pub fn key(&self, h: Handle) -> u64 {
        assert!(self.nodes[h.0].live);
        self.nodes[h.0].key
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use std::collections::BinaryHeap;

    #[test]
    fn insert_and_pop_sorted() {
        let mut h = FibHeap::new();
        for k in [5u64, 3, 8, 1, 9, 2] {
            h.insert(k, k * 10);
        }
        let mut out = Vec::new();
        while let Some((k, v)) = h.pop_min() {
            assert_eq!(v, k * 10);
            out.push(k);
        }
        assert_eq!(out, vec![1, 2, 3, 5, 8, 9]);
    }

    #[test]
    fn min_is_correct_under_mixed_ops() {
        let mut h = FibHeap::new();
        let h5 = h.insert(5, "a");
        h.insert(7, "b");
        assert_eq!(h.min_key(), Some(5));
        h.insert(3, "c");
        assert_eq!(h.min_key(), Some(3));
        h.delete(h5);
        assert_eq!(h.min_key(), Some(3));
        assert_eq!(h.pop_min().unwrap().0, 3);
        assert_eq!(h.min_key(), Some(7));
    }

    #[test]
    fn decrease_key_moves_min() {
        let mut h = FibHeap::new();
        h.insert(10, ());
        let hx = h.insert(20, ());
        h.insert(30, ());
        h.decrease_key(hx, 1);
        assert_eq!(h.min_key(), Some(1));
        assert_eq!(h.pop_min().unwrap().0, 1);
    }

    #[test]
    fn delete_arbitrary() {
        let mut h = FibHeap::new();
        let handles: Vec<_> = (0..20u64).map(|k| h.insert(k, k)).collect();
        // Delete all even keys.
        for (k, hd) in handles.iter().enumerate() {
            if k % 2 == 0 {
                let (_, v) = h.delete(*hd);
                assert_eq!(v, k as u64);
            }
        }
        let mut out = Vec::new();
        while let Some((k, _)) = h.pop_min() {
            out.push(k);
        }
        assert_eq!(out, (0..20u64).filter(|k| k % 2 == 1).collect::<Vec<_>>());
    }

    #[test]
    fn duplicate_keys_ok() {
        let mut h = FibHeap::new();
        for i in 0..10 {
            h.insert(7, i);
        }
        let mut seen = Vec::new();
        while let Some((k, v)) = h.pop_min() {
            assert_eq!(k, 7);
            seen.push(v);
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn slot_reuse_after_delete() {
        let mut h = FibHeap::new();
        let a = h.insert(1, "x");
        h.delete(a);
        let b = h.insert(2, "y");
        // Slot may be reused; the new handle must work.
        assert_eq!(h.key(b), 2);
        assert_eq!(h.pop_min().unwrap().1, "y");
        assert!(h.is_empty());
    }

    #[test]
    fn differential_vs_binary_heap() {
        // Randomized differential test against std BinaryHeap with lazy
        // deletion semantics replicated by explicit handle tracking.
        let mut rng = Rng::new(77);
        for _trial in 0..20 {
            let mut fib = FibHeap::new();
            let mut reference: Vec<(u64, u64)> = Vec::new(); // (key, id)
            let mut handles: Vec<(Handle, u64, u64)> = Vec::new(); // handle, key, id
            let mut next_id = 0u64;
            for _op in 0..400 {
                match rng.index(4) {
                    0 | 1 => {
                        let k = rng.below(1000);
                        let id = next_id;
                        next_id += 1;
                        let hd = fib.insert(k, id);
                        handles.push((hd, k, id));
                        reference.push((k, id));
                    }
                    2 => {
                        // pop_min
                        if reference.is_empty() {
                            assert!(fib.pop_min().is_none());
                        } else {
                            let (k, id) = fib.pop_min().unwrap();
                            let min_key = reference.iter().map(|&(k, _)| k).min().unwrap();
                            assert_eq!(k, min_key);
                            let pos = reference
                                .iter()
                                .position(|&(rk, rid)| rk == k && rid == id)
                                .expect("popped entry must exist in reference");
                            reference.swap_remove(pos);
                            handles.retain(|&(_, _, hid)| hid != id);
                        }
                    }
                    _ => {
                        // delete random live handle
                        if !handles.is_empty() {
                            let idx = rng.index(handles.len());
                            let (hd, k, id) = handles.swap_remove(idx);
                            let (_, v) = fib.delete(hd);
                            assert_eq!(v, id);
                            let pos = reference
                                .iter()
                                .position(|&(rk, rid)| rk == k && rid == id)
                                .unwrap();
                            reference.swap_remove(pos);
                        }
                    }
                }
                assert_eq!(fib.len(), reference.len());
                assert_eq!(
                    fib.min_key(),
                    reference.iter().map(|&(k, _)| k).min(),
                    "min mismatch"
                );
            }
        }
    }

    #[test]
    fn large_sequence_is_sorted() {
        let mut rng = Rng::new(123);
        let mut h = FibHeap::new();
        for _ in 0..10_000 {
            let k = rng.below(1_000_000);
            h.insert(k, ());
        }
        let mut prev = 0;
        let mut heap_check = BinaryHeap::new(); // silence unused import in some cfgs
        heap_check.push(0u64);
        while let Some((k, _)) = h.pop_min() {
            assert!(k >= prev);
            prev = k;
        }
    }
}
