//! Open-loop wire load generator for the network ingress (DESIGN.md §12).
//!
//! Reuses the workload synthesis stack — [`TraceSpec`] / [`ModelTraffic`]
//! with the Azure-burst arrival process — to produce a release-time
//! schedule, then replays it **open-loop** over real TCP connections
//! against a `serve --listen` endpoint: requests are sent at their
//! scheduled times regardless of how fast replies come back, which is
//! what makes offered load meaningful under overload.
//!
//! Connections are partitioned across a small pool of sender threads;
//! each thread owns its connections outright (non-blocking sockets,
//! partial-write backlogs, partial-reply reassembly) and paces sends
//! against one shared epoch. Wire→wire latency is measured per request:
//! reply receive time minus actual send time, correlated through the
//! echoed frame `seq` (dense per connection).

use crate::clock::ms_to_us;
use crate::serve::ingress::{
    decode_reply, encode_frame, ReqFrame, REPLY_LEN, REQ_HEADER_LEN, WIRE_DROP,
};
use crate::util::stats;
use crate::workload::azure::AzureTraceConfig;
use crate::workload::exectime::ExecTimeDist;
use crate::workload::trace::{ModelTraffic, TraceSpec};
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

/// What to offer, where, and over how many connections.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Target `host:port`.
    pub addr: String,
    /// Concurrent connections.
    pub conns: usize,
    /// Offered aggregate request rate (req/s).
    pub rate_per_s: f64,
    /// Schedule length (seconds).
    pub duration_s: f64,
    /// Applications multiplexed per model.
    pub apps: usize,
    /// Models in the traffic mix (1 = single-model).
    pub models: usize,
    /// SLO = `slo_multiple ×` the schedule's per-model p99 exec time.
    pub slo_multiple: f64,
    /// Solo execution-time hint carried in each frame (ms).
    pub exec_ms: f64,
    /// Opaque payload bytes appended to each frame.
    pub payload: usize,
    pub seed: u64,
    /// Sender threads (0 = auto: `min(8, parallelism)`, capped by conns).
    pub workers: usize,
    /// How long to wait for outstanding replies after the schedule ends.
    pub drain_timeout_s: f64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:7433".to_string(),
            conns: 64,
            rate_per_s: 20_000.0,
            duration_s: 3.0,
            apps: 2,
            models: 1,
            slo_multiple: 10.0,
            exec_ms: 5.0,
            payload: 0,
            seed: 42,
            workers: 0,
            drain_timeout_s: 5.0,
        }
    }
}

/// Client-side view of one run.
#[derive(Debug, Clone, Default)]
pub struct LoadgenReport {
    pub sent: u64,
    pub replies: u64,
    pub finished: u64,
    pub late: u64,
    /// TimedOut + Aborted replies (server-side sheds).
    pub shed: u64,
    /// `WIRE_DROP` replies — the ingress ring was full at arrival.
    pub wire_dropped: u64,
    /// Full wall time of the run, schedule + drain (seconds).
    pub wall_s: f64,
    pub sent_rps: f64,
    pub reply_rps: f64,
    /// Wire→wire latency over all replies (send→reply, client clock).
    pub wire_p50_ms: f64,
    pub wire_p99_ms: f64,
    /// Requests sent on the wire that never got a reply (or a counted
    /// wire drop) within the drain timeout. Zero on a healthy run.
    pub conservation_violations: u64,
}

/// One scheduled send, pre-resolved to its owning connection.
struct Shot {
    release: u64,
    conn: usize,
    frame: ReqFrame,
}

struct ClientConn {
    stream: TcpStream,
    /// Unsent/unacked outbound bytes (partial writes land here).
    out: Vec<u8>,
    opos: usize,
    /// Partial-reply reassembly carry.
    carry: Vec<u8>,
    /// Send timestamp per seq (dense, push on send).
    sent_at: Vec<u64>,
    seq: u32,
    dead: bool,
}

struct WorkerResult {
    sent: u64,
    replies: u64,
    finished: u64,
    late: u64,
    shed: u64,
    wire_dropped: u64,
    latencies_ms: Vec<f64>,
}

/// Run the load generator to completion. Blocks the calling thread for
/// roughly `duration_s + drain_timeout_s`.
pub fn run(cfg: &LoadgenConfig) -> io::Result<LoadgenReport> {
    let addr = cfg
        .addr
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| io::Error::new(io::ErrorKind::InvalidInput, "unresolvable address"))?;
    let conns = cfg.conns.max(1);
    let nworkers = if cfg.workers > 0 {
        cfg.workers
    } else {
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(4)
            .min(8)
    }
    .min(conns)
    .max(1);

    // The schedule: same synthesis stack the experiments use.
    let dists: Vec<ExecTimeDist> = (0..cfg.apps.max(1))
        .map(|_| ExecTimeDist::constant("loadgen", cfg.exec_ms))
        .collect();
    let models = if cfg.models <= 1 {
        Vec::new()
    } else {
        (0..cfg.models as u32)
            .map(|m| ModelTraffic::new(m, 1.0 / cfg.models as f64, dists.clone()))
            .collect()
    };
    let spec = TraceSpec {
        name: "loadgen".to_string(),
        dists,
        arrivals: AzureTraceConfig {
            apps: cfg.apps.max(1),
            rate_per_s: cfg.rate_per_s,
            duration_s: cfg.duration_s,
            ..Default::default()
        },
        seed: cfg.seed,
        models,
    };
    let requests = spec.generate().requests(cfg.slo_multiple);

    // Request i rides connection i % conns; worker w owns the connections
    // with conn % nworkers == w, so each shot list stays release-sorted.
    let mut shots: Vec<Vec<Shot>> = (0..nworkers).map(|_| Vec::new()).collect();
    for (i, r) in requests.iter().enumerate() {
        let conn = i % conns;
        shots[conn % nworkers].push(Shot {
            release: r.release,
            conn: conn / nworkers,
            frame: ReqFrame {
                seq: 0, // assigned densely per connection at send time
                app: r.app.0,
                model: r.model.0,
                slo_us: r.slo().min(u32::MAX as u64) as u32,
                exec_us: ms_to_us(r.exec_ms).min(u32::MAX as u64) as u32,
                payload_len: cfg.payload as u32,
            },
        });
    }
    let conns_of = |w: usize| conns / nworkers + usize::from(w < conns % nworkers);

    let started = Instant::now();
    let mut results: Vec<WorkerResult> = Vec::with_capacity(nworkers);
    let mut connect_err: Option<io::Error> = None;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for (w, shots) in shots.into_iter().enumerate() {
            let n_conns = conns_of(w);
            let payload = vec![0u8; cfg.payload];
            let drain_timeout = Duration::from_secs_f64(cfg.drain_timeout_s.max(0.0));
            handles.push(scope.spawn(move || {
                let conns = connect_all(&addr, n_conns)?;
                Ok::<WorkerResult, io::Error>(drive(
                    conns,
                    shots,
                    &payload,
                    started,
                    drain_timeout,
                ))
            }));
        }
        for h in handles {
            match h.join().expect("loadgen worker panicked") {
                Ok(r) => results.push(r),
                Err(e) => connect_err = Some(e),
            }
        }
    });
    if let Some(e) = connect_err {
        return Err(e);
    }
    let wall_s = started.elapsed().as_secs_f64();

    let mut rep = LoadgenReport {
        wall_s,
        ..Default::default()
    };
    let mut lat: Vec<f64> = Vec::new();
    for r in results {
        rep.sent += r.sent;
        rep.replies += r.replies;
        rep.finished += r.finished;
        rep.late += r.late;
        rep.shed += r.shed;
        rep.wire_dropped += r.wire_dropped;
        lat.extend(r.latencies_ms);
    }
    rep.sent_rps = rep.sent as f64 / wall_s.max(1e-9);
    rep.reply_rps = rep.replies as f64 / wall_s.max(1e-9);
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    if !lat.is_empty() {
        rep.wire_p50_ms = stats::percentile_sorted(&lat, 50.0);
        rep.wire_p99_ms = stats::percentile_sorted(&lat, 99.0);
    }
    rep.conservation_violations = rep.sent.saturating_sub(rep.replies);
    Ok(rep)
}

/// Connect this worker's connections, with retry/backoff so a 10k-conn
/// burst survives transient accept-backlog overflow.
fn connect_all(addr: &SocketAddr, n: usize) -> io::Result<Vec<ClientConn>> {
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let mut last_err = None;
        let mut stream = None;
        for attempt in 0..50u64 {
            match TcpStream::connect(addr) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(e) => {
                    last_err = Some(e);
                    std::thread::sleep(Duration::from_millis(1 + attempt));
                }
            }
        }
        let stream = match stream {
            Some(s) => s,
            None => return Err(last_err.unwrap()),
        };
        let _ = stream.set_nodelay(true);
        stream.set_nonblocking(true)?;
        out.push(ClientConn {
            stream,
            out: Vec::with_capacity(4096),
            opos: 0,
            carry: Vec::with_capacity(REPLY_LEN * 64),
            sent_at: Vec::new(),
            seq: 0,
            dead: false,
        });
    }
    Ok(out)
}

/// Pace the schedule, sweep replies, then drain.
fn drive(
    mut conns: Vec<ClientConn>,
    shots: Vec<Shot>,
    payload: &[u8],
    epoch: Instant,
    drain_timeout: Duration,
) -> WorkerResult {
    let mut res = WorkerResult {
        sent: 0,
        replies: 0,
        finished: 0,
        late: 0,
        shed: 0,
        wire_dropped: 0,
        latencies_ms: Vec::new(),
    };
    let now_us = |epoch: Instant| epoch.elapsed().as_micros() as u64;
    let mut next = 0usize;
    while next < shots.len() {
        let now = now_us(epoch);
        while next < shots.len() && shots[next].release <= now {
            let shot = &shots[next];
            next += 1;
            let conn = &mut conns[shot.conn];
            if conn.dead {
                continue;
            }
            let mut frame = shot.frame;
            frame.seq = conn.seq;
            conn.seq = conn.seq.wrapping_add(1);
            conn.sent_at.push(now_us(epoch));
            conn.out.extend_from_slice(&encode_frame(&frame));
            conn.out.extend_from_slice(payload);
            res.sent += 1;
        }
        for conn in conns.iter_mut() {
            flush_out(conn);
            sweep_replies(conn, epoch, &mut res);
        }
        if next < shots.len() {
            let wait = shots[next].release.saturating_sub(now_us(epoch));
            if wait > 0 {
                std::thread::sleep(Duration::from_micros(wait.min(500)));
            }
        }
    }
    // Drain: keep sweeping until every sent request got its reply (or a
    // wire drop), the server hung up, or the timeout expires.
    let deadline = Instant::now() + drain_timeout;
    loop {
        let mut alive = false;
        for conn in conns.iter_mut() {
            flush_out(conn);
            sweep_replies(conn, epoch, &mut res);
            alive |= !conn.dead;
        }
        let outstanding = res.sent.saturating_sub(res.replies);
        if outstanding == 0 || !alive || Instant::now() >= deadline {
            break;
        }
        std::thread::sleep(Duration::from_micros(200));
    }
    res
}

fn flush_out(conn: &mut ClientConn) {
    if conn.dead || conn.out.len() == conn.opos {
        return;
    }
    loop {
        match conn.stream.write(&conn.out[conn.opos..]) {
            Ok(0) => {
                conn.dead = true;
                return;
            }
            Ok(n) => {
                conn.opos += n;
                if conn.opos == conn.out.len() {
                    conn.out.clear();
                    conn.opos = 0;
                    return;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
                return;
            }
        }
    }
}

fn sweep_replies(conn: &mut ClientConn, epoch: Instant, res: &mut WorkerResult) {
    if conn.dead {
        return;
    }
    let mut buf = [0u8; 4096];
    loop {
        let n = match conn.stream.read(&mut buf) {
            Ok(0) => {
                conn.dead = true;
                break;
            }
            Ok(n) => n,
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
                break;
            }
        };
        conn.carry.extend_from_slice(&buf[..n]);
        let mut pos = 0usize;
        while conn.carry.len() - pos >= REPLY_LEN {
            let mut frame = [0u8; REPLY_LEN];
            frame.copy_from_slice(&conn.carry[pos..pos + REPLY_LEN]);
            pos += REPLY_LEN;
            let Some(reply) = decode_reply(&frame) else {
                // Desynchronized stream: nothing downstream can be
                // trusted, stop reading this connection.
                conn.dead = true;
                break;
            };
            res.replies += 1;
            match reply.outcome {
                0 => res.finished += 1,
                1 => res.late += 1,
                WIRE_DROP => res.wire_dropped += 1,
                _ => res.shed += 1,
            }
            if let Some(&at) = conn.sent_at.get(reply.seq as usize) {
                let now = epoch.elapsed().as_micros() as u64;
                res.latencies_ms.push(now.saturating_sub(at) as f64 / 1000.0);
            }
        }
        conn.carry.drain(..pos);
        if n < buf.len() {
            break;
        }
    }
}

/// Bytes one request occupies on the wire (header + payload) — handy for
/// sizing sanity checks in tests and the experiment.
pub fn wire_bytes_per_request(payload: usize) -> usize {
    REQ_HEADER_LEN + payload
}
