//! Workload synthesis: execution-time distributions (Table 1 presets +
//! synthetic k-modal mixtures), the Azure-Functions-like arrival process,
//! and replayable traces binding the two together.

pub mod azure;
pub mod exectime;
pub mod loadgen;
pub mod trace;
