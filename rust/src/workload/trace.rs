//! Request trace assembly, record and replay (paper §5.2).
//!
//! A *trace* is the fully materialized request sequence: arrival time, app,
//! and ground-truth solo execution time. It is generated once per
//! experiment (arrivals from the Azure-like process × per-app execution
//! time distributions) and replayed identically for every system and SLO
//! setting — deadlines are applied at replay time as `release + mult·P99`,
//! exactly the paper's metrics methodology.

use super::azure::{self, AzureTraceConfig};
use super::exectime::ExecTimeDist;
use crate::clock::{ms_to_us, Micros};
use crate::core::batchmodel::BatchCostModel;
use crate::core::histogram::Histogram;
use crate::core::request::{AppId, Request};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// One recorded trace event.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub at: Micros,
    pub app: u32,
    pub exec_ms: f64,
}

/// A generated, replayable workload trace.
#[derive(Debug, Clone)]
pub struct Trace {
    pub name: String,
    pub events: Vec<TraceEvent>,
    /// P99 of the solo execution times in this trace (SLO reference).
    pub p99_ms: f64,
}

/// Everything needed to generate a trace deterministically.
#[derive(Debug, Clone)]
pub struct TraceSpec {
    pub name: String,
    /// Per-app execution time distributions (app i uses dists[i]).
    pub dists: Vec<ExecTimeDist>,
    pub arrivals: AzureTraceConfig,
    pub seed: u64,
}

impl TraceSpec {
    /// Pick the aggregate arrival rate so offered load is `util` of the
    /// worker's batched capacity at reference batch size `bs_ref` (paper:
    /// "scaled down such that the incoming rate matches the system load").
    pub fn scale_rate_to_load(
        &mut self,
        cost_model: BatchCostModel,
        util: f64,
        bs_ref: usize,
    ) {
        let mut rng = Rng::new(self.seed ^ 0xABCD);
        // Capacity is governed by the *max order statistic* of a batch
        // (Eq. 4: the batch pads to its longest member), not the mean —
        // using the mean here would silently overload every run.
        let hists: Vec<Histogram> = self
            .dists
            .iter()
            .map(|d| d.histogram(&mut rng, 8000, 96))
            .collect();
        let parts: Vec<(&Histogram, f64)> = hists.iter().map(|h| (h, 1.0)).collect();
        let mix = Histogram::mixture(&parts, 96);
        let batch_ms = cost_model.batch_latency_iid(&mix, bs_ref).mean();
        let capacity = bs_ref as f64 / (batch_ms / 1000.0); // req/s
        self.arrivals.rate_per_s = util * capacity;
    }

    pub fn generate(&self) -> Trace {
        let mut rng = Rng::new(self.seed);
        let mut arr_rng = rng.fork();
        let mut exec_rng = rng.fork();
        let arrivals = azure::generate(&self.arrivals, &mut arr_rng);
        let mut events = Vec::with_capacity(arrivals.len());
        let mut execs = Vec::with_capacity(arrivals.len());
        for (at, app) in arrivals {
            let dist = &self.dists[app % self.dists.len()];
            let exec_ms = dist.sample(&mut exec_rng);
            execs.push(exec_ms);
            events.push(TraceEvent {
                at,
                app: app as u32,
                exec_ms,
            });
        }
        execs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p99_ms = crate::util::stats::percentile_sorted(&execs, 99.0);
        Trace {
            name: self.name.clone(),
            events,
            p99_ms,
        }
    }

    /// Per-app seed histograms for the schedulers' profilers (deployment-
    /// time historical data).
    pub fn seed_histograms(&self, bins: usize) -> Vec<(AppId, Histogram)> {
        let mut rng = Rng::new(self.seed ^ 0x5EED);
        self.dists
            .iter()
            .enumerate()
            .map(|(i, d)| (AppId(i as u32), d.histogram(&mut rng, 8000, bins)))
            .collect()
    }
}

impl Trace {
    /// Materialize requests for a given SLO multiple of the trace P99.
    pub fn requests(&self, slo_multiple: f64) -> Vec<Request> {
        let slo = ms_to_us(slo_multiple * self.p99_ms);
        self.events
            .iter()
            .enumerate()
            .map(|(i, e)| Request::new(i as u64, AppId(e.app), e.at, slo, e.exec_ms))
            .collect()
    }

    /// Mean solo exec time of the trace (for baseline seeding).
    pub fn exec_mean_ms(&self) -> f64 {
        if self.events.is_empty() {
            return 0.0;
        }
        self.events.iter().map(|e| e.exec_ms).sum::<f64>() / self.events.len() as f64
    }

    // ---------- record / replay ----------

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("p99_ms", Json::num(self.p99_ms)),
            (
                "events",
                Json::arr(self.events.iter().map(|e| {
                    Json::arr(vec![
                        Json::num(e.at as f64),
                        Json::num(e.app as f64),
                        Json::num(e.exec_ms),
                    ])
                })),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Option<Trace> {
        let name = v.get("name").as_str()?.to_string();
        let p99_ms = v.get("p99_ms").as_f64()?;
        let events = v
            .get("events")
            .as_arr()?
            .iter()
            .map(|e| {
                Some(TraceEvent {
                    at: e.at(0).as_f64()? as Micros,
                    app: e.at(1).as_f64()? as u32,
                    exec_ms: e.at(2).as_f64()?,
                })
            })
            .collect::<Option<Vec<_>>>()?;
        Some(Trace {
            name,
            events,
            p99_ms,
        })
    }

    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }

    pub fn load(path: &std::path::Path) -> std::io::Result<Trace> {
        let text = std::fs::read_to_string(path)?;
        let v = Json::parse(&text)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
        Trace::from_json(&v)
            .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "bad trace"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> TraceSpec {
        TraceSpec {
            name: "test".into(),
            dists: vec![
                ExecTimeDist::multimodal("a", 2, 5.0, 50.0, 1.0, None),
                ExecTimeDist::constant("b", 10.0),
            ],
            arrivals: AzureTraceConfig {
                apps: 2,
                rate_per_s: 50.0,
                duration_s: 10.0,
                ..Default::default()
            },
            seed: 11,
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let s = spec();
        let a = s.generate();
        let b = s.generate();
        assert_eq!(a.events, b.events);
        assert_eq!(a.p99_ms, b.p99_ms);
    }

    #[test]
    fn requests_apply_slo_multiple() {
        let t = spec().generate();
        let r2 = t.requests(2.0);
        let r5 = t.requests(5.0);
        assert_eq!(r2.len(), r5.len());
        for (a, b) in r2.iter().zip(&r5) {
            assert_eq!(a.release, b.release);
            assert_eq!(a.exec_ms, b.exec_ms);
            assert!(b.deadline > a.deadline);
            assert_eq!(a.slo(), ms_to_us(2.0 * t.p99_ms));
        }
    }

    #[test]
    fn json_roundtrip() {
        let t = spec().generate();
        let j = t.to_json();
        let back = Trace::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.events, t.events);
        assert_eq!(back.p99_ms, t.p99_ms);
        assert_eq!(back.name, t.name);
    }

    #[test]
    fn file_roundtrip() {
        let t = spec().generate();
        let dir = std::env::temp_dir().join("orloj_trace_test.json");
        t.save(&dir).unwrap();
        let back = Trace::load(&dir).unwrap();
        assert_eq!(back.events.len(), t.events.len());
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn load_scaling_produces_sane_rate() {
        let mut s = spec();
        s.scale_rate_to_load(BatchCostModel::new(1.0, 0.25), 0.7, 8);
        // capacity = 8 / (latency(8, mean)/1000); mean ~ (≈17+10)/2 ≈ 14ms
        // latency(8,14) = 1+0.25*8*14 = 29ms → cap ≈ 276 r/s → rate ≈ 193.
        assert!(
            s.arrivals.rate_per_s > 50.0 && s.arrivals.rate_per_s < 500.0,
            "rate={}",
            s.arrivals.rate_per_s
        );
    }

    #[test]
    fn seed_histograms_cover_apps() {
        let s = spec();
        let seeds = s.seed_histograms(32);
        assert_eq!(seeds.len(), 2);
        assert!((seeds[1].1.mean() - 10.0).abs() < 0.5);
    }
}
