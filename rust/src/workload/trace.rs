//! Request trace assembly, record and replay (paper §5.2).
//!
//! A *trace* is the fully materialized request sequence: arrival time,
//! model, app, and ground-truth solo execution time. It is generated once
//! per experiment (arrivals from the Azure-like process × per-app
//! execution time distributions) and replayed identically for every
//! system and SLO setting — deadlines are applied at replay time as
//! `release + mult·P99`, exactly the paper's metrics methodology. Multi-
//! model traces ([`TraceSpec::models`]) superpose one arrival process per
//! model (per-model rate share, exec-time presets and SLO reference), so
//! heterogeneous-fleet runs stay deterministic and replayable too.

use super::azure::{self, AzureTraceConfig};
use super::exectime::ExecTimeDist;
use crate::clock::{ms_to_us, Micros};
use crate::core::batchmodel::BatchCostModel;
use crate::core::histogram::Histogram;
use crate::core::request::{AppId, ModelId, Request};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// One recorded trace event.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub at: Micros,
    pub app: u32,
    pub model: u32,
    pub exec_ms: f64,
}

/// A generated, replayable workload trace.
#[derive(Debug, Clone)]
pub struct Trace {
    pub name: String,
    pub events: Vec<TraceEvent>,
    /// P99 of the solo execution times in this trace (SLO reference).
    pub p99_ms: f64,
    /// Per-model SLO reference (model → P99 of its own solo execution
    /// times × its `slo_scale`). Models absent here fall back to the
    /// trace-wide `p99_ms`.
    pub slo_ref_by_model: Vec<(u32, f64)>,
}

/// One model's traffic in a multi-model trace: its share of the aggregate
/// arrival rate, its per-app execution-time distributions, and its SLO
/// scale. Under a *drifting* mix ([`TraceSpec::drift`]) the share follows
/// a piecewise-linear schedule over the trace instead of staying
/// constant.
#[derive(Debug, Clone)]
pub struct ModelTraffic {
    pub model: u32,
    /// Fraction of the aggregate arrival rate (normalized over all
    /// models) when no drift schedule is installed.
    pub share: f64,
    /// Piecewise-linear share-over-time schedule: `(time_s, share)`
    /// knots, sorted by time, linearly interpolated between knots and
    /// clamped at the ends. Empty = constant `share` for the whole trace.
    /// Installed by [`TraceSpec::drift`] / [`TraceSpec::drift_rotating`];
    /// drifting specs should keep the per-instant shares summing to ~1
    /// across models (the presets do).
    pub share_knots: Vec<(f64, f64)>,
    /// Per-app execution time distributions (app i uses dists[i]).
    pub dists: Vec<ExecTimeDist>,
    /// Extra scale on this model's SLO reference (1.0 = its own P99).
    pub slo_scale: f64,
}

impl ModelTraffic {
    pub fn new(model: u32, share: f64, dists: Vec<ExecTimeDist>) -> Self {
        assert!(share > 0.0 && !dists.is_empty());
        ModelTraffic {
            model,
            share,
            share_knots: Vec::new(),
            dists,
            slo_scale: 1.0,
        }
    }

    /// Share at `t_s` seconds: the knot interpolation, or the constant
    /// `share` when no schedule is installed.
    pub fn share_at(&self, t_s: f64) -> f64 {
        if self.share_knots.is_empty() {
            return self.share;
        }
        let first = self.share_knots[0];
        if t_s <= first.0 {
            return first.1;
        }
        for w in self.share_knots.windows(2) {
            let ((t0, s0), (t1, s1)) = (w[0], w[1]);
            if t_s <= t1 {
                if t1 <= t0 {
                    return s1;
                }
                let f = (t_s - t0) / (t1 - t0);
                return s0 + f * (s1 - s0);
            }
        }
        self.share_knots.last().unwrap().1
    }

    /// Peak share over the schedule (piecewise-linear → the max sits on a
    /// knot). Equals `share` without a schedule.
    pub fn peak_share(&self) -> f64 {
        if self.share_knots.is_empty() {
            return self.share;
        }
        self.share_knots
            .iter()
            .map(|(_, s)| *s)
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Time-averaged share over `[0, duration_s]` (trapezoid over the
    /// clamped schedule). Equals `share` without a schedule — so static
    /// load-scaling math is bit-identical.
    pub fn mean_share(&self, duration_s: f64) -> f64 {
        if self.share_knots.is_empty() || duration_s <= 0.0 {
            return self.share;
        }
        // Integrate the clamped piecewise-linear curve on a knot-aligned
        // grid: ends plus every interior knot.
        let mut ts: Vec<f64> = vec![0.0];
        ts.extend(
            self.share_knots
                .iter()
                .map(|(t, _)| *t)
                .filter(|t| *t > 0.0 && *t < duration_s),
        );
        ts.push(duration_s);
        ts.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut area = 0.0;
        for w in ts.windows(2) {
            let (a, b) = (w[0], w[1]);
            area += 0.5 * (self.share_at(a) + self.share_at(b)) * (b - a);
        }
        area / duration_s
    }
}

/// Everything needed to generate a trace deterministically.
#[derive(Debug, Clone)]
pub struct TraceSpec {
    pub name: String,
    /// Per-app execution time distributions (app i uses dists[i]) for the
    /// single-model path; ignored when `models` is non-empty.
    pub dists: Vec<ExecTimeDist>,
    pub arrivals: AzureTraceConfig,
    pub seed: u64,
    /// Multi-model traffic mix. Empty = historical single-model trace
    /// (model 0), generated bit-identically to the pre-placement code.
    pub models: Vec<ModelTraffic>,
}

impl TraceSpec {
    /// Pick the aggregate arrival rate so offered load is `util` of *one*
    /// worker's batched capacity at reference batch size `bs_ref` (paper:
    /// "scaled down such that the incoming rate matches the system load").
    /// Multi-model specs use the share-weighted mixture across models.
    pub fn scale_rate_to_load(&mut self, cost_model: BatchCostModel, util: f64, bs_ref: usize) {
        let mut rng = Rng::new(self.seed ^ 0xABCD);
        let duration_s = self.arrivals.duration_s;
        // Capacity is governed by the *max order statistic* of a batch
        // (Eq. 4: the batch pads to its longest member), not the mean —
        // using the mean here would silently overload every run.
        // Drifting mixes weight by the time-averaged share (identical to
        // `share` for static mixes).
        let parts_spec: Vec<(&ExecTimeDist, f64)> = if self.models.is_empty() {
            self.dists.iter().map(|d| (d, 1.0)).collect()
        } else {
            self.models
                .iter()
                .flat_map(|mt| {
                    let w = mt.mean_share(duration_s);
                    mt.dists.iter().map(move |d| (d, w))
                })
                .collect()
        };
        let hists: Vec<(Histogram, f64)> = parts_spec
            .iter()
            .map(|(d, w)| (d.histogram(&mut rng, 8000, 96), *w))
            .collect();
        let parts: Vec<(&Histogram, f64)> = hists.iter().map(|(h, w)| (h, *w)).collect();
        let mix = Histogram::mixture(&parts, 96);
        let batch_ms = cost_model.batch_latency_iid(&mix, bs_ref).mean();
        let capacity = bs_ref as f64 / (batch_ms / 1000.0); // req/s
        self.arrivals.rate_per_s = util * capacity;
    }

    /// Install a piecewise-linear per-model share schedule (drift): row
    /// `knots[i]` is `(time_s, shares)` with one share per entry of
    /// `self.models`, in the same order. Drifting shares are *absolute*
    /// fractions of `arrivals.rate_per_s` (keep each row summing to ~1).
    pub fn drift(mut self, knots: &[(f64, Vec<f64>)]) -> Self {
        assert!(!self.models.is_empty(), "drift needs a multi-model spec");
        assert!(!knots.is_empty(), "drift needs at least one knot");
        let m = self.models.len();
        for (t, shares) in knots {
            assert!(
                shares.len() == m,
                "drift knot at t={t}s names {} shares for {m} models",
                shares.len()
            );
        }
        for (j, mt) in self.models.iter_mut().enumerate() {
            mt.share_knots = knots.iter().map(|(t, shares)| (*t, shares[j])).collect();
        }
        self
    }

    /// Rotating-hot-model drift preset: every `period_s` the hot model
    /// (share `hot`) advances to the next model id, the others splitting
    /// the remainder evenly — the "traffic mix shifts under a fixed
    /// provisioning" scenario the elastic experiment sweeps.
    pub fn drift_rotating(self, period_s: f64, hot: f64) -> Self {
        let m = self.models.len();
        assert!(m >= 2, "rotation needs at least two models");
        assert!(period_s > 0.0 && hot > 0.0 && hot <= 1.0);
        let cold = (1.0 - hot) / (m - 1) as f64;
        let duration = self.arrivals.duration_s;
        let segs = (duration / period_s).ceil().max(1.0) as usize;
        // Near-step rotation: two knots per segment with a sharp ramp in
        // between (piecewise-linear everywhere).
        let eps = (period_s * 0.01).min(0.05);
        let mut knots: Vec<(f64, Vec<f64>)> = Vec::with_capacity(2 * segs);
        for k in 0..segs {
            let mut shares = vec![cold; m];
            shares[k % m] = hot;
            let t0 = k as f64 * period_s;
            let t1 = (((k + 1) as f64) * period_s - eps).min(duration);
            knots.push((t0, shares.clone()));
            knots.push((t1, shares));
        }
        self.drift(&knots)
    }

    /// Whether any model carries a drift schedule.
    pub fn has_drift(&self) -> bool {
        self.models.iter().any(|m| !m.share_knots.is_empty())
    }

    pub fn generate(&self) -> Trace {
        if self.models.is_empty() {
            return self.generate_single();
        }
        let share_sum: f64 = self.models.iter().map(|m| m.share).sum();
        let mut events: Vec<TraceEvent> = Vec::new();
        let mut slo_ref = Vec::with_capacity(self.models.len());
        let mut all_execs = Vec::new();
        for mt in &self.models {
            // One decorrelated arrival process per model; rates split by
            // normalized share. The static path below is byte-identical
            // to the pre-drift code (same RNG consumption).
            let mut rng = Rng::new(self.seed ^ ((mt.model as u64 + 1) << 40));
            let mut arr_rng = rng.fork();
            let mut exec_rng = rng.fork();
            let mut cfg = self.arrivals.clone();
            cfg.apps = mt.dists.len().max(1);
            let mut execs = Vec::new();
            if mt.share_knots.is_empty() {
                cfg.rate_per_s = self.arrivals.rate_per_s * mt.share / share_sum.max(1e-12);
                for (at, app) in azure::generate(&cfg, &mut arr_rng) {
                    let dist = &mt.dists[app % mt.dists.len()];
                    let exec_ms = dist.sample(&mut exec_rng);
                    execs.push(exec_ms);
                    events.push(TraceEvent {
                        at,
                        app: app as u32,
                        model: mt.model,
                        exec_ms,
                    });
                }
            } else {
                // Drifting model: generate the azure process at the peak
                // share and thin each arrival down to the instantaneous
                // share — the process keeps its burst structure while the
                // mix drifts. Deterministic via a dedicated thinning rng.
                let peak = mt.peak_share().max(1e-12);
                cfg.rate_per_s = self.arrivals.rate_per_s * peak;
                let mut thin_rng = rng.fork();
                for (at, app) in azure::generate(&cfg, &mut arr_rng) {
                    let keep = (mt.share_at(at as f64 / 1e6) / peak).clamp(0.0, 1.0);
                    if !thin_rng.chance(keep) {
                        continue;
                    }
                    let dist = &mt.dists[app % mt.dists.len()];
                    let exec_ms = dist.sample(&mut exec_rng);
                    execs.push(exec_ms);
                    events.push(TraceEvent {
                        at,
                        app: app as u32,
                        model: mt.model,
                        exec_ms,
                    });
                }
            }
            execs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            if !execs.is_empty() {
                let model_p99 = crate::util::stats::percentile_sorted(&execs, 99.0);
                slo_ref.push((mt.model, model_p99 * mt.slo_scale));
            }
            all_execs.extend(execs);
        }
        // Deterministic merge of the per-model streams.
        events.sort_by_key(|e| (e.at, e.model, e.app));
        all_execs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p99_ms = crate::util::stats::percentile_sorted(&all_execs, 99.0);
        Trace {
            name: self.name.clone(),
            events,
            p99_ms,
            slo_ref_by_model: slo_ref,
        }
    }

    /// The historical single-model path — kept byte-identical (same RNG
    /// consumption) so pre-placement experiments reproduce exactly.
    fn generate_single(&self) -> Trace {
        let mut rng = Rng::new(self.seed);
        let mut arr_rng = rng.fork();
        let mut exec_rng = rng.fork();
        let arrivals = azure::generate(&self.arrivals, &mut arr_rng);
        let mut events = Vec::with_capacity(arrivals.len());
        let mut execs = Vec::with_capacity(arrivals.len());
        for (at, app) in arrivals {
            let dist = &self.dists[app % self.dists.len()];
            let exec_ms = dist.sample(&mut exec_rng);
            execs.push(exec_ms);
            events.push(TraceEvent {
                at,
                app: app as u32,
                model: 0,
                exec_ms,
            });
        }
        execs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p99_ms = crate::util::stats::percentile_sorted(&execs, 99.0);
        Trace {
            name: self.name.clone(),
            events,
            p99_ms,
            slo_ref_by_model: Vec::new(),
        }
    }

    /// Per-(model, app) seed histograms for the schedulers' profilers
    /// (deployment-time historical data).
    pub fn seed_histograms(&self, bins: usize) -> Vec<(ModelId, AppId, Histogram)> {
        if self.models.is_empty() {
            let mut rng = Rng::new(self.seed ^ 0x5EED);
            return self
                .dists
                .iter()
                .enumerate()
                .map(|(i, d)| {
                    (
                        ModelId::DEFAULT,
                        AppId(i as u32),
                        d.histogram(&mut rng, 8000, bins),
                    )
                })
                .collect();
        }
        let mut out = Vec::new();
        for mt in &self.models {
            let mut rng = Rng::new(self.seed ^ 0x5EED ^ ((mt.model as u64 + 1) << 32));
            for (i, d) in mt.dists.iter().enumerate() {
                out.push((
                    ModelId(mt.model),
                    AppId(i as u32),
                    d.histogram(&mut rng, 8000, bins),
                ));
            }
        }
        out
    }

    /// Per-model batch cost models calibrated to each model's own mean
    /// solo latency (empty for single-model specs — those use the shared
    /// `SchedulerConfig::cost_model`).
    pub fn model_cost_models(&self) -> Vec<(u32, BatchCostModel)> {
        self.models
            .iter()
            .map(|mt| {
                let mut rng = Rng::new(self.seed ^ 0xC057 ^ ((mt.model as u64 + 1) << 32));
                let mean = mt
                    .dists
                    .iter()
                    .map(|d| d.histogram(&mut rng, 4000, 64).mean())
                    .sum::<f64>()
                    / mt.dists.len() as f64;
                (mt.model, BatchCostModel::calibrated(mean))
            })
            .collect()
    }
}

impl Trace {
    /// SLO reference (ms) for one model: its own P99-based reference, or
    /// the trace-wide P99 for single-model traces.
    pub fn slo_ref_ms(&self, model: u32) -> f64 {
        self.slo_ref_by_model
            .iter()
            .find(|(m, _)| *m == model)
            .map_or(self.p99_ms, |(_, p)| *p)
    }

    /// Materialize requests for a given SLO multiple. Each request's SLO
    /// is `slo_multiple ×` its *model's* reference P99 (the trace-wide P99
    /// on single-model traces — identical to the historical behaviour).
    pub fn requests(&self, slo_multiple: f64) -> Vec<Request> {
        self.events
            .iter()
            .enumerate()
            .map(|(i, e)| {
                let slo = ms_to_us(slo_multiple * self.slo_ref_ms(e.model));
                Request::new(i as u64, AppId(e.app), e.at, slo, e.exec_ms)
                    .with_model(ModelId(e.model))
            })
            .collect()
    }

    /// Mean solo exec time of the trace (for baseline seeding).
    pub fn exec_mean_ms(&self) -> f64 {
        if self.events.is_empty() {
            return 0.0;
        }
        self.events.iter().map(|e| e.exec_ms).sum::<f64>() / self.events.len() as f64
    }

    /// Model ids present in the trace, sorted.
    pub fn model_ids(&self) -> Vec<u32> {
        let mut ids: Vec<u32> = self.events.iter().map(|e| e.model).collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    // ---------- record / replay ----------

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("p99_ms", Json::num(self.p99_ms)),
            (
                "slo_ref",
                Json::arr(self.slo_ref_by_model.iter().map(|(m, p)| {
                    Json::arr(vec![Json::num(*m as f64), Json::num(*p)])
                })),
            ),
            (
                "events",
                Json::arr(self.events.iter().map(|e| {
                    Json::arr(vec![
                        Json::num(e.at as f64),
                        Json::num(e.app as f64),
                        Json::num(e.model as f64),
                        Json::num(e.exec_ms),
                    ])
                })),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Option<Trace> {
        let name = v.get("name").as_str()?.to_string();
        let p99_ms = v.get("p99_ms").as_f64()?;
        // Legacy traces have 3-element event rows (no model column) and no
        // slo_ref.
        let slo_ref_by_model = match v.get("slo_ref").as_arr() {
            Some(rows) => rows
                .iter()
                .map(|r| Some((r.at(0).as_f64()? as u32, r.at(1).as_f64()?)))
                .collect::<Option<Vec<_>>>()?,
            None => Vec::new(),
        };
        let events = v
            .get("events")
            .as_arr()?
            .iter()
            .map(|e| {
                let has_model = e.at(3).as_f64().is_some();
                Some(TraceEvent {
                    at: e.at(0).as_f64()? as Micros,
                    app: e.at(1).as_f64()? as u32,
                    model: if has_model {
                        e.at(2).as_f64()? as u32
                    } else {
                        0
                    },
                    exec_ms: if has_model {
                        e.at(3).as_f64()?
                    } else {
                        e.at(2).as_f64()?
                    },
                })
            })
            .collect::<Option<Vec<_>>>()?;
        Some(Trace {
            name,
            events,
            p99_ms,
            slo_ref_by_model,
        })
    }

    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().to_string())
    }

    pub fn load(path: &std::path::Path) -> std::io::Result<Trace> {
        let text = std::fs::read_to_string(path)?;
        let v = Json::parse(&text)
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))?;
        Trace::from_json(&v)
            .ok_or_else(|| std::io::Error::new(std::io::ErrorKind::InvalidData, "bad trace"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> TraceSpec {
        TraceSpec {
            name: "test".into(),
            dists: vec![
                ExecTimeDist::multimodal("a", 2, 5.0, 50.0, 1.0, None),
                ExecTimeDist::constant("b", 10.0),
            ],
            arrivals: AzureTraceConfig {
                apps: 2,
                rate_per_s: 50.0,
                duration_s: 10.0,
                ..Default::default()
            },
            seed: 11,
            models: Vec::new(),
        }
    }

    fn mm_spec() -> TraceSpec {
        TraceSpec {
            name: "mm".into(),
            dists: Vec::new(),
            arrivals: AzureTraceConfig {
                apps: 1,
                rate_per_s: 80.0,
                duration_s: 10.0,
                ..Default::default()
            },
            seed: 21,
            models: vec![
                ModelTraffic::new(0, 0.8, vec![ExecTimeDist::constant("fast", 8.0)]),
                ModelTraffic::new(
                    1,
                    0.2,
                    vec![ExecTimeDist::multimodal("slow", 2, 20.0, 120.0, 1.0, None)],
                ),
            ],
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let s = spec();
        let a = s.generate();
        let b = s.generate();
        assert_eq!(a.events, b.events);
        assert_eq!(a.p99_ms, b.p99_ms);
    }

    #[test]
    fn requests_apply_slo_multiple() {
        let t = spec().generate();
        let r2 = t.requests(2.0);
        let r5 = t.requests(5.0);
        assert_eq!(r2.len(), r5.len());
        for (a, b) in r2.iter().zip(&r5) {
            assert_eq!(a.release, b.release);
            assert_eq!(a.exec_ms, b.exec_ms);
            assert!(b.deadline > a.deadline);
            assert_eq!(a.slo(), ms_to_us(2.0 * t.p99_ms));
            assert_eq!(a.model, ModelId::DEFAULT);
        }
    }

    #[test]
    fn multimodel_trace_mixes_models() {
        let s = mm_spec();
        let t = s.generate();
        assert_eq!(t.model_ids(), vec![0, 1]);
        let n0 = t.events.iter().filter(|e| e.model == 0).count();
        let n1 = t.events.iter().filter(|e| e.model == 1).count();
        assert!(n0 > 0 && n1 > 0);
        // 80/20 share: the hot model clearly dominates.
        assert!(n0 > 2 * n1, "n0={n0} n1={n1}");
        // Deterministic regeneration.
        assert_eq!(t.events, s.generate().events);
        // Arrivals stay sorted after the per-model merge.
        for w in t.events.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
    }

    #[test]
    fn multimodel_requests_use_per_model_slo() {
        let t = mm_spec().generate();
        // Model 0 is constant 8 ms, model 1 is bimodal up to ~120 ms —
        // their SLO references must differ accordingly.
        let fast_ref = t.slo_ref_ms(0);
        let slow_ref = t.slo_ref_ms(1);
        assert!(fast_ref < 12.0, "fast_ref={fast_ref}");
        assert!(slow_ref > 40.0, "slow_ref={slow_ref}");
        for r in t.requests(3.0) {
            let want = ms_to_us(3.0 * t.slo_ref_ms(r.model.0));
            assert_eq!(r.slo(), want);
        }
    }

    #[test]
    fn multimodel_seed_histograms_and_costs_cover_models() {
        let s = mm_spec();
        let seeds = s.seed_histograms(32);
        assert_eq!(seeds.len(), 2);
        assert!(seeds.iter().any(|(m, _, _)| *m == ModelId(0)));
        assert!(seeds.iter().any(|(m, _, _)| *m == ModelId(1)));
        let (_, _, fast) = seeds.iter().find(|(m, _, _)| *m == ModelId(0)).unwrap();
        assert!((fast.mean() - 8.0).abs() < 0.5);
        let costs = s.model_cost_models();
        assert_eq!(costs.len(), 2);
        let c0 = costs.iter().find(|(m, _)| *m == 0).unwrap().1;
        let c1 = costs.iter().find(|(m, _)| *m == 1).unwrap().1;
        assert!(c1.c0 > c0.c0, "slow model has the larger calibrated cost");
        // Single-model specs report no per-model costs.
        assert!(spec().model_cost_models().is_empty());
    }

    #[test]
    fn drift_schedule_interpolates_and_averages() {
        let mut mt = ModelTraffic::new(0, 0.5, vec![ExecTimeDist::constant("x", 5.0)]);
        assert_eq!(mt.share_at(3.0), 0.5, "no schedule → constant share");
        assert_eq!(mt.mean_share(10.0), 0.5);
        mt.share_knots = vec![(0.0, 0.8), (10.0, 0.2)];
        assert!((mt.share_at(0.0) - 0.8).abs() < 1e-12);
        assert!((mt.share_at(5.0) - 0.5).abs() < 1e-12);
        assert!((mt.share_at(10.0) - 0.2).abs() < 1e-12);
        assert!((mt.share_at(99.0) - 0.2).abs() < 1e-12, "clamped past the end");
        assert!((mt.peak_share() - 0.8).abs() < 1e-12);
        assert!((mt.mean_share(10.0) - 0.5).abs() < 1e-9, "trapezoid average");
    }

    #[test]
    fn drift_rotating_shifts_the_hot_model() {
        let mut s = mm_spec();
        s.arrivals.duration_s = 20.0;
        s.arrivals.rate_per_s = 200.0;
        let s = s.drift_rotating(10.0, 0.9);
        assert!(s.has_drift());
        let t = s.generate();
        // Segment 1 (0..10 s): model 0 hot; segment 2 (10..20 s): model 1.
        let count = |model: u32, lo_s: f64, hi_s: f64| {
            t.events
                .iter()
                .filter(|e| {
                    let ts = e.at as f64 / 1e6;
                    e.model == model && ts >= lo_s && ts < hi_s
                })
                .count()
        };
        let (a0, a1) = (count(0, 1.0, 9.0), count(1, 1.0, 9.0));
        let (b0, b1) = (count(0, 11.0, 19.0), count(1, 11.0, 19.0));
        assert!(a0 > 3 * a1.max(1), "seg 1 hot=model0: {a0} vs {a1}");
        assert!(b1 > 3 * b0.max(1), "seg 2 hot=model1: {b1} vs {b0}");
        // Deterministic regeneration.
        assert_eq!(t.events, s.generate().events);
        // Arrivals stay sorted after the per-model merge.
        for w in t.events.windows(2) {
            assert!(w[0].at <= w[1].at);
        }
    }

    #[test]
    fn drift_leaves_static_specs_untouched() {
        // The static multi-model path must stay byte-identical whether or
        // not the drift machinery exists: same spec, same events.
        let base = mm_spec().generate();
        let again = mm_spec().generate();
        assert_eq!(base.events, again.events);
        assert!(!mm_spec().has_drift());
        // Load scaling with a drift schedule uses the time-averaged
        // share, which for a symmetric rotation matches the even mix.
        let mut even = mm_spec();
        even.models[0].share = 0.5;
        even.models[1].share = 0.5;
        even.arrivals.duration_s = 10.0;
        let mut rotated = even.clone().drift_rotating(5.0, 0.9);
        even.scale_rate_to_load(BatchCostModel::new(1.0, 0.25), 0.7, 8);
        rotated.scale_rate_to_load(BatchCostModel::new(1.0, 0.25), 0.7, 8);
        let ratio = rotated.arrivals.rate_per_s / even.arrivals.rate_per_s;
        assert!(
            (0.8..=1.25).contains(&ratio),
            "symmetric rotation ≈ even mix for capacity math: {ratio}"
        );
    }

    #[test]
    fn json_roundtrip() {
        let t = spec().generate();
        let j = t.to_json();
        let back = Trace::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.events, t.events);
        assert_eq!(back.p99_ms, t.p99_ms);
        assert_eq!(back.name, t.name);
    }

    #[test]
    fn json_roundtrip_multimodel() {
        let t = mm_spec().generate();
        let j = t.to_json();
        let back = Trace::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.events, t.events);
        assert_eq!(back.slo_ref_by_model, t.slo_ref_by_model);
    }

    #[test]
    fn legacy_three_column_events_still_load() {
        let legacy = r#"{"name":"old","p99_ms":42.0,"events":[[1000,1,7.5],[2000,0,9.0]]}"#;
        let t = Trace::from_json(&Json::parse(legacy).unwrap()).unwrap();
        assert_eq!(t.events.len(), 2);
        assert_eq!(t.events[0].app, 1);
        assert_eq!(t.events[0].model, 0);
        assert!((t.events[0].exec_ms - 7.5).abs() < 1e-12);
        assert!(t.slo_ref_by_model.is_empty());
        assert_eq!(t.slo_ref_ms(0), 42.0);
    }

    #[test]
    fn file_roundtrip() {
        let t = spec().generate();
        let dir = std::env::temp_dir().join("orloj_trace_test.json");
        t.save(&dir).unwrap();
        let back = Trace::load(&dir).unwrap();
        assert_eq!(back.events.len(), t.events.len());
        std::fs::remove_file(dir).ok();
    }

    #[test]
    fn load_scaling_produces_sane_rate() {
        let mut s = spec();
        s.scale_rate_to_load(BatchCostModel::new(1.0, 0.25), 0.7, 8);
        // capacity = 8 / (latency(8, mean)/1000); mean ~ (≈17+10)/2 ≈ 14ms
        // latency(8,14) = 1+0.25*8*14 = 29ms → cap ≈ 276 r/s → rate ≈ 193.
        assert!(
            s.arrivals.rate_per_s > 50.0 && s.arrivals.rate_per_s < 500.0,
            "rate={}",
            s.arrivals.rate_per_s
        );
    }

    #[test]
    fn multimodel_load_scaling_weights_by_share() {
        let mut hot_heavy = mm_spec();
        hot_heavy.scale_rate_to_load(BatchCostModel::new(1.0, 0.25), 0.7, 8);
        let mut cold_heavy = mm_spec();
        cold_heavy.models[0].share = 0.2;
        cold_heavy.models[1].share = 0.8;
        cold_heavy.scale_rate_to_load(BatchCostModel::new(1.0, 0.25), 0.7, 8);
        // More slow-model traffic → lower batched capacity → lower rate.
        assert!(
            cold_heavy.arrivals.rate_per_s < hot_heavy.arrivals.rate_per_s,
            "cold {} vs hot {}",
            cold_heavy.arrivals.rate_per_s,
            hot_heavy.arrivals.rate_per_s
        );
    }

    #[test]
    fn seed_histograms_cover_apps() {
        let s = spec();
        let seeds = s.seed_histograms(32);
        assert_eq!(seeds.len(), 2);
        assert!((seeds[1].2.mean() - 10.0).abs() < 0.5);
        assert!(seeds.iter().all(|(m, _, _)| *m == ModelId::DEFAULT));
    }
}
