//! Execution-time distribution generators (paper §5.2, Table 1, Figs 8–10).
//!
//! Two families:
//!
//! * **Synthetic k-modal mixtures** — lognormal peaks spread over a decade,
//!   matching the paper's "group the dataset into short-running and
//!   relatively long-running requests" methodology and the Fig. 8–10
//!   sweeps (modality 1–8, per-peak σ, unequal peak weights).
//! * **Real-task presets** — the Table 1 model/dataset pairs, parameterized
//!   by the paper's published mean and P99 (a 2-parameter lognormal or a
//!   multi-modal shape for the early-exit CV models).

use crate::core::histogram::Histogram;
use crate::util::rng::Rng;

/// A sampleable execution-time distribution.
#[derive(Debug, Clone)]
pub struct ExecTimeDist {
    /// Mixture components: (weight, mu, sigma) of lognormals (ms).
    components: Vec<(f64, f64, f64)>,
    pub name: String,
}

impl ExecTimeDist {
    /// k-modal lognormal mixture. Peaks are log-spaced between `lo_ms` and
    /// `hi_ms`; `sigma` is the per-peak lognormal σ (the paper's "std-σ"
    /// cases); `weights` are per-peak (uniform if None).
    pub fn multimodal(
        name: &str,
        k: usize,
        lo_ms: f64,
        hi_ms: f64,
        sigma: f64,
        weights: Option<Vec<f64>>,
    ) -> Self {
        assert!(k >= 1 && lo_ms > 0.0 && hi_ms >= lo_ms);
        let w = weights.unwrap_or_else(|| vec![1.0; k]);
        assert_eq!(w.len(), k);
        let mut components = Vec::with_capacity(k);
        for (i, wi) in w.iter().enumerate() {
            let frac = if k == 1 {
                0.5
            } else {
                i as f64 / (k - 1) as f64
            };
            let center = lo_ms * (hi_ms / lo_ms).powf(frac);
            // lognormal with median `center`; σ in log-space scaled so the
            // paper's σ∈{0.5,1,2} spans overlapping↔separated peaks over a
            // decade of spread.
            let mu = center.ln();
            components.push((*wi, mu, sigma * 0.25));
        }
        ExecTimeDist {
            components,
            name: name.to_string(),
        }
    }

    /// Single lognormal with target mean and p99 (used for the Table 1 NLP
    /// tasks, whose measured histograms are continuous and right-skewed).
    pub fn lognormal_mean_p99(name: &str, mean_ms: f64, p99_ms: f64) -> Self {
        assert!(p99_ms > mean_ms && mean_ms > 0.0);
        // Solve mean = exp(mu + s²/2), p99 = exp(mu + 2.326·s).
        // => ln(p99) − ln(mean) = 2.326 s − s²/2  (quadratic in s)
        let gap = (p99_ms / mean_ms).ln();
        let z = 2.326;
        // s²/2 − z·s + gap = 0 → s = z − sqrt(z² − 2·gap)
        let disc = (z * z - 2.0 * gap).max(0.0);
        let s = (z - disc.sqrt()).max(0.02);
        let mu = mean_ms.ln() - 0.5 * s * s;
        ExecTimeDist {
            components: vec![(1.0, mu, s)],
            name: name.to_string(),
        }
    }

    /// Discrete code-path mixture for early-exit CV models (SkipNet /
    /// RDI-Nets, Fig. 2): a few tight clusters at distinct path costs.
    pub fn codepaths(name: &str, paths_ms: &[f64], weights: &[f64]) -> Self {
        assert_eq!(paths_ms.len(), weights.len());
        let components = paths_ms
            .iter()
            .zip(weights)
            .map(|(&c, &w)| (w, c.ln(), 0.05))
            .collect();
        ExecTimeDist {
            components,
            name: name.to_string(),
        }
    }

    /// Constant execution time (static DNNs, Fig. 11 / Table 4).
    pub fn constant(name: &str, ms: f64) -> Self {
        ExecTimeDist {
            components: vec![(1.0, ms.ln(), 1e-4)],
            name: name.to_string(),
        }
    }

    /// Multiply all execution times by `s` (Fig. 14 sweep).
    pub fn scaled(&self, s: f64) -> Self {
        assert!(s > 0.0);
        ExecTimeDist {
            components: self
                .components
                .iter()
                .map(|&(w, mu, sg)| (w, mu + s.ln(), sg))
                .collect(),
            name: format!("{}×{:.3}", self.name, s),
        }
    }

    /// Draw one execution time (ms).
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        let weights: Vec<f64> = self.components.iter().map(|c| c.0).collect();
        let i = rng.weighted(&weights);
        let (_, mu, sigma) = self.components[i];
        rng.lognormal(mu, sigma).max(1e-3)
    }

    /// Materialize as a histogram (for seeding profilers / SLO reference).
    pub fn histogram(&self, rng: &mut Rng, samples: usize, bins: usize) -> Histogram {
        let v: Vec<f64> = (0..samples).map(|_| self.sample(rng)).collect();
        Histogram::from_samples(&v, bins)
    }

    /// P99 from sampling (the paper's SLO reference, §5.2 Metrics).
    pub fn p99(&self, rng: &mut Rng, samples: usize) -> f64 {
        let mut v: Vec<f64> = (0..samples).map(|_| self.sample(rng)).collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        crate::util::stats::percentile_sorted(&v, 99.0)
    }
}

/// A Table 1 workload entry: name + target mean/p99 + the distribution.
#[derive(Debug, Clone)]
pub struct RealTask {
    pub id: &'static str,
    pub mean_ms: f64,
    pub p99_ms: f64,
    pub dist: ExecTimeDist,
}

/// The paper's Table 1 (model, dataset, mean, P99) presets.
pub fn table1_tasks() -> Vec<RealTask> {
    fn nlp(id: &'static str, mean: f64, p99: f64) -> RealTask {
        RealTask {
            id,
            mean_ms: mean,
            p99_ms: p99,
            dist: ExecTimeDist::lognormal_mean_p99(id, mean, p99),
        }
    }
    let mut tasks = vec![
        // Image classification (early-exit, multi-path).
        RealTask {
            id: "rdinet-cifar",
            mean_ms: 683.15,
            p99_ms: 2667.54,
            // Three exits: early ones common, deep path rare but 4–8×.
            dist: ExecTimeDist::codepaths(
                "rdinet-cifar",
                &[320.0, 700.0, 2400.0],
                &[0.45, 0.45, 0.10],
            ),
        },
        RealTask {
            id: "skipnet-imagenet",
            mean_ms: 3.24,
            p99_ms: 5.56,
            dist: ExecTimeDist::codepaths(
                "skipnet-imagenet",
                &[2.2, 3.3, 5.4],
                &[0.4, 0.45, 0.15],
            ),
        },
    ];
    tasks.push(nlp("blenderbot-convai", 200.39, 242.27));
    tasks.push(nlp("blenderbot-cornell", 203.22, 247.04));
    tasks.push(nlp("gpt-convai", 79.47, 143.40));
    tasks.push(nlp("gpt-cornell", 94.84, 161.69));
    tasks.push(nlp("bart-cnn", 774.66, 1101.99));
    tasks.push(nlp("t5-cnn", 552.91, 797.28));
    tasks.push(nlp("fsmt-wmt", 189.30, 319.31));
    tasks.push(nlp("mbart-wmt", 432.38, 729.87));
    tasks
}

/// Static models of Table 4 / Fig. 11. V100-scale single-image latencies.
pub fn static_tasks() -> Vec<RealTask> {
    vec![
        RealTask {
            id: "resnet-imagenet",
            mean_ms: 6.0,
            p99_ms: 6.0,
            dist: ExecTimeDist::constant("resnet-imagenet", 6.0),
        },
        RealTask {
            id: "inception-imagenet",
            mean_ms: 9.0,
            p99_ms: 9.0,
            dist: ExecTimeDist::constant("inception-imagenet", 9.0),
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn multimodal_peaks_spread() {
        let mut rng = Rng::new(1);
        let d = ExecTimeDist::multimodal("bi", 2, 10.0, 100.0, 1.0, None);
        let h = d.histogram(&mut rng, 50_000, 100);
        // Bimodal over [10,100]: mass near both ends, overall mean ~55.
        assert!(h.cdf(30.0) > 0.35 && h.cdf(30.0) < 0.65, "cdf(30)={}", h.cdf(30.0));
        let mean = h.mean();
        assert!(mean > 40.0 && mean < 75.0, "mean={mean}");
    }

    #[test]
    fn modality_increases_variance_span() {
        let mut rng = Rng::new(2);
        let d1 = ExecTimeDist::multimodal("m1", 1, 10.0, 100.0, 1.0, None);
        let d8 = ExecTimeDist::multimodal("m8", 8, 10.0, 100.0, 1.0, None);
        let h1 = d1.histogram(&mut rng, 30_000, 100);
        let h8 = d8.histogram(&mut rng, 30_000, 100);
        assert!(
            h8.variance() > h1.variance(),
            "8-modal should vary more: {} vs {}",
            h8.variance(),
            h1.variance()
        );
    }

    #[test]
    fn lognormal_hits_mean_and_p99() {
        let mut rng = Rng::new(3);
        let d = ExecTimeDist::lognormal_mean_p99("x", 100.0, 180.0);
        let n = 200_000;
        let samples: Vec<f64> = (0..n).map(|_| d.sample(&mut rng)).collect();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let p99 = crate::util::stats::percentile(&samples, 99.0);
        assert!((mean - 100.0).abs() / 100.0 < 0.03, "mean={mean}");
        assert!((p99 - 180.0).abs() / 180.0 < 0.08, "p99={p99}");
    }

    #[test]
    fn table1_presets_match_published_stats() {
        let mut rng = Rng::new(4);
        for task in table1_tasks() {
            let n = 100_000;
            let samples: Vec<f64> = (0..n).map(|_| task.dist.sample(&mut rng)).collect();
            let mean = samples.iter().sum::<f64>() / n as f64;
            let p99 = crate::util::stats::percentile(&samples, 99.0);
            // NLP lognormals should be tight; the multi-path CV models are
            // shape-matched (multi-cluster), so allow wider tolerance.
            let tol_mean = 0.25;
            let tol_p99 = 0.30;
            assert!(
                (mean - task.mean_ms).abs() / task.mean_ms < tol_mean,
                "{}: mean {mean} vs {}",
                task.id,
                task.mean_ms
            );
            assert!(
                (p99 - task.p99_ms).abs() / task.p99_ms < tol_p99,
                "{}: p99 {p99} vs {}",
                task.id,
                task.p99_ms
            );
        }
    }

    #[test]
    fn constant_task_has_no_variance() {
        let mut rng = Rng::new(5);
        let d = ExecTimeDist::constant("c", 6.0);
        for _ in 0..100 {
            let s = d.sample(&mut rng);
            assert!((s - 6.0).abs() < 0.05, "s={s}");
        }
    }

    #[test]
    fn scaled_shifts_everything() {
        let mut rng = Rng::new(6);
        let d = ExecTimeDist::multimodal("m3", 3, 10.0, 100.0, 1.0, None);
        let s = d.scaled(0.1);
        let p99_full = d.p99(&mut rng, 20_000);
        let p99_small = s.p99(&mut rng, 20_000);
        assert!(
            (p99_small - 0.1 * p99_full).abs() / (0.1 * p99_full) < 0.1,
            "{p99_small} vs {}",
            0.1 * p99_full
        );
    }

    #[test]
    fn unequal_weights_shift_mass() {
        let mut rng = Rng::new(7);
        let more_short =
            ExecTimeDist::multimodal("s", 2, 10.0, 100.0, 1.0, Some(vec![0.8, 0.2]));
        let more_long =
            ExecTimeDist::multimodal("l", 2, 10.0, 100.0, 1.0, Some(vec![0.2, 0.8]));
        let hs = more_short.histogram(&mut rng, 30_000, 64);
        let hl = more_long.histogram(&mut rng, 30_000, 64);
        assert!(hs.mean() < hl.mean());
    }
}
