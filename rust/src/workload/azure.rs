//! Azure-Functions-like arrival process (paper §5.2 "Input Trace").
//!
//! The paper replays the Microsoft Azure Functions trace [Shahrad et al.,
//! ATC'20] scaled so the incoming rate matches system load. The trace file
//! is not redistributable, so this module synthesizes an arrival process
//! with its published statistical signature:
//!
//! * heavy-tailed per-application request rates (a few hot apps, a long
//!   tail of cold ones) — Pareto-distributed app weights;
//! * bursty, minute-scale rate modulation per app (lognormal multiplicative
//!   noise on a slow sinusoidal "diurnal" carrier);
//! * Poisson arrivals within each minute bucket.
//!
//! The generated trace is deterministic given the seed and is recorded/
//! replayed via `workload::trace` so all four systems see byte-identical
//! arrival sequences (§5.2: "the generation is done once among different
//! runs").

use crate::clock::{ms_to_us, Micros};
use crate::util::rng::Rng;

/// Arrival-process configuration.
#[derive(Debug, Clone)]
pub struct AzureTraceConfig {
    /// Number of applications multiplexed onto the model.
    pub apps: usize,
    /// Mean aggregate request rate (req/s) after scaling to system load.
    pub rate_per_s: f64,
    /// Trace duration (seconds).
    pub duration_s: f64,
    /// Rate-modulation bucket (seconds); Azure publishes per-minute counts,
    /// we default to finer 10 s buckets scaled for shorter experiments.
    pub bucket_s: f64,
    /// Burstiness: σ of the lognormal multiplicative noise per bucket.
    pub burst_sigma: f64,
}

impl Default for AzureTraceConfig {
    fn default() -> Self {
        AzureTraceConfig {
            apps: 2,
            rate_per_s: 100.0,
            duration_s: 60.0,
            bucket_s: 5.0,
            burst_sigma: 0.3,
        }
    }
}

/// One synthesized arrival: (time µs, app index).
pub type Arrival = (Micros, usize);

/// Generate the arrival sequence.
pub fn generate(cfg: &AzureTraceConfig, rng: &mut Rng) -> Vec<Arrival> {
    assert!(cfg.apps >= 1 && cfg.rate_per_s > 0.0 && cfg.duration_s > 0.0);
    // Heavy-tailed app weights (Pareto α≈1.1 like the FaaS popularity
    // distribution), normalized.
    let mut weights: Vec<f64> = (0..cfg.apps).map(|_| rng.pareto(1.0, 1.1)).collect();
    let wsum: f64 = weights.iter().sum();
    for w in &mut weights {
        *w /= wsum;
    }
    let buckets = (cfg.duration_s / cfg.bucket_s).ceil() as usize;
    let mut arrivals: Vec<Arrival> = Vec::new();
    // Per-app random phase for the slow carrier.
    let phases: Vec<f64> = (0..cfg.apps).map(|_| rng.f64() * std::f64::consts::TAU).collect();
    for b in 0..buckets {
        let t0 = b as f64 * cfg.bucket_s;
        for app in 0..cfg.apps {
            // Carrier: slow sinusoid (diurnal-like), ±30%.
            let carrier = 1.0 + 0.3 * (t0 / cfg.duration_s * std::f64::consts::TAU + phases[app]).sin();
            // Burst: lognormal multiplicative noise per bucket.
            let burst = rng.lognormal(0.0, cfg.burst_sigma);
            let lam = cfg.rate_per_s * weights[app] * carrier * burst * cfg.bucket_s;
            let n = rng.poisson(lam);
            for _ in 0..n {
                let at = t0 + rng.f64() * cfg.bucket_s;
                if at < cfg.duration_s {
                    arrivals.push((ms_to_us(at * 1000.0), app));
                }
            }
        }
    }
    arrivals.sort_unstable_by_key(|a| a.0);
    arrivals
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_roughly_matches_target() {
        let mut rng = Rng::new(1);
        let cfg = AzureTraceConfig {
            apps: 3,
            rate_per_s: 200.0,
            duration_s: 50.0,
            ..Default::default()
        };
        let arr = generate(&cfg, &mut rng);
        let rate = arr.len() as f64 / cfg.duration_s;
        assert!(
            (rate - 200.0).abs() / 200.0 < 0.35,
            "rate={rate} (bursty, so loose tolerance)"
        );
    }

    #[test]
    fn arrivals_sorted_and_in_range() {
        let mut rng = Rng::new(2);
        let cfg = AzureTraceConfig::default();
        let arr = generate(&cfg, &mut rng);
        assert!(!arr.is_empty());
        for w in arr.windows(2) {
            assert!(w[0].0 <= w[1].0);
        }
        let end = ms_to_us(cfg.duration_s * 1000.0);
        assert!(arr.iter().all(|&(t, app)| t < end && app < cfg.apps));
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = AzureTraceConfig::default();
        let a = generate(&cfg, &mut Rng::new(7));
        let b = generate(&cfg, &mut Rng::new(7));
        assert_eq!(a, b);
        let c = generate(&cfg, &mut Rng::new(8));
        assert_ne!(a, c);
    }

    #[test]
    fn app_shares_are_heavy_tailed() {
        let mut rng = Rng::new(3);
        let cfg = AzureTraceConfig {
            apps: 10,
            rate_per_s: 500.0,
            duration_s: 40.0,
            ..Default::default()
        };
        let arr = generate(&cfg, &mut rng);
        let mut counts = vec![0usize; cfg.apps];
        for &(_, app) in &arr {
            counts[app] += 1;
        }
        counts.sort_unstable_by(|a, b| b.cmp(a));
        // Hottest app should clearly dominate the median app (Pareto
        // weights; exact skew varies with seed).
        assert!(
            counts[0] > 2 * counts[cfg.apps / 2].max(1),
            "counts={counts:?}"
        );
    }

    #[test]
    fn bursts_create_rate_variation() {
        let mut rng = Rng::new(4);
        let cfg = AzureTraceConfig {
            apps: 1,
            rate_per_s: 300.0,
            duration_s: 60.0,
            bucket_s: 5.0,
            burst_sigma: 0.5,
        };
        let arr = generate(&cfg, &mut rng);
        // Count per bucket; coefficient of variation should be well above
        // a plain Poisson's.
        let buckets = 12;
        let mut counts = vec![0f64; buckets];
        for &(t, _) in &arr {
            let b = ((t as f64 / 1e6) / 5.0) as usize;
            counts[b.min(buckets - 1)] += 1.0;
        }
        let mean = crate::util::stats::mean(&counts);
        let std = crate::util::stats::stddev(&counts);
        let poisson_cv = 1.0 / mean.sqrt();
        assert!(
            std / mean > 2.0 * poisson_cv,
            "cv={} poisson_cv={poisson_cv}",
            std / mean
        );
    }
}
