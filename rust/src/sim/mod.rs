//! Discrete-event evaluation substrate: virtual-time worker, engine, and
//! the (system × workload × SLO) experiment runner used by every table and
//! figure reproduction.

pub mod engine;
pub mod runner;
pub mod worker;
