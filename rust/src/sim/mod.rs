//! Discrete-event evaluation substrate: virtual-time worker, the
//! single-worker engine shim over the unified serving core
//! (`crate::serve`), and the (system × workload × SLO × replica count)
//! experiment runner used by every table and figure reproduction.

pub mod engine;
pub mod runner;
pub mod worker;
