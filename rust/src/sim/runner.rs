//! Experiment runner: (system × trace × SLO multiple × replica count) →
//! finish rate.
//!
//! This is the evaluation harness behind every table and figure (§5): it
//! replays the identical recorded trace through each system at each SLO
//! setting, seeds every scheduler with the same deployment-time profile,
//! and reports the paper's metrics. Scale-out runs build an N-replica
//! [`Cluster`] (one scheduler instance per replica, §3.1) with a
//! [`Router`](crate::serve::Router) front-end.

use crate::clock::VirtualClock;
use crate::scheduler::SchedulerConfig;
use crate::serve::{
    replay, router, AdmissionConfig, AdmissionController, AdmissionStats, Cluster, ElasticConfig,
    Placement, PlacementController, PlacementStats, ServingLoop,
};
use crate::server::metrics::RunReport;
use crate::sim::worker::SimWorker;
use crate::telemetry::{Recorder, RecorderConfig};
use crate::workload::trace::{Trace, TraceSpec};

/// Replica-count, routing, model-placement and elasticity knobs for a
/// run (workers=1 with the default "all" placement and no controller
/// reproduces the historical single-loop harness exactly).
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub workers: usize,
    pub router: String,
    /// Placement spec (`serve::Placement::parse`): `all`, `partition`,
    /// `skewed`, or an explicit `"0,1;1;0"` worker→models list. Under
    /// elastic control this is the *initial* placement.
    pub placement: String,
    /// Elastic placement controller config (None = static placement).
    pub elastic: Option<ElasticConfig>,
    /// Record request-lifecycle telemetry (off by default: the recorder
    /// costs one branch per hook even when disabled, and real memory when
    /// enabled).
    pub telemetry: bool,
    /// Predictive admission control at this P(finish ≤ deadline) admit
    /// threshold (None = off; DESIGN.md §10). The controller is seeded
    /// with the same deployment-time histograms as the schedulers.
    pub admission: Option<f64>,
    /// Parallel event lanes for the virtual-time pump (DESIGN.md §11).
    /// 1 = sequential; >1 shards the replicas across scoped threads when
    /// the configuration is parallel-safe (and falls back to the
    /// sequential pump otherwise — results are identical either way).
    pub shards: usize,
    /// Also run the sequential pump and assert the sharded run produced a
    /// byte-identical completion sequence (costs a second full replay;
    /// meaningful only with `shards > 1`).
    pub cross_check: bool,
}

impl Default for ClusterSpec {
    fn default() -> Self {
        ClusterSpec {
            workers: 1,
            router: "round_robin".into(),
            placement: "all".into(),
            elastic: None,
            telemetry: false,
            admission: None,
            shards: 1,
            cross_check: false,
        }
    }
}

impl ClusterSpec {
    pub fn new(workers: usize, router: &str) -> Self {
        ClusterSpec {
            workers: workers.max(1),
            router: router.to_string(),
            placement: "all".into(),
            elastic: None,
            telemetry: false,
            admission: None,
            shards: 1,
            cross_check: false,
        }
    }

    pub fn with_placement(mut self, placement: &str) -> Self {
        self.placement = placement.to_string();
        self
    }

    /// Enable the elastic placement controller (requires an explicit
    /// placement spec — `all`/`partition`/`skewed`/explicit lists all
    /// qualify; they parse to concrete worker→models tables).
    pub fn with_elastic(mut self, cfg: ElasticConfig) -> Self {
        self.elastic = Some(cfg);
        self
    }

    /// Capture request-lifecycle telemetry; the filled recorder comes
    /// back on [`Cell::telemetry`].
    pub fn with_telemetry(mut self) -> Self {
        self.telemetry = true;
        self
    }

    /// Enable predictive admission control at `threshold` (DESIGN.md §10).
    pub fn with_admission(mut self, threshold: f64) -> Self {
        self.admission = Some(threshold);
        self
    }

    /// Shard the virtual-time pump across `shards` parallel event lanes
    /// (DESIGN.md §11; no-op on configurations that are not parallel-safe).
    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards.max(1);
        self
    }

    /// Re-run the sequential pump alongside the sharded one and assert
    /// identical completion sequences (determinism cross-check).
    pub fn with_cross_check(mut self) -> Self {
        self.cross_check = true;
        self
    }
}

/// One (system, slo) cell of a results table.
#[derive(Debug, Clone)]
pub struct Cell {
    pub system: String,
    pub slo_multiple: f64,
    pub report: RunReport,
    /// Aggregate utilization: total busy time / (workers × run length).
    pub utilization: f64,
    pub workers: usize,
    /// Elastic placement counters (all zero on static runs).
    pub placement: PlacementStats,
    /// Admission-control tallies (disabled + all-zero without a
    /// controller).
    pub admission: AdmissionStats,
    /// Filled lifecycle recorder (only when the [`ClusterSpec`] asked
    /// for telemetry).
    pub telemetry: Option<Box<Recorder>>,
}

/// Run one system over one trace at one SLO multiple.
pub fn run_one(
    system: &str,
    spec: &TraceSpec,
    trace: &Trace,
    slo_multiple: f64,
    cfg: &SchedulerConfig,
    seed: u64,
    cluster: &ClusterSpec,
) -> Cell {
    let n = cluster.workers.max(1);
    let n_models = spec.models.len().max(1);
    let placement = match Placement::parse_checked(&cluster.placement, n, n_models) {
        Ok(p) => p,
        Err(why) => panic!(
            "bad placement '{}' for {n} workers × {n_models} models: {why}",
            cluster.placement
        ),
    };
    // Heterogeneous co-located models get per-model cost curves derived
    // from the spec (no-op for single-model specs).
    let mut cfg = cfg.clone();
    if cfg.model_costs.is_empty() {
        cfg.model_costs = spec.model_cost_models();
    }
    let requests = trace.requests(slo_multiple);
    // Identical seeding on every call: the determinism cross-check
    // rebuilds the whole core and must get byte-identical state.
    let build = |requests_len: usize| {
        let mut replicas = Cluster::build_placed(system, &cfg, seed, placement.clone())
            .unwrap_or_else(|| panic!("unknown system {system}"));
        let mut admission_ctl = cluster
            .admission
            .map(|t| AdmissionController::new(AdmissionConfig::with_threshold(t)));
        for (model, app, hist) in spec.seed_histograms(cfg.bins) {
            if cluster.elastic.is_some() {
                // Any replica may acquire any model at runtime: deployment-
                // time profiles go everywhere, hosting or not.
                replicas.seed_app_profile_everywhere(model, app, &hist, 1000);
            } else {
                replicas.seed_app_profile(model, app, &hist, 1000);
            }
            if let Some(ctl) = admission_ctl.as_mut() {
                // The gate sees the same deployment-time profiles as the
                // schedulers; it refines nothing at runtime (DESIGN.md §10).
                ctl.seed_profile(model, app, &hist);
            }
        }
        let workers: Vec<SimWorker> = (0..n)
            .map(|w| {
                SimWorker::new(cfg.cost_model, 0.0, seed ^ 0x5151 ^ ((w as u64) << 16))
                    .with_model_costs(cfg.model_costs.clone())
            })
            .collect();
        let route = router::by_name(&cluster.router)
            .unwrap_or_else(|| panic!("unknown router {}", cluster.router));
        let mut core = ServingLoop::new(VirtualClock::new(), replicas, route);
        if let Some(ecfg) = &cluster.elastic {
            core = core.with_elastic(PlacementController::new(ecfg.clone()));
        }
        if let Some(ctl) = admission_ctl {
            core = core.with_admission(ctl);
        }
        if cluster.telemetry {
            // Generous ring: every request produces a handful of lifecycle
            // events plus per-batch and per-wake events; undersizing would
            // drop the early Terminals that the conservation checks need.
            let capacity = (requests_len * 16).max(1 << 14);
            core = core.with_telemetry(Recorder::with_config(RecorderConfig {
                capacity,
                ..Default::default()
            }));
        }
        (core, workers)
    };
    let shards = cluster.shards.max(1);
    let res = if cluster.cross_check && shards > 1 {
        let (core, workers) = build(requests.len());
        let (core_seq, workers_seq) = build(requests.len());
        let seq = replay::run_cluster_sharded(core_seq, workers_seq, requests.clone(), 1);
        let res = replay::run_cluster_sharded(core, workers, requests, shards);
        assert_eq!(
            format!("{:?}", res.completions),
            format!("{:?}", seq.completions),
            "{system}: sharded replay diverged from the sequential pump"
        );
        res
    } else {
        let (core, workers) = build(requests.len());
        replay::run_cluster_sharded(core, workers, requests, shards)
    };
    let report =
        RunReport::from_completions(&res.completions).with_worker_stats(&res.per_worker, res.end_time);
    let utilization = if res.end_time > 0 {
        res.busy_us as f64 / (res.end_time as f64 * n as f64)
    } else {
        0.0
    };
    Cell {
        system: system.to_string(),
        slo_multiple,
        report,
        utilization,
        workers: n,
        placement: res.placement,
        admission: res.admission,
        telemetry: res.telemetry,
    }
}

/// Run the full (systems × SLOs) grid over one trace.
pub fn run_grid(
    systems: &[&str],
    spec: &TraceSpec,
    slo_multiples: &[f64],
    cfg: &SchedulerConfig,
    seed: u64,
    cluster: &ClusterSpec,
) -> Vec<Cell> {
    let trace = spec.generate();
    let mut cells = Vec::new();
    for &slo in slo_multiples {
        for system in systems {
            cells.push(run_one(system, spec, &trace, slo, cfg, seed, cluster));
        }
    }
    cells
}

/// Render a grid as a paper-style table (rows: SLO; columns: systems).
pub fn render_table(title: &str, cells: &[Cell], systems: &[&str]) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    writeln!(out, "### {title}").unwrap();
    write!(out, "{:>10} ", "SLO(xP99)").unwrap();
    for s in systems {
        write!(out, "{:>10} ", s).unwrap();
    }
    writeln!(out).unwrap();
    let mut slos: Vec<f64> = cells.iter().map(|c| c.slo_multiple).collect();
    slos.sort_by(|a, b| a.partial_cmp(b).unwrap());
    slos.dedup();
    for slo in slos {
        write!(out, "{:>10} ", format!("{slo:.1}")).unwrap();
        for s in systems {
            let cell = cells
                .iter()
                .find(|c| c.slo_multiple == slo && c.system == *s);
            match cell {
                Some(c) => write!(out, "{:>10.2} ", c.report.finish_rate()).unwrap(),
                None => write!(out, "{:>10} ", "-").unwrap(),
            }
        }
        writeln!(out).unwrap();
    }
    out
}

/// Render per-replica utilization / batch counts (the multi-worker
/// counterpart of `render_table`).
pub fn render_worker_util(title: &str, cells: &[Cell]) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    writeln!(out, "-- {title} --").unwrap();
    for c in cells {
        let utils: Vec<String> = c
            .report
            .per_worker
            .iter()
            .map(|w| format!("w{}={:.2}({}b)", w.worker, w.utilization, w.batches))
            .collect();
        writeln!(
            out,
            "{:>10} slo={:<4} {}",
            c.system,
            format!("{:.1}", c.slo_multiple),
            utils.join(" ")
        )
        .unwrap();
    }
    out
}

/// Render elastic placement counters (load/unload actions, re-routed
/// requests, convergence time) for cells run under a controller.
pub fn render_placement_actions(title: &str, cells: &[Cell]) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    writeln!(out, "-- {title} --").unwrap();
    for c in cells {
        writeln!(
            out,
            "{:>10} slo={:<4} loads={} unloads={} rerouted={} react={:.1}s last={:.1}s",
            c.system,
            format!("{:.1}", c.slo_multiple),
            c.placement.loads,
            c.placement.unloads,
            c.placement.rerouted,
            c.placement.first_action_at as f64 / 1e6,
            c.placement.last_action_at as f64 / 1e6,
        )
        .unwrap();
    }
    out
}

/// Render per-model finish rates for cells that co-serve several models.
pub fn render_model_rates(title: &str, cells: &[Cell]) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    writeln!(out, "-- {title} --").unwrap();
    for c in cells {
        let rates: Vec<String> = c
            .report
            .per_model
            .iter()
            .map(|(m, r)| {
                format!(
                    "m{}={:.2}({}r,p99={:.0}ms)",
                    m,
                    r.finish_rate(),
                    r.total,
                    r.latency.p99
                )
            })
            .collect();
        writeln!(
            out,
            "{:>10} slo={:<4} {}",
            c.system,
            format!("{:.1}", c.slo_multiple),
            rates.join(" ")
        )
        .unwrap();
    }
    out
}

/// Render estimator calibration (predicted vs. realized batch latency,
/// per (model, app)) for cells run with telemetry enabled.
pub fn render_calibration(title: &str, cells: &[Cell]) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    writeln!(out, "-- {title} --").unwrap();
    for c in cells {
        let Some(rec) = &c.telemetry else { continue };
        let rows = rec.calibration();
        if rows.is_empty() {
            continue;
        }
        writeln!(
            out,
            "{:>10} slo={:<4} ({} events, {} dropped)",
            c.system,
            format!("{:.1}", c.slo_multiple),
            rec.recorded(),
            rec.dropped_events(),
        )
        .unwrap();
        out.push_str(&crate::telemetry::calibration_table(&rows));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines;
    use crate::core::batchmodel::BatchCostModel;
    use crate::workload::azure::AzureTraceConfig;
    use crate::workload::exectime::ExecTimeDist;
    use crate::workload::trace::ModelTraffic;

    fn small_spec(bimodal: bool) -> TraceSpec {
        let dists = if bimodal {
            vec![ExecTimeDist::multimodal("bi", 2, 5.0, 50.0, 1.0, None)]
        } else {
            vec![ExecTimeDist::constant("static", 10.0)]
        };
        let mut spec = TraceSpec {
            name: "unit".into(),
            dists,
            arrivals: AzureTraceConfig {
                apps: 1,
                rate_per_s: 0.0, // set by scaling
                duration_s: 20.0,
                ..Default::default()
            },
            seed: 77,
            models: Vec::new(),
        };
        spec.scale_rate_to_load(BatchCostModel::gpu_like(), 0.6, 8);
        spec
    }

    fn multimodel_spec() -> TraceSpec {
        let mut spec = TraceSpec {
            name: "mm-unit".into(),
            dists: Vec::new(),
            arrivals: AzureTraceConfig {
                apps: 1,
                rate_per_s: 0.0,
                duration_s: 15.0,
                ..Default::default()
            },
            seed: 78,
            models: vec![
                ModelTraffic::new(0, 0.7, vec![ExecTimeDist::constant("fast", 8.0)]),
                ModelTraffic::new(
                    1,
                    0.3,
                    vec![ExecTimeDist::multimodal("slow", 2, 15.0, 80.0, 1.0, None)],
                ),
            ],
        };
        spec.scale_rate_to_load(BatchCostModel::gpu_like(), 0.6, 8);
        spec
    }

    fn cfg() -> SchedulerConfig {
        SchedulerConfig {
            cost_model: BatchCostModel::gpu_like(),
            ..Default::default()
        }
    }

    #[test]
    fn all_four_systems_run_to_completion() {
        let spec = small_spec(true);
        let cells = run_grid(
            &baselines::PAPER_SYSTEMS,
            &spec,
            &[3.0],
            &cfg(),
            1,
            &ClusterSpec::default(),
        );
        assert_eq!(cells.len(), 4);
        for c in &cells {
            assert!(c.report.total > 50, "{}: total={}", c.system, c.report.total);
            assert!(c.report.finish_rate() >= 0.0 && c.report.finish_rate() <= 1.0);
            assert_eq!(c.workers, 1);
            assert_eq!(c.report.per_worker.len(), 1);
        }
    }

    #[test]
    fn orloj_beats_point_estimators_on_bimodal() {
        // The paper's headline directional claim at a moderate SLO.
        let spec = small_spec(true);
        let cells = run_grid(
            &["clockwork", "orloj"],
            &spec,
            &[3.0],
            &cfg(),
            2,
            &ClusterSpec::default(),
        );
        let get = |name: &str| {
            cells
                .iter()
                .find(|c| c.system == name)
                .unwrap()
                .report
                .finish_rate()
        };
        assert!(
            get("orloj") > get("clockwork"),
            "orloj {} vs clockwork {}",
            get("orloj"),
            get("clockwork")
        );
    }

    #[test]
    fn static_workload_everyone_reasonable() {
        let spec = small_spec(false);
        let cells = run_grid(
            &["clockwork", "orloj"],
            &spec,
            &[4.0],
            &cfg(),
            3,
            &ClusterSpec::default(),
        );
        for c in &cells {
            assert!(
                c.report.finish_rate() > 0.7,
                "{} should do fine on static: {}",
                c.system,
                c.report.finish_rate()
            );
        }
    }

    #[test]
    fn multi_worker_grid_reports_per_replica_stats() {
        let spec = small_spec(true);
        for router_name in crate::serve::router::ROUTERS {
            let cells = run_grid(
                &["orloj"],
                &spec,
                &[3.0],
                &cfg(),
                5,
                &ClusterSpec::new(2, router_name),
            );
            let c = &cells[0];
            assert_eq!(c.workers, 2, "{router_name}");
            assert_eq!(c.report.per_worker.len(), 2, "{router_name}");
            assert_eq!(
                c.report.total,
                spec.generate().events.len(),
                "{router_name}: conservation"
            );
            // Same offered load over twice the capacity → roughly at least
            // as many requests finish as on one worker (3% slack for lost
            // batching efficiency).
            let single = run_grid(
                &["orloj"],
                &spec,
                &[3.0],
                &cfg(),
                5,
                &ClusterSpec::default(),
            );
            assert!(
                c.report.finished as f64 >= 0.97 * single[0].report.finished as f64,
                "{router_name}: 2 workers ({}) should not lose to 1 ({})",
                c.report.finished,
                single[0].report.finished
            );
        }
    }

    #[test]
    fn render_table_has_all_rows() {
        let spec = small_spec(true);
        let cells = run_grid(
            &["orloj"],
            &spec,
            &[1.5, 3.0],
            &cfg(),
            4,
            &ClusterSpec::default(),
        );
        let table = render_table("t", &cells, &["orloj"]);
        assert!(table.contains("1.5"));
        assert!(table.contains("3.0") || table.contains("3"));
        let util = render_worker_util("u", &cells);
        assert!(util.contains("w0="));
    }

    #[test]
    fn multimodel_grid_conserves_and_reports_per_model() {
        let spec = multimodel_spec();
        let trace = spec.generate();
        for placement in ["all", "partition", "skewed"] {
            let cells = run_grid(
                &["edf", "orloj"],
                &spec,
                &[3.0],
                &cfg(),
                6,
                &ClusterSpec::new(2, "least_loaded").with_placement(placement),
            );
            for c in &cells {
                assert_eq!(
                    c.report.total,
                    trace.events.len(),
                    "{placement}/{}: conservation",
                    c.system
                );
                assert_eq!(c.report.per_model.len(), 2, "{placement}/{}", c.system);
                let rendered = render_model_rates("per-model", &cells);
                assert!(rendered.contains("m0="), "{rendered}");
                assert!(rendered.contains("m1="), "{rendered}");
            }
        }
    }

    #[test]
    fn elastic_runs_conserve_and_take_actions() {
        // A drifting 2-model mix over 4 capacity-1 workers: the elastic
        // controller must act (the hot model rotates), and conservation
        // must hold across every evict-triggered re-route.
        let spec = multimodel_spec().drift_rotating(5.0, 0.9);
        let trace = spec.generate();
        let ecfg = ElasticConfig {
            capacity: 1,
            interval_us: 250_000,
            alpha: 0.5,
            min_dwell_us: 1_000_000,
            ..Default::default()
        };
        let cells = run_grid(
            &["edf", "orloj"],
            &spec,
            &[3.0],
            &cfg(),
            9,
            &ClusterSpec::new(4, "least_loaded")
                .with_placement("partition")
                .with_elastic(ecfg),
        );
        for c in &cells {
            assert_eq!(
                c.report.total,
                trace.events.len(),
                "{}: conservation under elastic placement",
                c.system
            );
            assert!(
                c.placement.actions() > 0,
                "{}: a rotating hot model must force placement actions",
                c.system
            );
            assert!(c.placement.last_action_at > 0);
        }
        let rendered = render_placement_actions("elastic", &cells);
        assert!(rendered.contains("loads="), "{rendered}");
    }

    #[test]
    #[should_panic(expected = "bad placement")]
    fn bad_placement_panics_loudly() {
        let spec = multimodel_spec();
        let trace = spec.generate();
        run_one(
            "edf",
            &spec,
            &trace,
            3.0,
            &cfg(),
            1,
            &ClusterSpec::new(2, "round_robin").with_placement("0;0"),
        );
    }
}
