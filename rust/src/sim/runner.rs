//! Experiment runner: (system × trace × SLO multiple) → finish rate.
//!
//! This is the evaluation harness behind every table and figure (§5): it
//! replays the identical recorded trace through each system at each SLO
//! setting, seeds every scheduler with the same deployment-time profile,
//! and reports the paper's metrics.

use super::engine;
use super::worker::SimWorker;
use crate::baselines;
use crate::scheduler::SchedulerConfig;
use crate::server::metrics::RunReport;
use crate::workload::trace::{Trace, TraceSpec};

/// One (system, slo) cell of a results table.
#[derive(Debug, Clone)]
pub struct Cell {
    pub system: String,
    pub slo_multiple: f64,
    pub report: RunReport,
    pub utilization: f64,
}

/// Run one system over one trace at one SLO multiple.
pub fn run_one(
    system: &str,
    spec: &TraceSpec,
    trace: &Trace,
    slo_multiple: f64,
    cfg: &SchedulerConfig,
    seed: u64,
) -> Cell {
    let mut sched =
        baselines::by_name(system, cfg.clone(), seed).unwrap_or_else(|| panic!("unknown system {system}"));
    for (app, hist) in spec.seed_histograms(cfg.bins) {
        sched.seed_app_profile(app, &hist, 1000);
    }
    let mut worker = SimWorker::new(cfg.cost_model, 0.0, seed ^ 0x5151);
    let requests = trace.requests(slo_multiple);
    let res = engine::run(sched.as_mut(), &mut worker, requests);
    let report = RunReport::from_completions(&res.completions);
    let utilization = if res.end_time > 0 {
        res.busy_us as f64 / res.end_time as f64
    } else {
        0.0
    };
    Cell {
        system: system.to_string(),
        slo_multiple,
        report,
        utilization,
    }
}

/// Run the full (systems × SLOs) grid over one trace.
pub fn run_grid(
    systems: &[&str],
    spec: &TraceSpec,
    slo_multiples: &[f64],
    cfg: &SchedulerConfig,
    seed: u64,
) -> Vec<Cell> {
    let trace = spec.generate();
    let mut cells = Vec::new();
    for &slo in slo_multiples {
        for system in systems {
            cells.push(run_one(system, spec, &trace, slo, cfg, seed));
        }
    }
    cells
}

/// Render a grid as a paper-style table (rows: SLO; columns: systems).
pub fn render_table(title: &str, cells: &[Cell], systems: &[&str]) -> String {
    use std::fmt::Write;
    let mut out = String::new();
    writeln!(out, "### {title}").unwrap();
    write!(out, "{:>10} ", "SLO(xP99)").unwrap();
    for s in systems {
        write!(out, "{:>10} ", s).unwrap();
    }
    writeln!(out).unwrap();
    let mut slos: Vec<f64> = cells.iter().map(|c| c.slo_multiple).collect();
    slos.sort_by(|a, b| a.partial_cmp(b).unwrap());
    slos.dedup();
    for slo in slos {
        write!(out, "{:>10} ", format!("{slo:.1}")).unwrap();
        for s in systems {
            let cell = cells
                .iter()
                .find(|c| c.slo_multiple == slo && c.system == *s);
            match cell {
                Some(c) => write!(out, "{:>10.2} ", c.report.finish_rate()).unwrap(),
                None => write!(out, "{:>10} ", "-").unwrap(),
            }
        }
        writeln!(out).unwrap();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::batchmodel::BatchCostModel;
    use crate::workload::azure::AzureTraceConfig;
    use crate::workload::exectime::ExecTimeDist;

    fn small_spec(bimodal: bool) -> TraceSpec {
        let dists = if bimodal {
            vec![ExecTimeDist::multimodal("bi", 2, 5.0, 50.0, 1.0, None)]
        } else {
            vec![ExecTimeDist::constant("static", 10.0)]
        };
        let mut spec = TraceSpec {
            name: "unit".into(),
            dists,
            arrivals: AzureTraceConfig {
                apps: 1,
                rate_per_s: 0.0, // set by scaling
                duration_s: 20.0,
                ..Default::default()
            },
            seed: 77,
        };
        spec.scale_rate_to_load(BatchCostModel::gpu_like(), 0.6, 8);
        spec
    }

    fn cfg() -> SchedulerConfig {
        SchedulerConfig {
            cost_model: BatchCostModel::gpu_like(),
            ..Default::default()
        }
    }

    #[test]
    fn all_four_systems_run_to_completion() {
        let spec = small_spec(true);
        let cells = run_grid(
            &baselines::PAPER_SYSTEMS,
            &spec,
            &[3.0],
            &cfg(),
            1,
        );
        assert_eq!(cells.len(), 4);
        for c in &cells {
            assert!(c.report.total > 50, "{}: total={}", c.system, c.report.total);
            assert!(c.report.finish_rate() >= 0.0 && c.report.finish_rate() <= 1.0);
        }
    }

    #[test]
    fn orloj_beats_point_estimators_on_bimodal() {
        // The paper's headline directional claim at a moderate SLO.
        let spec = small_spec(true);
        let cells = run_grid(&["clockwork", "orloj"], &spec, &[3.0], &cfg(), 2);
        let get = |name: &str| {
            cells
                .iter()
                .find(|c| c.system == name)
                .unwrap()
                .report
                .finish_rate()
        };
        assert!(
            get("orloj") > get("clockwork"),
            "orloj {} vs clockwork {}",
            get("orloj"),
            get("clockwork")
        );
    }

    #[test]
    fn static_workload_everyone_reasonable() {
        let spec = small_spec(false);
        let cells = run_grid(&["clockwork", "orloj"], &spec, &[4.0], &cfg(), 3);
        for c in &cells {
            assert!(
                c.report.finish_rate() > 0.7,
                "{} should do fine on static: {}",
                c.system,
                c.report.finish_rate()
            );
        }
    }

    #[test]
    fn render_table_has_all_rows() {
        let spec = small_spec(true);
        let cells = run_grid(&["orloj"], &spec, &[1.5, 3.0], &cfg(), 4);
        let table = render_table("t", &cells, &["orloj"]);
        assert!(table.contains("1.5"));
        assert!(table.contains("3.0") || table.contains("3"));
    }
}
