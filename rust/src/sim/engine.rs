//! Discrete-event serving engine: drives a [`Scheduler`] and a [`Worker`]
//! over a request trace in virtual time.
//!
//! The engine models the single-GPU worker of the paper's setup (§3.1):
//! one batch in flight at a time, non-preemptive, open-loop arrivals (the
//! client never waits). It is also reused by the real-time serving loop
//! with a [`crate::sim::worker::Worker`] backed by PJRT — only the clock
//! differs.

use super::worker::Worker;
use crate::clock::{ms_to_us, Micros};
use crate::core::request::{Completion, Outcome, Request};
use crate::scheduler::Scheduler;

/// Result of an engine run.
#[derive(Debug)]
pub struct EngineResult {
    pub completions: Vec<Completion>,
    /// Virtual end time.
    pub end_time: Micros,
    /// Number of executed batches.
    pub batches: usize,
    /// Total worker busy time (µs) — utilization = busy / end_time.
    pub busy_us: Micros,
}

struct InFlight {
    batch: Vec<Request>,
    started_at: Micros,
    done_at: Micros,
}

/// Run the trace to completion.
pub fn run(
    sched: &mut dyn Scheduler,
    worker: &mut dyn Worker,
    mut requests: Vec<Request>,
) -> EngineResult {
    requests.sort_by_key(|r| r.release);
    let mut completions = Vec::with_capacity(requests.len());
    let mut now: Micros = 0;
    let mut next_arrival = 0usize;
    let mut inflight: Option<InFlight> = None;
    let mut batches = 0usize;
    let mut busy_us: Micros = 0;

    loop {
        // Deliver all arrivals due now.
        while next_arrival < requests.len() && requests[next_arrival].release <= now {
            let r = requests[next_arrival].clone();
            next_arrival += 1;
            sched.on_arrival(r, now);
        }
        // Complete the in-flight batch if due.
        if let Some(f) = &inflight {
            if f.done_at <= now {
                let f = inflight.take().unwrap();
                let done = f.done_at;
                let bs = f.batch.len();
                for r in &f.batch {
                    let outcome = if done <= r.deadline {
                        Outcome::Finished
                    } else {
                        Outcome::Late
                    };
                    completions.push(Completion {
                        request: r.clone(),
                        outcome,
                        at: done,
                        batch_size: bs,
                    });
                }
                let batch_ms = crate::clock::us_to_ms(done - f.started_at);
                sched.on_batch_complete(&f.batch, batch_ms, now);
            }
        }
        // Drain scheduler-side drops.
        for (r, outcome) in sched.drain_dropped() {
            completions.push(Completion {
                request: r,
                outcome,
                at: now,
                batch_size: 0,
            });
        }
        // If the worker is idle, try to dispatch (repeat while the
        // scheduler's state changes — e.g. Clockwork aborting a planned
        // batch frees it to plan another immediately).
        if inflight.is_none() {
            loop {
                match sched.next_batch(now) {
                    Some(batch) => {
                        let exec_ms = worker.execute(&batch);
                        let done_at = now + ms_to_us(exec_ms);
                        busy_us += done_at - now;
                        batches += 1;
                        inflight = Some(InFlight {
                            batch,
                            started_at: now,
                            done_at,
                        });
                        break;
                    }
                    None => {
                        let dropped = sched.drain_dropped();
                        if dropped.is_empty() {
                            break;
                        }
                        for (r, outcome) in dropped {
                            completions.push(Completion {
                                request: r,
                                outcome,
                                at: now,
                                batch_size: 0,
                            });
                        }
                    }
                }
            }
        }
        // Pick the next event.
        let mut next: Option<Micros> = None;
        let mut consider = |t: Option<Micros>| {
            if let Some(t) = t {
                next = Some(match next {
                    Some(n) => n.min(t),
                    None => t,
                });
            }
        };
        if next_arrival < requests.len() {
            consider(Some(requests[next_arrival].release));
        }
        consider(inflight.as_ref().map(|f| f.done_at));
        if inflight.is_none() && sched.pending() > 0 {
            // Poll the scheduler at its own cadence while idle with work
            // queued (milestones / forced partial batches / window ends).
            let hint = sched.wake_hint(now).filter(|&h| h > now);
            consider(hint.or(Some(now + 1_000)));
        }
        match next {
            Some(t) if t > now => now = t,
            Some(_) => now += 1, // same-time event loop guard
            None => {
                // No arrivals, nothing in flight, nothing pending → done.
                if next_arrival >= requests.len() && inflight.is_none() && sched.pending() == 0 {
                    break;
                }
                now += 1_000;
            }
        }
        // Termination safeguard: everything delivered and queues empty.
        if next_arrival >= requests.len() && inflight.is_none() && sched.pending() == 0 {
            // Final drain.
            for (r, outcome) in sched.drain_dropped() {
                completions.push(Completion {
                    request: r,
                    outcome,
                    at: now,
                    batch_size: 0,
                });
            }
            break;
        }
    }
    EngineResult {
        completions,
        end_time: now,
        batches,
        busy_us,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::edf::EdfScheduler;
    use crate::core::batchmodel::BatchCostModel;
    use crate::core::request::AppId;
    use crate::scheduler::SchedulerConfig;
    use crate::sim::worker::SimWorker;

    fn requests(n: u64, gap_ms: f64, slo_ms: f64, exec_ms: f64) -> Vec<Request> {
        (0..n)
            .map(|i| {
                Request::new(
                    i,
                    AppId(0),
                    ms_to_us(i as f64 * gap_ms),
                    ms_to_us(slo_ms),
                    exec_ms,
                )
            })
            .collect()
    }

    fn cfg() -> SchedulerConfig {
        SchedulerConfig {
            cost_model: BatchCostModel::new(0.0, 1.0),
            ..Default::default()
        }
    }

    #[test]
    fn all_requests_accounted_for() {
        let mut s = EdfScheduler::new(cfg(), 0);
        s.seed_exec_mean(10.0);
        let mut w = SimWorker::new(BatchCostModel::new(0.0, 1.0), 0.0, 0);
        let reqs = requests(50, 5.0, 500.0, 10.0);
        let res = run(&mut s, &mut w, reqs);
        assert_eq!(res.completions.len(), 50);
        assert!(res.batches > 0);
        assert!(res.busy_us > 0);
    }

    #[test]
    fn relaxed_slo_finishes_everything() {
        let mut s = EdfScheduler::new(cfg(), 0);
        s.seed_exec_mean(10.0);
        let mut w = SimWorker::new(BatchCostModel::new(0.0, 1.0), 0.0, 0);
        let reqs = requests(40, 20.0, 5_000.0, 10.0);
        let res = run(&mut s, &mut w, reqs);
        let finished = res
            .completions
            .iter()
            .filter(|c| c.outcome == Outcome::Finished)
            .count();
        assert_eq!(finished, 40, "light load + huge SLO → all finish");
    }

    #[test]
    fn overload_drops_requests() {
        let mut s = EdfScheduler::new(cfg(), 0);
        s.seed_exec_mean(10.0);
        let mut w = SimWorker::new(BatchCostModel::new(0.0, 1.0), 0.0, 0);
        // 1 req/ms with 10 ms exec and tight SLO: hopeless overload.
        let reqs = requests(200, 1.0, 30.0, 10.0);
        let res = run(&mut s, &mut w, reqs);
        assert_eq!(res.completions.len(), 200);
        let finished = res
            .completions
            .iter()
            .filter(|c| c.outcome == Outcome::Finished)
            .count();
        assert!(finished < 150, "overload must shed load: finished={finished}");
    }

    #[test]
    fn completions_have_monotone_nonneg_latency() {
        let mut s = EdfScheduler::new(cfg(), 0);
        s.seed_exec_mean(5.0);
        let mut w = SimWorker::new(BatchCostModel::new(1.0, 0.5), 0.0, 0);
        let reqs = requests(30, 3.0, 300.0, 5.0);
        let res = run(&mut s, &mut w, reqs);
        for c in &res.completions {
            if c.outcome == Outcome::Finished || c.outcome == Outcome::Late {
                assert!(c.at >= c.request.release);
                assert!(c.batch_size >= 1);
            }
        }
    }
}
