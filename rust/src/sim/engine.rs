//! Single-worker discrete-event engine — now a thin compatibility shim
//! over the unified serving core (`serve::ServingLoop` + the virtual-time
//! pump in `serve::replay`; DESIGN.md §3).
//!
//! [`run`] keeps the historical signature: it drives one scheduler and one
//! worker over a request trace in virtual time, modelling the paper's
//! single-GPU setup (§3.1) — one batch in flight, non-preemptive,
//! open-loop arrivals. Multi-replica runs go through
//! [`crate::serve::replay::run_cluster`] directly (or `sim::runner`).

use super::worker::Worker;
use crate::clock::{Micros, VirtualClock};
use crate::core::request::{Completion, Request};
use crate::scheduler::Scheduler;
use crate::serve::{
    replay, router, AdmissionStats, Cluster, PlacementStats, ServingLoop, WorkerStats,
};

/// Result of an engine run.
#[derive(Debug)]
pub struct EngineResult {
    pub completions: Vec<Completion>,
    /// Virtual end time.
    pub end_time: Micros,
    /// Number of executed batches (summed across workers).
    pub batches: usize,
    /// Total worker busy time (µs) — utilization = busy / end_time
    /// (divide by the worker count for multi-replica runs).
    pub busy_us: Micros,
    /// Per-replica batch counts and busy time.
    pub per_worker: Vec<WorkerStats>,
    /// Elastic placement counters (all zero on static runs).
    pub placement: PlacementStats,
    /// Admission-control tallies (disabled + all-zero when no controller
    /// was installed).
    pub admission: AdmissionStats,
    /// Lifecycle recorder, present when the run was built with
    /// [`ServingLoop::with_telemetry`]; `None` (the default) costs one
    /// branch per hook on the hot path.
    pub telemetry: Option<Box<crate::telemetry::Recorder>>,
    /// Virtual-clock advances the pump performed (summed across event
    /// lanes on sharded runs) — the discrete-event step count. A pump
    /// that crawls instead of jumping to the next event shows up here.
    pub steps: usize,
}

/// Run the trace to completion on a single worker.
pub fn run(
    sched: &mut dyn Scheduler,
    worker: &mut dyn Worker,
    requests: Vec<Request>,
) -> EngineResult {
    let core = ServingLoop::new(
        VirtualClock::new(),
        Cluster::new(vec![sched]),
        router::by_name("round_robin").expect("registry has round_robin"),
    );
    replay::run_cluster(core, vec![worker], requests)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::edf::EdfScheduler;
    use crate::clock::ms_to_us;
    use crate::core::batchmodel::BatchCostModel;
    use crate::core::request::{AppId, Outcome};
    use crate::scheduler::SchedulerConfig;
    use crate::sim::worker::SimWorker;

    fn requests(n: u64, gap_ms: f64, slo_ms: f64, exec_ms: f64) -> Vec<Request> {
        (0..n)
            .map(|i| {
                Request::new(
                    i,
                    AppId(0),
                    ms_to_us(i as f64 * gap_ms),
                    ms_to_us(slo_ms),
                    exec_ms,
                )
            })
            .collect()
    }

    fn cfg() -> SchedulerConfig {
        SchedulerConfig {
            cost_model: BatchCostModel::new(0.0, 1.0),
            ..Default::default()
        }
    }

    #[test]
    fn all_requests_accounted_for() {
        let mut s = EdfScheduler::new(cfg(), 0);
        s.seed_exec_mean(10.0);
        let mut w = SimWorker::new(BatchCostModel::new(0.0, 1.0), 0.0, 0);
        let reqs = requests(50, 5.0, 500.0, 10.0);
        let res = run(&mut s, &mut w, reqs);
        assert_eq!(res.completions.len(), 50);
        assert!(res.batches > 0);
        assert!(res.busy_us > 0);
    }

    #[test]
    fn relaxed_slo_finishes_everything() {
        let mut s = EdfScheduler::new(cfg(), 0);
        s.seed_exec_mean(10.0);
        let mut w = SimWorker::new(BatchCostModel::new(0.0, 1.0), 0.0, 0);
        let reqs = requests(40, 20.0, 5_000.0, 10.0);
        let res = run(&mut s, &mut w, reqs);
        let finished = res
            .completions
            .iter()
            .filter(|c| c.outcome == Outcome::Finished)
            .count();
        assert_eq!(finished, 40, "light load + huge SLO → all finish");
    }

    #[test]
    fn overload_drops_requests() {
        let mut s = EdfScheduler::new(cfg(), 0);
        s.seed_exec_mean(10.0);
        let mut w = SimWorker::new(BatchCostModel::new(0.0, 1.0), 0.0, 0);
        // 1 req/ms with 10 ms exec and tight SLO: hopeless overload.
        let reqs = requests(200, 1.0, 30.0, 10.0);
        let res = run(&mut s, &mut w, reqs);
        assert_eq!(res.completions.len(), 200);
        let finished = res
            .completions
            .iter()
            .filter(|c| c.outcome == Outcome::Finished)
            .count();
        assert!(finished < 150, "overload must shed load: finished={finished}");
    }

    #[test]
    fn completions_have_monotone_nonneg_latency() {
        let mut s = EdfScheduler::new(cfg(), 0);
        s.seed_exec_mean(5.0);
        let mut w = SimWorker::new(BatchCostModel::new(1.0, 0.5), 0.0, 0);
        let reqs = requests(30, 3.0, 300.0, 5.0);
        let res = run(&mut s, &mut w, reqs);
        for c in &res.completions {
            if c.outcome == Outcome::Finished || c.outcome == Outcome::Late {
                assert!(c.at >= c.request.release);
                assert!(c.batch_size >= 1);
            }
        }
    }

    #[test]
    fn shim_reports_single_worker_stats() {
        let mut s = EdfScheduler::new(cfg(), 0);
        s.seed_exec_mean(10.0);
        let mut w = SimWorker::new(BatchCostModel::new(0.0, 1.0), 0.0, 0);
        let res = run(&mut s, &mut w, requests(25, 8.0, 800.0, 10.0));
        assert_eq!(res.per_worker.len(), 1);
        assert_eq!(res.per_worker[0].batches, res.batches);
        assert_eq!(res.per_worker[0].busy_us, res.busy_us);
    }
}
