//! Workers: the execution end of the serving stack.
//!
//! [`Worker`] abstracts "run this batch, tell me how long it took" so the
//! identical scheduler + engine code drives both the virtual-time simulator
//! (evaluation sweeps) and the PJRT runtime (real serving path, see
//! `runtime::executor`).

use crate::core::batchmodel::BatchCostModel;
use crate::core::request::{ModelId, Request};
use crate::util::rng::Rng;

/// A batch executor.
pub trait Worker: Send {
    /// Execute the batch; returns the measured batch latency in ms.
    fn execute(&mut self, batch: &[Request]) -> f64;

    /// Load `model` onto this worker (elastic placement cold start);
    /// returns the measured load time in ms. The default accepts the
    /// caller's predicted cost — virtual workers have nothing to actually
    /// fetch, so the cold-start curve *is* the measurement. Real workers
    /// (PJRT) override this to load the runtime and time it.
    fn load_model(&mut self, _model: ModelId, cost_hint_ms: f64) -> f64 {
        cost_hint_ms
    }

    /// Release `model`'s executor-side state after an eviction (elastic
    /// placement). Default: nothing to release.
    fn unload_model(&mut self, _model: ModelId) {}
}

/// Mutable borrows and boxes are workers too, so the unified serve pumps
/// can execute through a worker they do not own (e.g. the single-worker
/// `sim::engine::run` compatibility shim).
impl<W: Worker + ?Sized> Worker for &mut W {
    fn execute(&mut self, batch: &[Request]) -> f64 {
        (**self).execute(batch)
    }
    fn load_model(&mut self, model: ModelId, cost_hint_ms: f64) -> f64 {
        (**self).load_model(model, cost_hint_ms)
    }
    fn unload_model(&mut self, model: ModelId) {
        (**self).unload_model(model)
    }
}

impl<W: Worker + ?Sized> Worker for Box<W> {
    fn execute(&mut self, batch: &[Request]) -> f64 {
        (**self).execute(batch)
    }
    fn load_model(&mut self, model: ModelId, cost_hint_ms: f64) -> f64 {
        (**self).load_model(model, cost_hint_ms)
    }
    fn unload_model(&mut self, model: ModelId) {
        (**self).unload_model(model)
    }
}

/// Virtual-time worker implementing the paper's batch cost model (Eq. 3):
/// `l_B = c0 + c1·k·max_r l_r`, with optional multiplicative jitter
/// (hardware noise; Clockwork's premise is that this term is tiny).
/// Multi-model hosts can install per-model cost curves; batches are
/// model-pure, so the batch's model picks the curve.
pub struct SimWorker {
    pub model: BatchCostModel,
    /// Per-model cost overrides (empty = `model` for every batch).
    model_costs: Vec<(u32, BatchCostModel)>,
    /// Lognormal σ of multiplicative noise (0 = deterministic).
    pub noise_sigma: f64,
    rng: Rng,
}

impl SimWorker {
    pub fn new(model: BatchCostModel, noise_sigma: f64, seed: u64) -> Self {
        SimWorker {
            model,
            model_costs: Vec::new(),
            noise_sigma,
            rng: Rng::new(seed),
        }
    }

    /// Install per-model batch cost models (heterogeneous co-located
    /// models; unknown models fall back to the default).
    pub fn with_model_costs(mut self, costs: Vec<(u32, BatchCostModel)>) -> Self {
        self.model_costs = costs;
        self
    }

    fn cost_for(&self, model: u32) -> BatchCostModel {
        self.model_costs
            .iter()
            .find(|(m, _)| *m == model)
            .map_or(self.model, |(_, c)| *c)
    }
}

impl Worker for SimWorker {
    fn execute(&mut self, batch: &[Request]) -> f64 {
        assert!(!batch.is_empty());
        debug_assert!(
            batch.iter().all(|r| r.model == batch[0].model),
            "SimWorker executed a mixed-model batch"
        );
        let max_exec = batch
            .iter()
            .map(|r| r.exec_ms)
            .fold(f64::NEG_INFINITY, f64::max);
        let base = self
            .cost_for(batch[0].model.0)
            .latency(batch.len(), max_exec);
        if self.noise_sigma > 0.0 {
            base * self.rng.lognormal(0.0, self.noise_sigma)
        } else {
            base
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::request::AppId;

    fn req(exec_ms: f64) -> Request {
        Request::new(0, AppId(0), 0, 1_000_000, exec_ms)
    }

    #[test]
    fn cost_model_applied_to_max() {
        let mut w = SimWorker::new(BatchCostModel::new(1.0, 0.5), 0.0, 0);
        let batch = vec![req(2.0), req(10.0), req(4.0)];
        // 1 + 0.5·3·10 = 16
        assert!((w.execute(&batch) - 16.0).abs() < 1e-12);
    }

    #[test]
    fn per_model_costs_pick_the_batch_model() {
        use crate::core::request::ModelId;
        let mut w = SimWorker::new(BatchCostModel::new(0.0, 1.0), 0.0, 0)
            .with_model_costs(vec![(1, BatchCostModel::new(5.0, 2.0))]);
        let fast = vec![req(10.0)];
        // model 0 (default cost): 1·1·10 = 10
        assert!((w.execute(&fast) - 10.0).abs() < 1e-12);
        // model 1 (override): 5 + 2·1·10 = 25
        let slow = vec![req(10.0).with_model(ModelId(1))];
        assert!((w.execute(&slow) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn noise_is_multiplicative_and_seeded() {
        let mut a = SimWorker::new(BatchCostModel::new(0.0, 1.0), 0.2, 7);
        let mut b = SimWorker::new(BatchCostModel::new(0.0, 1.0), 0.2, 7);
        let batch = vec![req(10.0)];
        let xa: Vec<f64> = (0..10).map(|_| a.execute(&batch)).collect();
        let xb: Vec<f64> = (0..10).map(|_| b.execute(&batch)).collect();
        assert_eq!(xa, xb, "seeded determinism");
        assert!(xa.iter().any(|&x| (x - 10.0).abs() > 1e-6), "noise present");
    }
}
