//! `orloj` — CLI entry point for the Orloj serving system reproduction.
//!
//! Subcommands:
//!   experiment <id|all>   regenerate a paper table/figure (see DESIGN.md §5)
//!   serve                 end-to-end PJRT serving demo on real artifacts
//!   serve --listen        wire-facing server: sharded TCP ingress + sim workers (§12)
//!   loadgen               open-loop wire load generator against a --listen server
//!   trace                 generate + save a replayable workload trace
//!   list                  list experiment ids

use orloj::experiments::{self, ExpOptions};
use orloj::util::cli::Args;
use orloj::util::logging;

fn usage() -> ! {
    eprintln!(
        "usage: orloj <command> [options]\n\
         \n\
         commands:\n\
           experiment <id|all>   run a paper experiment (ids: {})\n\
             --duration <s>        virtual seconds per run   (default 40)\n\
             --util <frac>         offered load / capacity   (default 0.7)\n\
             --slo <list>          SLO multiples of P99      (default 1.5,2,3,4,5)\n\
             --seed <n>            experiment seed           (default 42)\n\
             --runs <n>            repetitions to average    (default 1)\n\
             --workers <n>         scheduling replicas       (default 1)\n\
             --router <name>       {}  (default round_robin)\n\
             --models <n>          co-served models for the multimodel/elastic grids (default 2/3 there)\n\
             --placement <spec>    {}|'0,1;1;0'  worker→models (default all)\n\
             --elastic             run cells under the elastic placement controller\n\
             --capacity <n>        per-worker model budget for elastic runs (default 2)\n\
             --drift <s>           hot-model rotation period for the elastic experiment (default 8)\n\
             --telemetry[=dir]     record lifecycle telemetry; writes TELEMETRY_<case>.json and\n\
                                   a Perfetto-loadable TELEMETRY_<case>.trace.json (default dir: results)\n\
             --admission[=p]       predictive admission control at admit threshold p (bare: 0.5);\n\
                                   the `overload` experiment compares on/off regardless\n\
             --shards <n>          parallel event lanes for the virtual-time pump (default: 1;\n\
                                   the `cluster` experiment auto-picks the machine's parallelism)\n\
             --quick               fast settings for smoke runs\n\
           serve                 PJRT serving demo (needs `make artifacts`)\n\
             --artifacts <dir>     artifact directory        (default artifacts)\n\
             --requests <n>        requests to replay        (default 200)\n\
             --system <name>       orloj|clipper|nexus|clockwork|edf\n\
             --workers <n>         replicas (one PJRT worker each, default 1)\n\
             --router <name>       arrival router            (default round_robin)\n\
             --models <n>          co-served model copies (default 1; each loads its own runtime)\n\
             --placement <spec>    worker→models spec        (default all)\n\
             --elastic             elastic placement (lazy PJRT runtime loads on LoadModel)\n\
             --capacity <n>        per-worker model budget   (default 2)\n\
             --slo-ms <ms>         per-request SLO           (default 12x deep solo latency)\n\
             --gap-us <us>         inter-arrival gap         (default 500)\n\
             --telemetry[=dir]     record lifecycle telemetry (TELEMETRY_serve.json + .trace.json)\n\
             --admission[=p]       gate arrivals through predictive admission control\n\
             --listen <addr>       serve the binary wire protocol instead (DESIGN.md §12);\n\
                                   sim workers, no PJRT needed. Extra options:\n\
               --shards <n>          ingress shard threads     (default 2)\n\
               --sched-shards <n>    scheduling shards (parallel lanes over the LoadBoard,\n\
                                     DESIGN.md §13; default 1 = sequential pump)\n\
               --duration <s>        drain + exit after s seconds (default: until SIGINT)\n\
               --apps <n>            app profiles to seed      (default 2)\n\
               --exec-ms <ms>        per-request sim cost      (default 5)\n\
           loadgen               open-loop load generator for a --listen server\n\
             --addr <host:port>    target server             (default 127.0.0.1:7433)\n\
             --conns <n>           client connections        (default 64)\n\
             --rate <r/s>          offered load              (default 20000)\n\
             --duration <s>        send window               (default 3)\n\
             --apps <n> --models <n> --payload <bytes> --exec-ms <ms>\n\
             --slo <mult>          SLO multiple of p99 exec  (default 10)\n\
             --threads <n>         client threads (0 = auto)\n\
           trace                 generate a trace JSON\n\
             --out <path>          output path (default trace.json)\n\
             --apps <n> --rate <r/s> --duration <s> --modes <k>\n\
             --models <n>          multi-model trace: n models with skewed shares (default 1)\n\
             --drift <s>           rotate the hot model every <s> seconds (multi-model only)\n\
             --telemetry[=dir]     also replay the trace through orloj and write telemetry files\n\
             --admission[=p]       gate the replay through predictive admission control\n\
           list                  list experiment ids",
        experiments::ALL.join(", "),
        orloj::serve::router::ROUTERS.join("|"),
        orloj::serve::placement::PLACEMENTS.join("|"),
    );
    std::process::exit(2);
}

/// `--telemetry[=dir]`: bare flag → default dir (empty string, resolved
/// to `results/` downstream), explicit value → that directory.
fn telemetry_opt(args: &Args) -> Option<String> {
    if args.flag("telemetry") {
        Some(String::new())
    } else {
        args.get("telemetry").map(str::to_string)
    }
}

/// `--admission[=p]`: bare flag → the default 0.5 admit threshold,
/// explicit value → that P(finish ≤ deadline) threshold (DESIGN.md §10).
fn admission_opt(args: &Args) -> Option<f64> {
    if args.flag("admission") {
        Some(0.5)
    } else {
        args.get("admission").map(|s| {
            s.parse::<f64>()
                .unwrap_or_else(|_| panic!("--admission={s}: not a number"))
        })
    }
}

fn exp_options(args: &Args) -> ExpOptions {
    let mut opts = if args.flag("quick") {
        ExpOptions::quick()
    } else {
        ExpOptions::default()
    };
    opts.duration_s = args.get_f64("duration", opts.duration_s);
    opts.util = args.get_f64("util", opts.util);
    opts.seed = args.get_u64("seed", opts.seed);
    opts.runs = args.get_usize("runs", opts.runs);
    opts.slos = args.get_list_f64("slo", &opts.slos);
    opts.workers = args.get_usize("workers", opts.workers).max(1);
    if let Some(router) = args.get("router") {
        opts.router = router.to_string();
    }
    opts.models = args.get_usize("models", opts.models).max(1);
    if let Some(placement) = args.get("placement") {
        opts.placement = placement.to_string();
    }
    opts.elastic = args.flag("elastic");
    opts.capacity = args.get_usize("capacity", opts.capacity).max(1);
    opts.drift_period_s = args.get_f64("drift", opts.drift_period_s);
    opts.telemetry = telemetry_opt(args);
    opts.admission = admission_opt(args);
    opts.shards = args.get_usize("shards", opts.shards);
    opts
}

fn cmd_experiment(args: &Args) {
    let opts = exp_options(args);
    let Some(id) = args.positional.first().map(|s| s.as_str()) else {
        usage()
    };
    let ids: Vec<&str> = if id == "all" {
        experiments::ALL.to_vec()
    } else {
        vec![id]
    };
    for id in ids {
        match experiments::run(id, &opts) {
            Some(rows) => experiments::save_results(id, rows),
            None => {
                eprintln!("unknown experiment '{id}'");
                usage();
            }
        }
        println!();
    }
}

fn cmd_trace(args: &Args) {
    use orloj::workload::azure::AzureTraceConfig;
    use orloj::workload::exectime::ExecTimeDist;
    use orloj::workload::trace::{ModelTraffic, TraceSpec};
    let apps = args.get_usize("apps", 2);
    let modes = args.get_usize("modes", 2);
    let n_models = args.get_usize("models", 1).max(1);
    // Multi-model traces get a skewed mix: model 0 takes half the
    // traffic, the rest split the remainder evenly.
    let models: Vec<ModelTraffic> = if n_models > 1 {
        (0..n_models)
            .map(|m| {
                let share = if m == 0 {
                    0.5
                } else {
                    0.5 / (n_models - 1) as f64
                };
                let dists = (0..apps)
                    .map(|i| {
                        ExecTimeDist::multimodal(
                            &format!("m{m}-app{i}"),
                            modes,
                            10.0 * (m + 1) as f64,
                            100.0 * (m + 1) as f64,
                            1.0,
                            None,
                        )
                    })
                    .collect();
                ModelTraffic::new(m as u32, share, dists)
            })
            .collect()
    } else {
        Vec::new()
    };
    let spec = TraceSpec {
        name: "cli".into(),
        dists: (0..apps)
            .map(|i| {
                ExecTimeDist::multimodal(&format!("app{i}"), modes, 10.0, 100.0, 1.0, None)
            })
            .collect(),
        arrivals: AzureTraceConfig {
            apps,
            rate_per_s: args.get_f64("rate", 100.0),
            duration_s: args.get_f64("duration", 30.0),
            ..Default::default()
        },
        seed: args.get_u64("seed", 1),
        models,
    };
    // Optional drifting mix: rotate the hot model every --drift seconds.
    let drift_s = args.get_f64("drift", 0.0);
    let spec = if drift_s > 0.0 && n_models > 1 {
        spec.drift_rotating(drift_s, 0.8)
    } else {
        spec
    };
    let trace = spec.generate();
    let out = args.get_or("out", "trace.json").to_string();
    trace.save(std::path::Path::new(&out)).expect("write trace");
    println!(
        "wrote {} events across {} model(s) (p99={:.1} ms) to {out}",
        trace.events.len(),
        trace.model_ids().len(),
        trace.p99_ms
    );
    // --telemetry: replay the freshly generated trace through orloj with
    // the recorder on and write the telemetry exports next to the bench
    // results (the quickest way to get a Perfetto-loadable trace).
    if let Some(dir) = telemetry_opt(args) {
        use orloj::core::batchmodel::BatchCostModel;
        use orloj::scheduler::SchedulerConfig;
        use orloj::sim::runner::{self, ClusterSpec};
        let cfg = SchedulerConfig {
            cost_model: BatchCostModel::gpu_like(),
            ..Default::default()
        };
        let slo = args.get_f64("slo", 3.0);
        let mut cluster = ClusterSpec::default().with_telemetry();
        if let Some(t) = admission_opt(args) {
            cluster = cluster.with_admission(t);
        }
        let cell = runner::run_one("orloj", &spec, &trace, slo, &cfg, spec.seed, &cluster);
        if cell.admission.enabled {
            println!(
                "admission: {} admitted, {} downgraded, {} early-rejected, {} best-effort served",
                cell.admission.admitted,
                cell.admission.downgraded,
                cell.admission.early_rejected,
                cell.admission.best_effort_served
            );
        }
        let cells = [cell];
        print!(
            "{}",
            runner::render_calibration("estimator calibration (predicted vs realized, ms)", &cells)
        );
        orloj::experiments::export_telemetry(&dir, "trace", &cells);
    }
}

/// `serve --listen <addr>` — the wire-facing serving loop (DESIGN.md
/// §12): sharded TCP ingress in front of the serving core, sim workers
/// standing in for accelerators (no PJRT needed). Runs until SIGINT or
/// `--duration` elapses, then drains everything in flight, flushes the
/// reply rings, and prints the final report plus the ingress counters
/// and a conservation verdict (exit 1 on violation).
fn cmd_serve_listen(args: &Args) {
    use orloj::core::batchmodel::BatchCostModel;
    use orloj::scheduler::{Scheduler, SchedulerConfig};
    use orloj::serve::ingress::{ctrlc, IngressConfig};
    use orloj::serve::{router, Placement};
    use orloj::server::metrics::RunReport;
    use orloj::server::Server;
    use orloj::sim::worker::SimWorker;
    use orloj::workload::azure::AzureTraceConfig;
    use orloj::workload::exectime::ExecTimeDist;
    use orloj::workload::trace::{ModelTraffic, TraceSpec};

    let addr = args.get("listen").expect("--listen takes <host:port>").to_string();
    let system = args.get_or("system", "orloj").to_string();
    let n_workers = args.get_usize("workers", 2).max(1);
    let n_models = args.get_usize("models", 1).max(1);
    let apps = args.get_usize("apps", 2).max(1);
    let router_name = args.get_or("router", "round_robin").to_string();
    let n_shards = args.get_usize("shards", 2).max(1);
    let sched_shards = args.get_usize("sched-shards", 1).max(1);
    let duration_s = args.get_f64("duration", 0.0);
    let exec_ms = args.get_f64("exec-ms", 5.0);
    let seed = args.get_u64("seed", 42);
    let placement_spec = args.get_or("placement", "all").to_string();

    let cfg = SchedulerConfig {
        cost_model: BatchCostModel::calibrated(exec_ms),
        ..Default::default()
    };
    // Seed per-(model, app) exec-time profiles so the predictive
    // schedulers have a prior before the first wire completions arrive —
    // the same spec shape `loadgen` synthesizes its traffic from.
    let dists: Vec<ExecTimeDist> = (0..apps)
        .map(|_| ExecTimeDist::constant("wire", exec_ms))
        .collect();
    let models = if n_models <= 1 {
        Vec::new()
    } else {
        (0..n_models as u32)
            .map(|m| ModelTraffic::new(m, 1.0 / n_models as f64, dists.clone()))
            .collect()
    };
    let seed_spec = TraceSpec {
        name: "listen".into(),
        dists,
        arrivals: AzureTraceConfig {
            apps,
            rate_per_s: 0.0,
            duration_s: 1.0,
            ..Default::default()
        },
        seed,
        models,
    };
    let hists = seed_spec.seed_histograms(cfg.bins);
    let placement = match Placement::parse_checked(&placement_spec, n_workers, n_models) {
        Ok(p) => p,
        Err(why) => panic!("invalid placement: {why}"),
    };
    let replicas: Vec<(Box<dyn Scheduler>, SimWorker)> = (0..n_workers)
        .map(|w| {
            let mut sched =
                orloj::baselines::by_name(&system, cfg.clone(), seed ^ ((w as u64) << 24))
                    .unwrap_or_else(|| panic!("unknown system '{system}'"));
            for (model, app, hist) in &hists {
                sched.seed_app_profile(*model, *app, hist, 1000);
            }
            (sched, SimWorker::new(cfg.cost_model, 0.0, seed ^ ((w as u64) << 8)))
        })
        .collect();
    let router = router::by_name(&router_name).expect("known router");
    let server = Server::cluster(replicas, router)
        .with_placement(placement)
        .with_shards(sched_shards);
    let icfg = IngressConfig {
        shards: n_shards,
        ..Default::default()
    };
    let bound = server.listen(&addr, icfg).expect("bind listen address");
    let ctl = bound.controller();
    println!(
        "listening on {} ({n_shards} shards x {sched_shards} sched shards, {n_workers} workers, \
         system={system})",
        bound.local_addr()
    );

    // Shutdown: SIGINT latch (the handler only sets a flag; this watcher
    // does the drain) or the --duration deadline, whichever fires first.
    ctrlc::install();
    let deadline = (duration_s > 0.0)
        .then(|| std::time::Instant::now() + std::time::Duration::from_secs_f64(duration_s));
    let watcher = std::thread::spawn(move || loop {
        if ctrlc::triggered() {
            eprintln!("SIGINT: draining in-flight requests");
            ctl.begin_drain();
            return;
        }
        if deadline.is_some_and(|d| std::time::Instant::now() >= d) {
            eprintln!("duration elapsed: draining in-flight requests");
            ctl.begin_drain();
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(25));
    });
    let (res, counts) = bound.run();
    watcher.join().ok();

    let report = RunReport::from_completions(&res.completions)
        .with_worker_stats(&res.per_worker, res.end_time);
    println!("[{system} x{n_workers} router={router_name} wire] {report}");
    println!(
        "  ingress: {} conns, {} frames in, {} replies out ({} dead), {} wire drops, \
         {} proto errors, {:.1} MiB in / {:.1} MiB out",
        counts.accepted_conns,
        counts.frames,
        counts.replies_written,
        counts.replies_dead,
        counts.wire_drops,
        counts.proto_errors,
        counts.bytes_in as f64 / (1024.0 * 1024.0),
        counts.bytes_out as f64 / (1024.0 * 1024.0),
    );
    // Sharded runs: per-shard ledgers and conservation verdicts first
    // (they localize a violation to the shard that lost a request).
    let mut shard_violation = false;
    for ss in &res.shards {
        let verdict = if ss.conserved() { "OK" } else { "VIOLATION" };
        shard_violation |= !ss.conserved();
        println!(
            "  shard {}: workers {}..{}, {} popped + {} handoff in = {} completions \
             + {} handoff out [{verdict}], occupancy {:.1}%",
            ss.shard,
            ss.lo,
            ss.lo + ss.workers,
            ss.popped,
            ss.handoff_in,
            ss.completions,
            ss.handoff_out,
            ss.occupancy() * 100.0,
        );
    }
    let completions = res.completions.len() as u64;
    let total_ok = counts.frames == completions + counts.wire_drops;
    if total_ok && !shard_violation {
        println!(
            "ingress conservation: OK ({} frames = {completions} completions + {} wire drops)",
            counts.frames, counts.wire_drops
        );
    } else if !total_ok {
        println!(
            "ingress conservation: VIOLATION ({} frames != {completions} completions + {} wire drops)",
            counts.frames, counts.wire_drops
        );
        std::process::exit(1);
    } else {
        println!("ingress conservation: VIOLATION (per-shard ledger imbalance, see shard lines)");
        std::process::exit(1);
    }
}

/// `loadgen` — open-loop wire load generator against a `serve --listen`
/// server; prints throughput, outcome mix, wire→wire percentiles, and a
/// conservation verdict (exit 1 if any request went unanswered).
fn cmd_loadgen(args: &Args) {
    use orloj::workload::loadgen::{self, LoadgenConfig};
    let cfg = LoadgenConfig {
        addr: args.get_or("addr", "127.0.0.1:7433").to_string(),
        conns: args.get_usize("conns", 64).max(1),
        rate_per_s: args.get_f64("rate", 20_000.0),
        duration_s: args.get_f64("duration", 3.0),
        apps: args.get_usize("apps", 2).max(1),
        models: args.get_usize("models", 1).max(1),
        slo_multiple: args.get_f64("slo", 10.0),
        exec_ms: args.get_f64("exec-ms", 5.0),
        payload: args.get_usize("payload", 0),
        seed: args.get_u64("seed", 42),
        workers: args.get_usize("threads", 0),
        drain_timeout_s: args.get_f64("drain-timeout", 5.0),
    };
    let rep = loadgen::run(&cfg).unwrap_or_else(|e| panic!("loadgen: {e}"));
    println!(
        "loadgen: {} sent / {} replies in {:.2}s ({:.0} sent/s, {:.0} replies/s)",
        rep.sent, rep.replies, rep.wall_s, rep.sent_rps, rep.reply_rps
    );
    println!(
        "  outcomes: {} finished, {} late, {} shed, {} wire-dropped; \
         wire p50={:.3} ms p99={:.3} ms",
        rep.finished, rep.late, rep.shed, rep.wire_dropped, rep.wire_p50_ms, rep.wire_p99_ms
    );
    if rep.conservation_violations > 0 {
        println!(
            "  conservation: {} requests got no reply",
            rep.conservation_violations
        );
        std::process::exit(1);
    }
    println!("  conservation: OK (every request answered)");
}

/// The PJRT demo needs the vendored runtime; without the `pjrt` feature
/// the command explains itself instead of failing to link.
#[cfg(not(feature = "pjrt"))]
fn cmd_serve(_args: &Args) {
    eprintln!(
        "the `serve` command needs the PJRT runtime — rebuild with \
         `cargo run --features pjrt -- serve ...` (and `make artifacts`)"
    );
    std::process::exit(2);
}

#[cfg(feature = "pjrt")]
fn cmd_serve(args: &Args) {
    use orloj::clock::ms_to_us;
    use orloj::core::batchmodel::BatchCostModel;
    use orloj::core::request::{AppId, ModelId, Request};
    use orloj::runtime::executor::{pjrt_placed_replicas, MultiModelPjrtWorker, PjrtWorker};
    use orloj::runtime::ModelRuntime;
    use orloj::scheduler::SchedulerConfig;
    use orloj::serve::Placement;
    use orloj::server::metrics::RunReport;
    use orloj::server::Server;
    use orloj::util::rng::Rng;
    use std::sync::Arc;

    let dir = args.get_or("artifacts", "artifacts").to_string();
    let n = args.get_usize("requests", 200);
    let system = args.get_or("system", "orloj").to_string();
    let n_workers = args.get_usize("workers", 1).max(1);
    let n_models = args.get_usize("models", 1).max(1);
    let router_name = args.get_or("router", "round_robin").to_string();
    let placement_spec = args.get_or("placement", "all").to_string();
    let elastic = args.flag("elastic");
    let capacity = args.get_usize("capacity", 2).max(1);
    let placement = match Placement::parse_checked(&placement_spec, n_workers, n_models) {
        Ok(p) => p,
        Err(why) => panic!("invalid placement: {why}"),
    };
    let rt = Arc::new(ModelRuntime::load(std::path::Path::new(&dir)).expect("load artifacts"));
    let mut calib_worker = PjrtWorker::new(rt.clone());
    let calib = calib_worker.calibrate(10);
    println!("per-depth calibration (ms): {calib:?}");
    let mean_ms = calib.iter().map(|(_, m)| m).sum::<f64>() / calib.len() as f64;
    let cfg = SchedulerConfig {
        cost_model: BatchCostModel::new(0.1, 0.8),
        batch_sizes: rt.manifest.batch_sizes.clone(),
        ..Default::default()
    };
    let max_depth = rt.manifest.model.max_depth;
    // The calibration worker's handle must go before serving starts: the
    // PJRT client is thread-compatible, not thread-safe, and its runtime
    // is reused as the first hosted slot below.
    drop(calib_worker);
    // One scheduler replica per --workers (the paper's per-GPU scheduler,
    // scaled out), each hosting one ModelRuntime per *hosted model*: each
    // concurrent worker thread needs its own client (see runtime/mod.rs),
    // and each co-served model its own compiled executables — exactly the
    // per-GPU-device, per-model-memory semantics. The calibration runtime
    // fills the first slot instead of reloading from disk.
    let replicas = pjrt_placed_replicas(
        &system,
        &cfg,
        7,
        &calib,
        std::path::Path::new(&dir),
        &placement,
        Some(rt),
        elastic,
    )
    .expect("known system");
    let router = orloj::serve::router::by_name(&router_name).expect("known router");
    let (submitter, rx) =
        Server::<Box<dyn orloj::scheduler::Scheduler>, MultiModelPjrtWorker>::channel();
    let mut server = Server::cluster(replicas, router).with_placement(placement);
    if elastic {
        use orloj::serve::{ElasticConfig, PlacementController};
        server = server.with_elastic(PlacementController::new(ElasticConfig {
            capacity,
            ..Default::default()
        }));
    }
    if let Some(t) = admission_opt(args) {
        use orloj::core::histogram::Histogram;
        use orloj::serve::{AdmissionConfig, AdmissionController};
        let mut ctl = AdmissionController::new(AdmissionConfig::with_threshold(t));
        // Seed per-(model, depth-app) profiles from the calibration pass:
        // a point mass at each depth's measured mean latency.
        for m in 0..n_models as u32 {
            for (depth, mean) in &calib {
                let h = Histogram::from_weights((mean - 0.5).max(0.0), 1.0, &[1.0]);
                ctl.seed_profile(ModelId(m), AppId(*depth as u32 - 1), &h);
            }
        }
        server = server.with_admission(ctl);
    }
    let telemetry_dir = telemetry_opt(args);
    if telemetry_dir.is_some() {
        server = server.with_telemetry(orloj::telemetry::Recorder::with_config(
            orloj::telemetry::RecorderConfig {
                capacity: (n * 16).max(1 << 14),
                ..Default::default()
            },
        ));
    }
    let handle = std::thread::spawn(move || server.run(rx));
    let mut rng = Rng::new(99);
    let slo_ms = args.get_f64("slo-ms", mean_ms * max_depth as f64 * 12.0);
    let gap_us = args.get_u64("gap-us", 500);
    let t0 = std::time::Instant::now();
    for i in 0..n as u64 {
        let depth = 1 + rng.index(max_depth) as u32;
        let model = ModelId((i % n_models as u64) as u32);
        let release = t0.elapsed().as_micros() as u64;
        let exec = calib
            .iter()
            .find(|(d, _)| *d == depth as usize)
            .map(|(_, m)| *m)
            .unwrap_or(mean_ms);
        let req = Request::new(i, AppId(depth - 1), release, ms_to_us(slo_ms), exec)
            .with_variant(depth)
            .with_model(model);
        submitter.submit(req);
        std::thread::sleep(std::time::Duration::from_micros(gap_us));
    }
    drop(submitter);
    let res = handle.join().unwrap();
    let report = RunReport::from_completions(&res.completions)
        .with_worker_stats(&res.per_worker, res.end_time);
    println!(
        "[{system} x{n_workers} router={router_name} models={n_models} placement={placement_spec}{}] {report}",
        if elastic { " elastic" } else { "" }
    );
    if res.placement.actions() > 0 {
        println!(
            "  placement: {} loads, {} unloads, {} rerouted, last action at {:.1}s",
            res.placement.loads,
            res.placement.unloads,
            res.placement.rerouted,
            res.placement.last_action_at as f64 / 1e6
        );
    }
    if res.admission.enabled {
        println!(
            "  admission: {} admitted, {} downgraded, {} early-rejected, {} best-effort served",
            res.admission.admitted,
            res.admission.downgraded,
            res.admission.early_rejected,
            res.admission.best_effort_served
        );
    }
    for w in &report.per_worker {
        println!(
            "  worker {}: utilization={:.2} batches={} busy={:.1}ms",
            w.worker,
            w.utilization,
            w.batches,
            w.busy_us as f64 / 1000.0
        );
    }
    for (m, r) in &report.per_model {
        println!(
            "  model {m}: finish_rate={:.3} ({}/{})  lat_p50={:.1}ms lat_p99={:.1}ms",
            r.finish_rate(),
            r.finished,
            r.total,
            r.latency.p50,
            r.latency.p99
        );
    }
    if let (Some(dir), Some(rec)) = (&telemetry_dir, &res.telemetry) {
        let dir = if dir.is_empty() { "results" } else { dir };
        std::fs::create_dir_all(dir).ok();
        let p = std::path::Path::new(dir).join("TELEMETRY_serve.json");
        std::fs::write(&p, rec.time_series().to_pretty()).ok();
        let tp = std::path::Path::new(dir).join("TELEMETRY_serve.trace.json");
        std::fs::write(&tp, rec.chrome_trace().to_string()).ok();
        println!(
            "  telemetry: {} events ({} dropped) -> {} and {}",
            rec.recorded(),
            rec.dropped_events(),
            p.display(),
            tp.display()
        );
        print!("{}", orloj::telemetry::calibration_table(&rec.calibration()));
    }
}

fn main() {
    logging::init_from_env();
    let args = Args::from_env();
    match args.command.as_deref() {
        Some("experiment") => cmd_experiment(&args),
        Some("trace") => cmd_trace(&args),
        // `--listen` routes to the wire-facing loop (sim workers, no
        // PJRT); the bare command stays the PJRT demo.
        Some("serve") if args.get("listen").is_some() => cmd_serve_listen(&args),
        Some("serve") => cmd_serve(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("list") => println!("{}", experiments::ALL.join("\n")),
        _ => usage(),
    }
}
