//! Tiny CLI argument parser (the offline build has no `clap`).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, and
//! positional arguments, with generated usage text. Intentionally minimal —
//! just what the `orloj` binary, examples and bench harness need.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    /// Subcommand (first bare word), if any.
    pub command: Option<String>,
    /// Positional arguments after the subcommand.
    pub positional: Vec<String>,
    /// `--key value` and `--key=value` options.
    opts: BTreeMap<String, String>,
    /// Bare `--flag`s.
    flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (testable) — pass
    /// `std::env::args().skip(1)` in main.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut command = None;
        let mut positional = Vec::new();
        let mut opts = BTreeMap::new();
        let mut flags = Vec::new();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if let Some(eq) = rest.find('=') {
                    opts.insert(rest[..eq].to_string(), rest[eq + 1..].to_string());
                } else if iter
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let val = iter.next().unwrap();
                    opts.insert(rest.to_string(), val);
                } else {
                    flags.push(rest.to_string());
                }
            } else if command.is_none() {
                command = Some(arg);
            } else {
                positional.push(arg);
            }
        }
        Args {
            command,
            positional,
            opts,
            flags,
        }
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    /// Comma-separated list option, e.g. `--slo 1.5,2,3`.
    pub fn get_list_f64(&self, name: &str, default: &[f64]) -> Vec<f64> {
        match self.get(name) {
            Some(s) => s
                .split(',')
                .filter_map(|t| t.trim().parse().ok())
                .collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse(&["experiment", "table3", "extra"]);
        assert_eq!(a.command.as_deref(), Some("experiment"));
        assert_eq!(a.positional, vec!["table3", "extra"]);
    }

    #[test]
    fn key_value_forms() {
        let a = parse(&["serve", "--port", "8080", "--rate=2.5"]);
        assert_eq!(a.get("port"), Some("8080"));
        assert_eq!(a.get_f64("rate", 0.0), 2.5);
    }

    #[test]
    fn flags() {
        let a = parse(&["x", "--verbose", "--seed", "7", "--dry-run"]);
        assert!(a.flag("verbose"));
        assert!(a.flag("dry-run"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get_u64("seed", 0), 7);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.command, None);
        assert_eq!(a.get_or("mode", "sim"), "sim");
        assert_eq!(a.get_usize("n", 10), 10);
    }

    #[test]
    fn list_option() {
        let a = parse(&["x", "--slo", "1.5,2,3"]);
        assert_eq!(a.get_list_f64("slo", &[]), vec![1.5, 2.0, 3.0]);
        assert_eq!(a.get_list_f64("other", &[9.0]), vec![9.0]);
    }

    #[test]
    fn flag_followed_by_flag_not_swallowed() {
        let a = parse(&["x", "--a", "--b", "val"]);
        assert!(a.flag("a"));
        assert_eq!(a.get("b"), Some("val"));
    }
}
