//! Utility substrates built in-tree for the offline environment:
//! RNG + samplers, JSON, statistics, CLI parsing, logging, and a mini
//! property-testing driver. See DESIGN.md §3 for the substitution table.

pub mod benchmark;
pub mod cli;
pub mod json;
pub mod logging;
pub mod proptest;
pub mod rng;
pub mod stats;
