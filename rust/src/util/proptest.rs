//! Mini property-based testing driver (no `proptest` in the offline set).
//!
//! A property is a closure over a seeded [`Rng`]; the driver runs it for N
//! seeds and, on failure, reports the failing seed so the case can be
//! replayed deterministically (`check_with_seed`). We deliberately skip
//! shrinking — the generators used in Orloj's properties produce small cases
//! already, and the seed is enough to reproduce.

use super::rng::Rng;

/// Number of cases run per property by default.
pub const DEFAULT_CASES: u64 = 256;

/// Run `prop` for `cases` seeds derived from `base_seed`. Panics with the
/// failing seed embedded in the message if the property returns an `Err`.
pub fn check_cases<F>(name: &str, base_seed: u64, cases: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(case);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (replay seed {seed}): {msg}"
            );
        }
    }
}

/// Run with the default number of cases.
pub fn check<F>(name: &str, base_seed: u64, prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    check_cases(name, base_seed, DEFAULT_CASES, prop);
}

/// Replay a single failing seed reported by `check`.
pub fn check_with_seed<F>(name: &str, seed: u64, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property '{name}' failed (seed {seed}): {msg}");
    }
}

/// Assert-like helper producing property-friendly results.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err(format!($($arg)*));
        }
    };
}

/// Approximate float equality for properties.
pub fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check_cases("trivial", 1, 50, |rng| {
            count += 1;
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("x out of range: {x}"))
            }
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        check_cases("always-fails", 2, 10, |_| Err("nope".to_string()));
    }

    #[test]
    fn close_tolerance() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-9));
        assert!(!close(1.0, 1.1, 1e-9));
        assert!(close(1e9, 1e9 + 10.0, 1e-7));
    }
}
