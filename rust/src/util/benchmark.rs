//! Micro-benchmark harness (the offline vendored set has no criterion).
//!
//! Warmup + timed iterations, reporting mean/p50/p99 per iteration in
//! nanoseconds. Used by the `cargo bench` targets (`harness = false`).
//!
//! Bench targets additionally emit machine-readable reports
//! (`BENCH_serve.json`, `BENCH_sched.json`) via [`json_report`] so the
//! repo's perf trajectory has durable data points; `ORLOJ_BENCH_QUICK=1`
//! shrinks every target to a CI-sized smoke run (same code paths, fewer
//! iterations), and `ORLOJ_BENCH_OUT` overrides the output directory
//! (default: the cargo manifest dir, falling back to the cwd).

use super::json::Json;
use super::stats::Summary;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// True when `ORLOJ_BENCH_QUICK` is set to a non-empty, non-"0" value —
/// the CI smoke mode: every bench runs the same code with shrunk
/// iteration counts / trace durations.
pub fn quick_mode() -> bool {
    std::env::var("ORLOJ_BENCH_QUICK")
        .map(|v| !v.is_empty() && v != "0")
        .unwrap_or(false)
}

/// Pick a parameter by bench mode.
pub fn quick_or<T>(quick: T, full: T) -> T {
    if quick_mode() {
        quick
    } else {
        full
    }
}

/// Where bench JSON artifacts go: `$ORLOJ_BENCH_OUT`, else the cargo
/// manifest dir (cargo sets it for bench processes), else the cwd.
pub fn bench_out_path(file: &str) -> PathBuf {
    let dir = std::env::var("ORLOJ_BENCH_OUT")
        .or_else(|_| std::env::var("CARGO_MANIFEST_DIR"))
        .unwrap_or_else(|_| ".".to_string());
    Path::new(&dir).join(file)
}

/// Assemble a bench report document (pure; [`json_report`] writes it).
pub fn report_json(bench: &str, cases: Vec<Json>) -> Json {
    let unix_s = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0);
    Json::obj(vec![
        ("bench", Json::str(bench)),
        ("schema", Json::num(1.0)),
        ("quick", Json::Bool(quick_mode())),
        ("unix_time_s", Json::num(unix_s)),
        ("cases", Json::Arr(cases)),
    ])
}

/// Write a machine-readable bench report and return its path. Every case
/// is one measured configuration; by convention rows carry the knobs
/// (`system`, `workers`, `router`, …) and the measurements (`events_per_s`,
/// `req_per_s`, per-iter `ns_*` percentiles).
pub fn json_report(file: &str, bench: &str, cases: Vec<Json>) -> std::io::Result<PathBuf> {
    let path = bench_out_path(file);
    std::fs::write(&path, report_json(bench, cases).to_pretty())?;
    Ok(path)
}

/// JSON row for a per-iteration [`Summary`] (nanoseconds).
pub fn summary_json(s: &Summary) -> Json {
    Json::obj(vec![
        ("count", Json::num(s.count as f64)),
        ("ns_mean", Json::num(s.mean)),
        ("ns_p50", Json::num(s.p50)),
        ("ns_p90", Json::num(s.p90)),
        ("ns_p99", Json::num(s.p99)),
        ("ns_max", Json::num(s.max)),
    ])
}

/// Time `iters` runs of `f` after `warmup` runs; returns per-iteration
/// nanoseconds. `f` gets the iteration index and should return something
/// observable so the optimizer cannot delete the work (we black-box it).
pub fn time_per_iter<T, F: FnMut(usize) -> T>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for i in 0..warmup {
        std::hint::black_box(f(i));
    }
    let mut samples = Vec::with_capacity(iters);
    for i in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f(i));
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    Summary::of(&samples)
}

/// Time one batched measurement: total wall time of `iters` calls divided
/// by `iters` (for very fast operations where per-call timer overhead
/// dominates).
pub fn time_batched<T, F: FnMut(usize) -> T>(warmup: usize, iters: usize, mut f: F) -> f64 {
    for i in 0..warmup {
        std::hint::black_box(f(i));
    }
    let t0 = Instant::now();
    for i in 0..iters {
        std::hint::black_box(f(i));
    }
    t0.elapsed().as_nanos() as f64 / iters as f64
}

/// Peak resident set size of this process in bytes (`VmHWM` from
/// `/proc/self/status`); `None` off Linux or when the proc filesystem is
/// unavailable/unparseable — callers should omit the metric rather than
/// report a garbage zero. A high-water mark: it only ever grows over the
/// process lifetime, so per-phase deltas need a reading before and after.
pub fn peak_rss_bytes() -> Option<u64> {
    #[cfg(target_os = "linux")]
    {
        let status = std::fs::read_to_string("/proc/self/status").ok()?;
        for line in status.lines() {
            if let Some(rest) = line.strip_prefix("VmHWM:") {
                let kb: u64 = rest.trim().trim_end_matches("kB").trim().parse().ok()?;
                return Some(kb * 1024);
            }
        }
        None
    }
    #[cfg(not(target_os = "linux"))]
    {
        None
    }
}

/// Pretty row printer for bench tables.
pub fn row(name: &str, n: usize, s: &Summary) {
    println!(
        "{name:>28} n={n:>6}  mean={:>10.0} ns  p50={:>10.0}  p99={:>10.0}",
        s.mean, s.p50, s.p99
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_is_positive_and_ordered() {
        let fast = time_batched(10, 100, |i| i * 2);
        let slow = time_batched(2, 20, |_| {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i * i);
            }
            x
        });
        assert!(fast >= 0.0);
        assert!(slow > fast, "slow={slow} fast={fast}");
    }

    #[test]
    fn summary_has_iters() {
        let s = time_per_iter(1, 50, |i| i + 1);
        assert_eq!(s.count, 50);
    }

    #[test]
    fn report_json_roundtrips() {
        let case = Json::obj(vec![
            ("system", Json::str("orloj")),
            ("workers", Json::num(4.0)),
            ("events_per_s", Json::num(123456.0)),
        ]);
        let doc = report_json("serve_loop", vec![case]);
        let parsed = Json::parse(&doc.to_pretty()).unwrap();
        assert_eq!(parsed.get("bench").as_str(), Some("serve_loop"));
        assert_eq!(parsed.get("schema").as_u64(), Some(1));
        assert_eq!(
            parsed.get("cases").at(0).get("system").as_str(),
            Some("orloj")
        );
        assert_eq!(parsed.get("cases").at(0).get("workers").as_u64(), Some(4));
    }

    #[test]
    fn summary_json_carries_percentiles() {
        let s = time_per_iter(1, 40, |i| i * i);
        let j = summary_json(&s);
        assert_eq!(j.get("count").as_u64(), Some(40));
        assert!(j.get("ns_p50").as_f64().unwrap() >= 0.0);
        assert!(j.get("ns_p99").as_f64().unwrap() >= j.get("ns_p50").as_f64().unwrap());
    }
}
