//! Micro-benchmark harness (the offline vendored set has no criterion).
//!
//! Warmup + timed iterations, reporting mean/p50/p99 per iteration in
//! nanoseconds. Used by the `cargo bench` targets (`harness = false`).

use super::stats::Summary;
use std::time::Instant;

/// Time `iters` runs of `f` after `warmup` runs; returns per-iteration
/// nanoseconds. `f` gets the iteration index and should return something
/// observable so the optimizer cannot delete the work (we black-box it).
pub fn time_per_iter<T, F: FnMut(usize) -> T>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for i in 0..warmup {
        std::hint::black_box(f(i));
    }
    let mut samples = Vec::with_capacity(iters);
    for i in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f(i));
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    Summary::of(&samples)
}

/// Time one batched measurement: total wall time of `iters` calls divided
/// by `iters` (for very fast operations where per-call timer overhead
/// dominates).
pub fn time_batched<T, F: FnMut(usize) -> T>(warmup: usize, iters: usize, mut f: F) -> f64 {
    for i in 0..warmup {
        std::hint::black_box(f(i));
    }
    let t0 = Instant::now();
    for i in 0..iters {
        std::hint::black_box(f(i));
    }
    t0.elapsed().as_nanos() as f64 / iters as f64
}

/// Pretty row printer for bench tables.
pub fn row(name: &str, n: usize, s: &Summary) {
    println!(
        "{name:>28} n={n:>6}  mean={:>10.0} ns  p50={:>10.0}  p99={:>10.0}",
        s.mean, s.p50, s.p99
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_is_positive_and_ordered() {
        let fast = time_batched(10, 100, |i| i * 2);
        let slow = time_batched(2, 20, |_| {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i * i);
            }
            x
        });
        assert!(fast >= 0.0);
        assert!(slow > fast, "slow={slow} fast={fast}");
    }

    #[test]
    fn summary_has_iters() {
        let s = time_per_iter(1, 50, |i| i + 1);
        assert_eq!(s.count, 50);
    }
}
