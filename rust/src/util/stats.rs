//! Small statistics helpers: means, percentiles, streaming summaries.
//!
//! Used by the metrics module, the benchmark harness (which replaces
//! criterion in this offline build), and the evaluation harness when
//! reporting paper-style rows.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation; 0.0 for len < 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via linear interpolation on a *sorted* slice.
/// `q` in [0, 100].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let q = q.clamp(0.0, 100.0);
    let rank = q / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Percentile of an unsorted slice (copies + sorts).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, q)
}

/// Summary of a sample: count/mean/std/min/p50/p90/p99/max.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary {
                count: 0,
                mean: 0.0,
                std: 0.0,
                min: 0.0,
                p50: 0.0,
                p90: 0.0,
                p99: 0.0,
                max: 0.0,
            };
        }
        let mut v = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            count: v.len(),
            mean: mean(&v),
            std: stddev(&v),
            min: v[0],
            p50: percentile_sorted(&v, 50.0),
            p90: percentile_sorted(&v, 90.0),
            p99: percentile_sorted(&v, 99.0),
            max: *v.last().unwrap(),
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.3} std={:.3} min={:.3} p50={:.3} p90={:.3} p99={:.3} max={:.3}",
            self.count, self.mean, self.std, self.min, self.p50, self.p90, self.p99, self.max
        )
    }
}

/// Streaming counter with Welford mean/variance — used where storing every
/// observation would bloat the hot path.
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(Summary::of(&[]).count, 0);
    }

    #[test]
    fn percentile_interpolation() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [9.0, 1.0, 5.0];
        assert!((percentile(&xs, 50.0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn summary_fields() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.count, 100);
        assert!((s.min - 1.0).abs() < 1e-12);
        assert!((s.max - 100.0).abs() < 1e-12);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert!((s.mean - 50.5).abs() < 1e-9);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.stddev() - stddev(&xs)).abs() < 1e-9);
    }
}
