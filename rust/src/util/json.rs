//! Minimal JSON parser + writer.
//!
//! The offline environment ships neither `serde` nor `serde_json`; Orloj
//! needs JSON for the AOT artifact manifest (written by `python/compile/
//! aot.py`), experiment configs, and trace record/replay. This module is a
//! complete, strict JSON implementation (RFC 8259 subset: no surrogate-pair
//! escapes beyond BMP are required by our producers, but \uXXXX including
//! surrogate pairs is handled).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in a BTreeMap for deterministic
/// serialization (stable diffs for recorded traces).
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------- accessors ----------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 => Some(*n as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; returns Null for missing keys on non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index lookup.
    pub fn at(&self, idx: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(v) => v.get(idx).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ---------- constructors ----------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ---------- parse ----------

    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: input.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---------- write ----------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() {
        if n.fract() == 0.0 && n.abs() < 9.0e15 {
            out.push_str(&format!("{}", n as i64));
        } else {
            out.push_str(&format!("{}", n));
        }
    } else {
        // JSON has no Inf/NaN; emit null (matches python json.dumps default
        // behaviour closely enough for our producers, which never emit them).
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            pos: self.i,
        }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(self.err("invalid low surrogate"));
                                    }
                                    self.i -= 1; // compensate for += 1 below
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                self.i -= 1; // compensate for += 1 below
                                hi
                            };
                            match char::from_u32(cp) {
                                Some(c) => s.push(c),
                                None => return Err(self.err("invalid codepoint")),
                            }
                        }
                        _ => return Err(self.err("invalid escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = &self.b[self.i..];
                    let text = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    if (c as u32) < 0x20 {
                        return Err(self.err("control char in string"));
                    }
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("short \\u escape"));
        }
        let txt = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(txt, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 3; // caller advances 1 more
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").at(0).as_f64(), Some(1.0));
        assert_eq!(v.get("a").at(2).get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\n\t\"\\Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\Aé"));
    }

    #[test]
    fn parse_surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s",true,null],"obj":{"k":-7}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn roundtrip_pretty() {
        let v = Json::obj(vec![
            ("x", Json::num(1.0)),
            ("y", Json::arr(vec![Json::str("a"), Json::Bool(false)])),
        ]);
        let pretty = v.to_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
        assert!(pretty.contains('\n'));
    }

    #[test]
    fn integer_formatting_is_exact() {
        assert_eq!(Json::num(1_000_000.0).to_string(), "1000000");
        assert_eq!(Json::num(0.5).to_string(), "0.5");
    }

    #[test]
    fn u64_accessor() {
        assert_eq!(Json::parse("7").unwrap().as_u64(), Some(7));
        assert_eq!(Json::parse("-7").unwrap().as_u64(), None);
        assert_eq!(Json::parse("7.5").unwrap().as_u64(), None);
    }

    #[test]
    fn missing_keys_are_null() {
        let v = Json::parse("{}").unwrap();
        assert!(v.get("nope").is_null());
        assert!(v.get("nope").get("deeper").is_null());
    }

    #[test]
    fn python_json_dumps_compat() {
        // The exact shape python's json.dump(manifest, indent=2) produces.
        let src = "{\n  \"variants\": [\n    {\n      \"depth\": 1,\n      \"batch\": 4,\n      \"path\": \"model_d1_b4.hlo.txt\"\n    }\n  ]\n}";
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("variants").at(0).get("depth").as_u64(), Some(1));
    }
}
