//! Deterministic pseudo-random number generation and samplers.
//!
//! The offline build environment ships no `rand`/`rand_distr`, so Orloj
//! carries its own generator (xoshiro256++, Blackman & Vigna) plus the
//! distribution samplers the workload generators need: uniform, normal
//! (Box–Muller), lognormal, exponential, Poisson and gamma. Everything is
//! seedable so request traces can be recorded and replayed bit-exactly
//! (Section 5.2 of the paper: "the generation is done once among different
//! runs ... replayed for subsequent runs").

/// xoshiro256++ PRNG. Fast, 256-bit state, passes BigCrush; more than
/// adequate for workload synthesis (not for cryptography).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

/// SplitMix64, used to expand a 64-bit seed into the full state as
/// recommended by the xoshiro authors.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = rotl(s[0].wrapping_add(s[3]), 23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = rotl(s[3], 45);
        result
    }

    /// Uniform in [0, 1). 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (polar variant avoided for
    /// determinism-simplicity; the trig form consumes exactly two uniforms).
    pub fn normal(&mut self) -> f64 {
        // Avoid log(0).
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean/stddev.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal: exp(N(mu, sigma)).
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    #[inline]
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        let u = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        -u.ln() / lambda
    }

    /// Poisson-distributed count with mean `lambda`.
    ///
    /// Knuth's product method for small lambda, PTRS-style normal
    /// approximation w/ rejection fallback kept simple: for lambda > 30 we
    /// use the (rounded, clamped) normal approximation which is accurate to
    /// well under the noise floor of the workloads that use it.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let x = self.normal_ms(lambda, lambda.sqrt()).round();
            if x < 0.0 {
                0
            } else {
                x as u64
            }
        }
    }

    /// Gamma(shape k, scale theta) via Marsaglia–Tsang.
    pub fn gamma(&mut self, shape: f64, scale: f64) -> f64 {
        debug_assert!(shape > 0.0 && scale > 0.0);
        if shape < 1.0 {
            // Boost: Gamma(k) = Gamma(k+1) * U^(1/k)
            let u = self.f64().max(f64::MIN_POSITIVE);
            return self.gamma(shape + 1.0, scale) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.f64().max(f64::MIN_POSITIVE);
            if u.ln() < 0.5 * x * x + d - d * v3 + d * v3.ln() {
                return d * v3 * scale;
            }
        }
    }

    /// Pareto (heavy tail) with scale x_m and shape alpha.
    pub fn pareto(&mut self, x_m: f64, alpha: f64) -> f64 {
        let u = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        x_m / u.powf(1.0 / alpha)
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork a child generator (independent stream) — used to give each
    /// application / component its own stream while keeping the experiment
    /// reproducible from one root seed.
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::new(17);
        for lambda in [0.5, 3.0, 12.0, 80.0] {
            let n = 50_000;
            let mean = (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < 0.05 * lambda.max(1.0),
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn gamma_mean_variance() {
        let mut r = Rng::new(19);
        let (k, theta) = (2.5, 1.5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gamma(k, theta)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        assert!((mean - k * theta).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn lognormal_median() {
        let mut r = Rng::new(23);
        let n = 100_001;
        let mut xs: Vec<f64> = (0..n).map(|_| r.lognormal(1.0, 0.7)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[n / 2];
        // Median of lognormal(mu, sigma) is e^mu.
        assert!((median - 1.0f64.exp()).abs() < 0.06, "median={median}");
    }

    #[test]
    fn weighted_proportions() {
        let mut r = Rng::new(29);
        let w = [1.0, 3.0];
        let n = 40_000;
        let ones = (0..n).filter(|_| r.weighted(&w) == 1).count();
        let frac = ones as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn pareto_lower_bound() {
        let mut r = Rng::new(31);
        for _ in 0..10_000 {
            assert!(r.pareto(2.0, 1.1) >= 2.0);
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork();
        let mut b = root.fork();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(37);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
