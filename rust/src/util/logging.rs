//! Minimal leveled logger (no `tracing` in the offline vendored set).
//!
//! Level comes from `ORLOJ_LOG` (error|warn|info|debug|trace) or is set
//! programmatically. Output goes to stderr so stdout stays clean for the
//! experiment harness's machine-readable tables.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(2); // Info

impl Level {
    pub fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

/// Initialize from the ORLOJ_LOG env var (idempotent, cheap).
pub fn init_from_env() {
    if let Ok(v) = std::env::var("ORLOJ_LOG") {
        if let Some(l) = Level::from_str(&v) {
            set_level(l);
        }
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

#[inline]
pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(l) {
        eprintln!("[{} {}] {}", l.tag(), target, msg);
    }
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_trace {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Trace, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_str("debug"), Some(Level::Debug));
        assert_eq!(Level::from_str("WARN"), Some(Level::Warn));
        assert_eq!(Level::from_str("nope"), None);
    }

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default for other tests
    }

    #[test]
    fn trace_macro_expands_and_gates() {
        // `log_trace!` must expand (the call itself is the regression:
        // the macro was missing while `Level::Trace` existed) and must be
        // gated off at the default Info level.
        assert!(!enabled(Level::Trace));
        crate::log_trace!("logging::test", "suppressed at level {:?}", level());
        set_level(Level::Trace);
        assert!(enabled(Level::Trace));
        set_level(Level::Info); // restore default for other tests
    }
}
