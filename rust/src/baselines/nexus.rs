//! Nexus-style plan-based scheduler (Shen et al., SOSP'19; paper §2.3).
//!
//! Nexus pre-computes an execution plan per epoch using the *mean*
//! execution time ("squishy bin-packing"): pick the largest batch size
//! whose planned batch latency fits within half the SLO (the other half is
//! the queuing budget), then execute fixed-size batches on that cadence.
//! The plan is only re-derived at epoch boundaries. Under high-variance
//! dynamic workloads the mean mispredicts almost every batch, the cadence
//! drifts, and "it cannot reach a stable state" (paper §2.3).

use crate::clock::{us_to_ms, Micros};
use crate::core::request::{ModelId, Outcome, Request};
use crate::scheduler::{BatchPrediction, FifoQueues, Scheduler, SchedulerConfig};
use crate::util::stats::Welford;

pub struct NexusScheduler {
    cfg: SchedulerConfig,
    /// Per-model FIFO lanes sharing one arrival order (§Perf: model-pure
    /// plan batches fill in O(batch)).
    queue: FifoQueues,
    dropped: Vec<(Request, Outcome)>,
    /// Mean solo exec time (ms) from observation (epoch input).
    exec_mean: Welford,
    /// Mean SLO (ms) from observation.
    slo_mean: Welford,
    /// Current plan: fixed batch size.
    plan_bs: usize,
    /// Planned batch latency (ms) under the mean-exec assumption.
    plan_latency_ms: f64,
    /// Epoch bookkeeping.
    last_plan: Micros,
    epoch: Micros,
    /// Plan's latency belief for the batch most recently formed
    /// (telemetry; see `Scheduler::last_batch_prediction`).
    last_prediction: Option<BatchPrediction>,
}

impl NexusScheduler {
    pub fn new(cfg: SchedulerConfig, _seed: u64) -> Self {
        NexusScheduler {
            cfg,
            queue: FifoQueues::new(),
            dropped: Vec::new(),
            exec_mean: Welford::new(),
            slo_mean: Welford::new(),
            plan_bs: 1,
            plan_latency_ms: 10.0,
            last_plan: 0,
            epoch: 1_000_000, // 1 s epochs
            last_prediction: None,
        }
    }

    /// Seed the mean-exec estimate (deployment-time profile, mirroring how
    /// the experiments seed Orloj's profiler).
    pub fn seed_exec_mean(&mut self, mean_ms: f64) {
        self.exec_mean.push(mean_ms);
    }

    fn replan(&mut self, now: Micros) {
        self.last_plan = now;
        let exec = if self.exec_mean.count() > 0 {
            self.exec_mean.mean()
        } else {
            10.0
        };
        let slo = if self.slo_mean.count() > 0 {
            self.slo_mean.mean()
        } else {
            100.0
        };
        let m = self.cfg.cost_model;
        // Largest supported batch size whose planned latency fits half the
        // SLO (queueing gets the other half).
        let mut best = (1usize, m.latency(1, exec));
        for &bs in &self.cfg.batch_sizes {
            let lat = m.latency(bs, exec);
            if lat <= slo * 0.5 && bs > best.0 {
                best = (bs, lat);
            }
        }
        self.plan_bs = best.0;
        self.plan_latency_ms = best.1;
    }

    fn drop_expired(&mut self, now: Micros) {
        // Nexus drops requests that cannot make it under the *planned*
        // latency.
        let lat = self.plan_latency_ms;
        while let Some(front) = self.queue.front() {
            if us_to_ms(now) + lat > us_to_ms(front.deadline) {
                let r = self.queue.pop_front().unwrap();
                self.dropped.push((r, Outcome::TimedOut));
            } else {
                break;
            }
        }
    }
}

impl Scheduler for NexusScheduler {
    fn name(&self) -> &'static str {
        "nexus"
    }

    fn seed_app_profile(
        &mut self,
        _model: ModelId,
        _app: crate::core::request::AppId,
        hist: &crate::core::histogram::Histogram,
        weight: u64,
    ) {
        // Nexus plans on the mean: fold each app's mean in, traffic-weighted.
        for _ in 0..weight.clamp(1, 64) {
            self.exec_mean.push(hist.mean());
        }
    }

    fn install_model(&mut self, model: ModelId, _cold_start_ms: f64, _now: Micros) {
        // Nexus plans on the mean; the cold start perturbs one epoch and
        // washes out of the plan, so only the queue state is created.
        self.queue.ensure_lane(model);
    }

    fn evict_model(&mut self, model: ModelId) -> Vec<Request> {
        self.queue.remove_lane(model)
    }

    fn reap(&mut self, now: Micros) {
        // The next_batch-top shed under the *current* plan. Deliberately
        // no replan here: epoch boundaries must keep shifting only at
        // batch-formation time, or reaping would change the plan cadence.
        self.drop_expired(now);
    }

    fn on_arrival(&mut self, req: Request, now: Micros) {
        if req.expired(now) {
            self.dropped.push((req, Outcome::TimedOut));
            return;
        }
        self.slo_mean.push(us_to_ms(req.slo()));
        if self.exec_mean.count() == 0 {
            self.replan(now);
        }
        self.queue.push(req);
    }

    fn next_batch(&mut self, now: Micros) -> Option<Vec<Request>> {
        if now.saturating_sub(self.last_plan) >= self.epoch {
            self.replan(now);
        }
        self.drop_expired(now);
        let head = self.queue.front()?;
        let (model, head_deadline) = (head.model, head.deadline);
        // Execute only full planned batches (of the head's model — a batch
        // executes exactly one model), except when the head's deadline
        // forces a partial batch now.
        let available = self.queue.pending_for(model).max(1);
        let forced = us_to_ms(now) + 2.0 * self.plan_latency_ms > us_to_ms(head_deadline);
        if available < self.plan_bs && !forced {
            return None; // wait for the plan's batch to fill
        }
        let take = self.plan_bs.min(available);
        // The plan's mean-exec belief for the batch actually taken (a
        // forced partial batch is re-costed at its real size). Nexus plans
        // on a point mean — record a narrow ±10% band.
        let exec = if self.exec_mean.count() > 0 {
            self.exec_mean.mean()
        } else {
            10.0
        };
        self.last_prediction = Some(BatchPrediction::point(
            self.cfg.cost_model.latency(take, exec),
            0.1,
        ));
        Some(self.queue.drain_model(model, take))
    }

    fn on_batch_complete(&mut self, batch: &[Request], _batch_ms: f64, _now: Micros) {
        for r in batch {
            self.exec_mean.push(r.exec_ms);
        }
    }

    fn drain_dropped(&mut self) -> Vec<(Request, Outcome)> {
        std::mem::take(&mut self.dropped)
    }

    fn wake_hint(&self, now: Micros) -> Option<Micros> {
        // Wake when the head would be forced, or at the epoch boundary.
        let epoch_end = self.last_plan + self.epoch;
        let head = self.queue.front().map(|r| {
            let forced_at_ms =
                us_to_ms(r.deadline) - 2.0 * self.plan_latency_ms;
            crate::clock::ms_to_us(forced_at_ms.max(0.0)).max(now + 100)
        });
        match head {
            Some(h) => Some(h.min(epoch_end)),
            None => Some(epoch_end),
        }
    }

    fn earliest_deadline(&self) -> Option<Micros> {
        // FIFO within the plan's head model: the global head's deadline
        // bounds the useful idle advance.
        self.queue.front().map(|r| r.deadline)
    }

    fn pending(&self) -> usize {
        self.queue.len()
    }

    fn pending_for(&self, model: ModelId) -> usize {
        self.queue.pending_for(model)
    }

    fn backlog_estimate(&mut self, model: ModelId) -> f64 {
        // Drain time on the current plan's cadence: queued work served as
        // plan-sized batches at the planned batch latency.
        let n = self.queue.pending_for(model);
        if n == 0 {
            return 0.0;
        }
        n.div_ceil(self.plan_bs.max(1)) as f64 * self.plan_latency_ms
    }

    fn last_batch_prediction(&self) -> Option<BatchPrediction> {
        self.last_prediction
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::ms_to_us;
    use crate::core::batchmodel::BatchCostModel;
    use crate::core::request::AppId;

    fn cfg() -> SchedulerConfig {
        SchedulerConfig {
            cost_model: BatchCostModel::new(0.0, 1.0),
            ..Default::default()
        }
    }

    fn req(id: u64, release: Micros, slo_ms: f64, exec_ms: f64) -> Request {
        Request::new(id, AppId(0), release, ms_to_us(slo_ms), exec_ms)
    }

    #[test]
    fn plan_respects_slo_budget() {
        let mut s = NexusScheduler::new(cfg(), 0);
        s.seed_exec_mean(10.0);
        // SLO 100 ms → budget 50 ms → with exec 10: bs=4 (40ms) fits, 8 (80) not.
        s.on_arrival(req(0, 0, 100.0, 10.0), 0);
        s.replan(0);
        assert_eq!(s.plan_bs, 4);
    }

    #[test]
    fn waits_for_full_plan_batch() {
        let mut s = NexusScheduler::new(cfg(), 0);
        s.seed_exec_mean(10.0);
        for i in 0..2 {
            s.on_arrival(req(i, 0, 400.0, 10.0), 0);
        }
        s.replan(0);
        assert!(s.plan_bs > 2);
        assert!(s.next_batch(0).is_none(), "waits to fill planned batch");
        // But a forced head executes partially: forced once
        // now + 2·plan_latency > deadline, while still feasible
        // (now + plan_latency ≤ deadline).
        let late = ms_to_us(150.0);
        let b = s.next_batch(late);
        assert!(b.is_some(), "deadline pressure forces partial batch");
        assert_eq!(b.unwrap().len(), 2);
    }

    #[test]
    fn drops_by_planned_latency() {
        let mut s = NexusScheduler::new(cfg(), 0);
        s.seed_exec_mean(50.0);
        s.on_arrival(req(0, 0, 60.0, 50.0), 0);
        s.replan(0);
        // planned latency at bs=1 is 50 ms; at t=20ms, 20+50 > 60 → drop.
        assert!(s.next_batch(ms_to_us(20.0)).is_none());
        assert_eq!(s.drain_dropped().len(), 1);
    }

    #[test]
    fn plan_batches_are_model_pure() {
        let mut s = NexusScheduler::new(cfg(), 0);
        s.seed_exec_mean(10.0);
        // plan_bs = 4 at SLO 100; give each model exactly a plan's worth.
        for i in 0..8 {
            let m = ModelId((i % 2) as u32);
            s.on_arrival(req(i, 0, 100.0, 10.0).with_model(m), 0);
        }
        s.replan(0);
        assert_eq!(s.plan_bs, 4);
        let b = s.next_batch(0).unwrap();
        assert_eq!(b.len(), 4);
        assert!(b.iter().all(|r| r.model == ModelId(0)));
        assert_eq!(s.pending_for(ModelId(1)), 4);
        let b2 = s.next_batch(0).unwrap();
        assert!(b2.iter().all(|r| r.model == ModelId(1)));
    }

    #[test]
    fn replans_each_epoch_from_means() {
        let mut s = NexusScheduler::new(cfg(), 0);
        s.seed_exec_mean(10.0);
        s.on_arrival(req(0, 0, 100.0, 10.0), 0);
        s.replan(0);
        let bs0 = s.plan_bs;
        // Feed much slower measurements, cross the epoch.
        let slow: Vec<Request> = (0..50).map(|i| req(100 + i, 0, 100.0, 45.0)).collect();
        s.on_batch_complete(&slow, 45.0, 500_000);
        let _ = s.next_batch(1_100_000);
        assert!(s.plan_bs < bs0, "plan shrinks when exec mean grows");
    }
}
