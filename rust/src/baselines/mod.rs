//! Baseline serving policies the paper evaluates against (§2.3, §5):
//! Clipper (reactive), Nexus (precomputed plan from means), Clockwork
//! (point-estimate plan-ahead with strict execution windows), plus a plain
//! EDF max-batch policy used in ablations.
//!
//! These are re-implementations of each system's *scheduling policy* on the
//! shared [`Scheduler`](crate::scheduler::Scheduler) trait — the level at
//! which the paper's comparison operates — not ports of their full
//! codebases.

pub mod clipper;
pub mod clockwork;
pub mod edf;
pub mod nexus;

use crate::scheduler::{Scheduler, SchedulerConfig};
use crate::scheduler::orloj::OrlojScheduler;

/// Construct any of the four systems by name.
pub fn by_name(name: &str, cfg: SchedulerConfig, seed: u64) -> Option<Box<dyn Scheduler>> {
    match name {
        "orloj" => Some(Box::new(OrlojScheduler::new(cfg, seed))),
        "clipper" => Some(Box::new(clipper::ClipperScheduler::new(cfg, seed))),
        "nexus" => Some(Box::new(nexus::NexusScheduler::new(cfg, seed))),
        "clockwork" => Some(Box::new(clockwork::ClockworkScheduler::new(cfg, seed))),
        "edf" => Some(Box::new(edf::EdfScheduler::new(cfg, seed))),
        _ => None,
    }
}

/// The four systems of the paper's evaluation, in its plotting order.
pub const PAPER_SYSTEMS: [&str; 4] = ["clipper", "nexus", "clockwork", "orloj"];

/// All five runnable systems: the paper's four plus the plain-EDF
/// ablation baseline. This is what the experiment grids and the serving
/// demos sweep.
pub const ALL_SYSTEMS: [&str; 5] = ["clipper", "nexus", "clockwork", "edf", "orloj"];
