//! Plain earliest-deadline-first max-batch policy — an ablation baseline
//! (not in the paper's comparison set) isolating how much of Orloj's win
//! comes from the distribution-aware score versus simply being
//! deadline-aware and work-conserving. Batches are model-pure: the head's
//! model is served, later-deadline requests of other co-located models
//! wait for their own batch.

use crate::clock::{us_to_ms, Micros};
use crate::core::request::{ModelId, Outcome, Request};
use crate::scheduler::{BatchPrediction, EdfQueues, Scheduler, SchedulerConfig};
use crate::util::stats::Welford;

pub struct EdfScheduler {
    cfg: SchedulerConfig,
    /// Per-model deadline heaps carrying the requests inline (§Perf: no
    /// id→request hash map, no skipped-entry re-push churn).
    queue: EdfQueues,
    dropped: Vec<(Request, Outcome)>,
    exec_mean: Welford,
    /// Mean-exec estimate for the batch most recently formed (telemetry;
    /// see `Scheduler::last_batch_prediction`).
    last_prediction: Option<BatchPrediction>,
}

impl EdfScheduler {
    pub fn new(cfg: SchedulerConfig, _seed: u64) -> Self {
        EdfScheduler {
            cfg,
            queue: EdfQueues::new(),
            dropped: Vec::new(),
            exec_mean: Welford::new(),
            last_prediction: None,
        }
    }

    pub fn seed_exec_mean(&mut self, ms: f64) {
        self.exec_mean.push(ms);
    }

    fn est(&self, bs: usize) -> f64 {
        let exec = if self.exec_mean.count() > 0 {
            self.exec_mean.mean()
        } else {
            10.0
        };
        self.cfg.cost_model.latency(bs, exec)
    }

    /// Drop queue heads that can't make it even solo — the shed
    /// `next_batch` performs before filling a batch.
    fn shed_hopeless(&mut self, now: Micros) {
        while let Some(head) = self.queue.peek() {
            if us_to_ms(now) + self.est(1) > us_to_ms(head.deadline) {
                let r = self.queue.pop_head().unwrap();
                self.dropped.push((r, Outcome::TimedOut));
            } else {
                break;
            }
        }
    }
}

impl Scheduler for EdfScheduler {
    fn name(&self) -> &'static str {
        "edf"
    }

    fn seed_app_profile(
        &mut self,
        _model: ModelId,
        _app: crate::core::request::AppId,
        hist: &crate::core::histogram::Histogram,
        _weight: u64,
    ) {
        self.exec_mean.push(hist.mean());
    }

    fn on_arrival(&mut self, req: Request, now: Micros) {
        if req.expired(now) {
            self.dropped.push((req, Outcome::TimedOut));
            return;
        }
        self.queue.push(req);
    }

    fn install_model(&mut self, model: ModelId, _cold_start_ms: f64, _now: Micros) {
        self.queue.ensure_lane(model);
    }

    fn evict_model(&mut self, model: ModelId) -> Vec<Request> {
        self.queue.remove_lane(model)
    }

    fn reap(&mut self, now: Micros) {
        self.shed_hopeless(now);
    }

    fn next_batch(&mut self, now: Micros) -> Option<Vec<Request>> {
        // Drop heads that can't make it even solo.
        self.shed_hopeless(now);
        let head = self.queue.peek()?;
        let (model, head_deadline) = (head.model, head.deadline);
        let slack = us_to_ms(head_deadline) - us_to_ms(now);
        let mut bs = 1usize;
        for &cand in &self.cfg.batch_sizes {
            if self.est(cand) <= slack && cand > bs {
                bs = cand;
            }
        }
        // Model-pure fill: take the head's model in deadline order; other
        // models' lanes are untouched.
        let take = bs.min(self.queue.pending_for(model).max(1));
        let batch = self.queue.drain_model(model, take);
        if batch.is_empty() {
            None
        } else {
            // Online-mean belief re-costed at the size actually taken;
            // Welford's stddev scales the band (±1σ around the mean, with
            // a ±10% floor before enough samples accrue).
            let est = self.est(batch.len());
            let frac = if self.exec_mean.count() > 1 && self.exec_mean.mean() > 0.0 {
                (self.exec_mean.stddev() / self.exec_mean.mean()).max(0.1)
            } else {
                0.1
            };
            self.last_prediction = Some(BatchPrediction::point(est, frac));
            Some(batch)
        }
    }

    fn on_batch_complete(&mut self, batch: &[Request], _batch_ms: f64, _now: Micros) {
        for r in batch {
            self.exec_mean.push(r.exec_ms);
        }
    }

    fn drain_dropped(&mut self) -> Vec<(Request, Outcome)> {
        std::mem::take(&mut self.dropped)
    }

    fn wake_hint(&self, _now: Micros) -> Option<Micros> {
        self.queue.min_deadline()
    }

    fn earliest_deadline(&self) -> Option<Micros> {
        self.queue.min_deadline()
    }

    fn pending(&self) -> usize {
        self.queue.len()
    }

    fn pending_for(&self, model: ModelId) -> usize {
        self.queue.pending_for(model)
    }

    fn backlog_estimate(&mut self, model: ModelId) -> f64 {
        // Drain time at the max supported batch size under the online-mean
        // belief (EDF is work-conserving: it fills as large as the head's
        // slack allows, so max-batch drain is its steady-state ceiling).
        let n = self.queue.pending_for(model);
        if n == 0 {
            return 0.0;
        }
        let bs = *self.cfg.batch_sizes.iter().max().unwrap_or(&1);
        n.div_ceil(bs) as f64 * self.est(bs)
    }

    fn last_batch_prediction(&self) -> Option<BatchPrediction> {
        self.last_prediction
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::ms_to_us;
    use crate::core::batchmodel::BatchCostModel;
    use crate::core::request::AppId;

    fn sched() -> EdfScheduler {
        let cfg = SchedulerConfig {
            cost_model: BatchCostModel::new(0.0, 1.0),
            ..Default::default()
        };
        let mut s = EdfScheduler::new(cfg, 0);
        s.seed_exec_mean(5.0);
        s
    }

    #[test]
    fn serves_in_deadline_order() {
        let mut s = sched();
        s.on_arrival(Request::new(1, AppId(0), 0, ms_to_us(300.0), 5.0), 0);
        s.on_arrival(Request::new(2, AppId(0), 0, ms_to_us(100.0), 5.0), 0);
        let b = s.next_batch(0).unwrap();
        assert_eq!(b[0].id.0, 2);
    }

    #[test]
    fn evict_drains_in_deadline_order_and_reap_sheds_heads() {
        let mut s = sched();
        s.install_model(ModelId(1), 50.0, 0);
        s.on_arrival(Request::new(0, AppId(0), 0, ms_to_us(300.0), 5.0), 0);
        s.on_arrival(
            Request::new(1, AppId(0), 0, ms_to_us(90.0), 5.0).with_model(ModelId(1)),
            0,
        );
        s.on_arrival(
            Request::new(2, AppId(0), 0, ms_to_us(40.0), 5.0).with_model(ModelId(1)),
            0,
        );
        let drained = s.evict_model(ModelId(1));
        assert_eq!(
            drained.iter().map(|r| r.id.0).collect::<Vec<_>>(),
            vec![2, 1],
            "deadline order"
        );
        assert_eq!(s.pending(), 1);
        assert!(s.drain_dropped().is_empty(), "evict drains, never drops");
        // Reap sheds exactly the hopeless head (deadline 300 ms, est 5 ms
        // → hopeless from ~295 ms).
        s.reap(ms_to_us(299.0));
        assert_eq!(s.pending(), 0);
        assert_eq!(s.drain_dropped().len(), 1);
    }

    #[test]
    fn batches_never_mix_models() {
        let mut s = sched();
        // Interleaved deadlines across two models.
        for i in 0..6u64 {
            let m = ModelId((i % 2) as u32);
            s.on_arrival(
                Request::new(i, AppId(0), 0, ms_to_us(100.0 + i as f64), 5.0).with_model(m),
                0,
            );
        }
        assert_eq!(s.pending_for(ModelId(0)), 3);
        assert_eq!(s.pending_for(ModelId(1)), 3);
        let b = s.next_batch(0).unwrap();
        assert!(b.iter().all(|r| r.model == b[0].model), "model-pure batch");
        assert_eq!(b[0].model, ModelId(0), "head's model served first");
        assert_eq!(b.len(), 3);
        // The other model's requests are still queued, in order.
        assert_eq!(s.pending(), 3);
        assert_eq!(s.pending_for(ModelId(1)), 3);
        let b2 = s.next_batch(0).unwrap();
        assert_eq!(b2.len(), 3);
        assert!(b2.iter().all(|r| r.model == ModelId(1)));
    }
}
