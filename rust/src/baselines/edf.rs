//! Plain earliest-deadline-first max-batch policy — an ablation baseline
//! (not in the paper's comparison set) isolating how much of Orloj's win
//! comes from the distribution-aware score versus simply being
//! deadline-aware and work-conserving.

use crate::clock::{us_to_ms, Micros};
use crate::core::request::{Outcome, Request};
use crate::scheduler::{Scheduler, SchedulerConfig};
use crate::util::stats::Welford;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

pub struct EdfScheduler {
    cfg: SchedulerConfig,
    queue: BinaryHeap<Reverse<(Micros, u64)>>,
    by_seq: HashMap<u64, Request>,
    dropped: Vec<(Request, Outcome)>,
    exec_mean: Welford,
}

impl EdfScheduler {
    pub fn new(cfg: SchedulerConfig, _seed: u64) -> Self {
        EdfScheduler {
            cfg,
            queue: BinaryHeap::new(),
            by_seq: HashMap::new(),
            dropped: Vec::new(),
            exec_mean: Welford::new(),
        }
    }

    pub fn seed_exec_mean(&mut self, ms: f64) {
        self.exec_mean.push(ms);
    }

    fn est(&self, bs: usize) -> f64 {
        let exec = if self.exec_mean.count() > 0 {
            self.exec_mean.mean()
        } else {
            10.0
        };
        self.cfg.cost_model.latency(bs, exec)
    }

    fn peek(&mut self) -> Option<(Micros, u64)> {
        while let Some(&Reverse((d, seq))) = self.queue.peek() {
            if self.by_seq.contains_key(&seq) {
                return Some((d, seq));
            }
            self.queue.pop();
        }
        None
    }
}

impl Scheduler for EdfScheduler {
    fn name(&self) -> &'static str {
        "edf"
    }

    fn seed_app_profile(
        &mut self,
        _app: crate::core::request::AppId,
        hist: &crate::core::histogram::Histogram,
        _weight: u64,
    ) {
        self.exec_mean.push(hist.mean());
    }

    fn on_arrival(&mut self, req: Request, now: Micros) {
        if req.expired(now) {
            self.dropped.push((req, Outcome::TimedOut));
            return;
        }
        self.queue.push(Reverse((req.deadline, req.id.0)));
        self.by_seq.insert(req.id.0, req);
    }

    fn next_batch(&mut self, now: Micros) -> Option<Vec<Request>> {
        // Drop heads that can't make it even solo.
        while let Some((d, seq)) = self.peek() {
            if us_to_ms(now) + self.est(1) > us_to_ms(d) {
                let r = self.by_seq.remove(&seq).unwrap();
                self.queue.pop();
                self.dropped.push((r, Outcome::TimedOut));
            } else {
                break;
            }
        }
        let (head_deadline, _) = self.peek()?;
        let slack = us_to_ms(head_deadline) - us_to_ms(now);
        let mut bs = 1usize;
        for &cand in &self.cfg.batch_sizes {
            if self.est(cand) <= slack && cand > bs {
                bs = cand;
            }
        }
        let take = bs.min(self.by_seq.len());
        let mut batch = Vec::with_capacity(take);
        for _ in 0..take {
            match self.peek() {
                Some((_, seq)) => {
                    self.queue.pop();
                    batch.push(self.by_seq.remove(&seq).unwrap());
                }
                None => break,
            }
        }
        if batch.is_empty() {
            None
        } else {
            Some(batch)
        }
    }

    fn on_batch_complete(&mut self, batch: &[Request], _batch_ms: f64, _now: Micros) {
        for r in batch {
            self.exec_mean.push(r.exec_ms);
        }
    }

    fn drain_dropped(&mut self) -> Vec<(Request, Outcome)> {
        std::mem::take(&mut self.dropped)
    }

    fn wake_hint(&self, _now: Micros) -> Option<Micros> {
        self.queue.peek().map(|Reverse((d, _))| *d)
    }

    fn pending(&self) -> usize {
        self.by_seq.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::ms_to_us;
    use crate::core::batchmodel::BatchCostModel;
    use crate::core::request::AppId;

    #[test]
    fn serves_in_deadline_order() {
        let cfg = SchedulerConfig {
            cost_model: BatchCostModel::new(0.0, 1.0),
            ..Default::default()
        };
        let mut s = EdfScheduler::new(cfg, 0);
        s.seed_exec_mean(5.0);
        s.on_arrival(Request::new(1, AppId(0), 0, ms_to_us(300.0), 5.0), 0);
        s.on_arrival(Request::new(2, AppId(0), 0, ms_to_us(100.0), 5.0), 0);
        let b = s.next_batch(0).unwrap();
        assert_eq!(b[0].id.0, 2);
    }
}
