//! Plain earliest-deadline-first max-batch policy — an ablation baseline
//! (not in the paper's comparison set) isolating how much of Orloj's win
//! comes from the distribution-aware score versus simply being
//! deadline-aware and work-conserving. Batches are model-pure: the head's
//! model is served, later-deadline requests of other co-located models
//! wait for their own batch.

use crate::clock::{us_to_ms, Micros};
use crate::core::request::{ModelId, Outcome, Request};
use crate::scheduler::{drain_edf_model, ModelPending, Scheduler, SchedulerConfig};
use crate::util::stats::Welford;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

pub struct EdfScheduler {
    cfg: SchedulerConfig,
    queue: BinaryHeap<Reverse<(Micros, u64)>>,
    by_seq: HashMap<u64, Request>,
    dropped: Vec<(Request, Outcome)>,
    exec_mean: Welford,
    per_model: ModelPending,
}

impl EdfScheduler {
    pub fn new(cfg: SchedulerConfig, _seed: u64) -> Self {
        EdfScheduler {
            cfg,
            queue: BinaryHeap::new(),
            by_seq: HashMap::new(),
            dropped: Vec::new(),
            exec_mean: Welford::new(),
            per_model: ModelPending::new(),
        }
    }

    pub fn seed_exec_mean(&mut self, ms: f64) {
        self.exec_mean.push(ms);
    }

    fn est(&self, bs: usize) -> f64 {
        let exec = if self.exec_mean.count() > 0 {
            self.exec_mean.mean()
        } else {
            10.0
        };
        self.cfg.cost_model.latency(bs, exec)
    }

    fn peek(&mut self) -> Option<(Micros, u64)> {
        while let Some(&Reverse((d, seq))) = self.queue.peek() {
            if self.by_seq.contains_key(&seq) {
                return Some((d, seq));
            }
            self.queue.pop();
        }
        None
    }
}

impl Scheduler for EdfScheduler {
    fn name(&self) -> &'static str {
        "edf"
    }

    fn seed_app_profile(
        &mut self,
        _model: ModelId,
        _app: crate::core::request::AppId,
        hist: &crate::core::histogram::Histogram,
        _weight: u64,
    ) {
        self.exec_mean.push(hist.mean());
    }

    fn on_arrival(&mut self, req: Request, now: Micros) {
        if req.expired(now) {
            self.dropped.push((req, Outcome::TimedOut));
            return;
        }
        self.queue.push(Reverse((req.deadline, req.id.0)));
        self.per_model.inc(req.model);
        self.by_seq.insert(req.id.0, req);
    }

    fn next_batch(&mut self, now: Micros) -> Option<Vec<Request>> {
        // Drop heads that can't make it even solo.
        while let Some((d, seq)) = self.peek() {
            if us_to_ms(now) + self.est(1) > us_to_ms(d) {
                let r = self.by_seq.remove(&seq).unwrap();
                self.queue.pop();
                self.per_model.dec(r.model);
                self.dropped.push((r, Outcome::TimedOut));
            } else {
                break;
            }
        }
        let (head_deadline, head_seq) = self.peek()?;
        let model = self.by_seq[&head_seq].model;
        let slack = us_to_ms(head_deadline) - us_to_ms(now);
        let mut bs = 1usize;
        for &cand in &self.cfg.batch_sizes {
            if self.est(cand) <= slack && cand > bs {
                bs = cand;
            }
        }
        // Model-pure fill: take the head's model in deadline order,
        // re-queueing other models' requests untouched.
        let take = bs.min(self.per_model.get(model).max(1));
        let batch = drain_edf_model(
            &mut self.queue,
            &mut self.by_seq,
            &mut self.per_model,
            model,
            take,
        );
        if batch.is_empty() {
            None
        } else {
            Some(batch)
        }
    }

    fn on_batch_complete(&mut self, batch: &[Request], _batch_ms: f64, _now: Micros) {
        for r in batch {
            self.exec_mean.push(r.exec_ms);
        }
    }

    fn drain_dropped(&mut self) -> Vec<(Request, Outcome)> {
        std::mem::take(&mut self.dropped)
    }

    fn wake_hint(&self, _now: Micros) -> Option<Micros> {
        self.queue.peek().map(|Reverse((d, _))| *d)
    }

    fn pending(&self) -> usize {
        self.by_seq.len()
    }

    fn pending_for(&self, model: ModelId) -> usize {
        self.per_model.get(model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::ms_to_us;
    use crate::core::batchmodel::BatchCostModel;
    use crate::core::request::AppId;

    fn sched() -> EdfScheduler {
        let cfg = SchedulerConfig {
            cost_model: BatchCostModel::new(0.0, 1.0),
            ..Default::default()
        };
        let mut s = EdfScheduler::new(cfg, 0);
        s.seed_exec_mean(5.0);
        s
    }

    #[test]
    fn serves_in_deadline_order() {
        let mut s = sched();
        s.on_arrival(Request::new(1, AppId(0), 0, ms_to_us(300.0), 5.0), 0);
        s.on_arrival(Request::new(2, AppId(0), 0, ms_to_us(100.0), 5.0), 0);
        let b = s.next_batch(0).unwrap();
        assert_eq!(b[0].id.0, 2);
    }

    #[test]
    fn batches_never_mix_models() {
        let mut s = sched();
        // Interleaved deadlines across two models.
        for i in 0..6u64 {
            let m = ModelId((i % 2) as u32);
            s.on_arrival(
                Request::new(i, AppId(0), 0, ms_to_us(100.0 + i as f64), 5.0).with_model(m),
                0,
            );
        }
        assert_eq!(s.pending_for(ModelId(0)), 3);
        assert_eq!(s.pending_for(ModelId(1)), 3);
        let b = s.next_batch(0).unwrap();
        assert!(b.iter().all(|r| r.model == b[0].model), "model-pure batch");
        assert_eq!(b[0].model, ModelId(0), "head's model served first");
        assert_eq!(b.len(), 3);
        // The other model's requests are still queued, in order.
        assert_eq!(s.pending(), 3);
        assert_eq!(s.pending_for(ModelId(1)), 3);
        let b2 = s.next_batch(0).unwrap();
        assert_eq!(b2.len(), 3);
        assert!(b2.iter().all(|r| r.model == ModelId(1)));
    }
}
