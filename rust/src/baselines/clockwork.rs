//! Clockwork-style plan-ahead scheduler (Gujarati et al., OSDI'20; §2.3).
//!
//! Clockwork's premise is *predictability from the bottom up*: every batch
//! size has a profiled, near-deterministic latency, and the central
//! controller plans execution windows against those point estimates,
//! rejecting work that would miss its window. With static DNNs the
//! estimates are essentially exact and the approach excels. With dynamic
//! DNNs the point estimate mispredicts most batches; an overrunning batch
//! blows its window and "caus[es] the subsequent batch to fail" (§2.3) —
//! the planned slot for the next batch has already passed when the GPU
//! frees, so its requests are aborted. That misfire-every-other-batch
//! pattern is why Clockwork pins to ≈0.5 finish rate on dynamic workloads
//! regardless of the distribution's shape (paper Fig. 8–10).
//!
//! The policy here reproduces that control loop: EDF admission against
//! point estimates, largest batch that fits the earliest deadline, strict
//! window accounting, abort of the batch planned into a blown window.

use crate::clock::{us_to_ms, Micros};
use crate::core::request::{ModelId, Outcome, Request};
use crate::scheduler::{BatchPrediction, EdfQueues, Scheduler, SchedulerConfig};

pub struct ClockworkScheduler {
    cfg: SchedulerConfig,
    /// Per-model EDF lanes carrying the requests inline (§Perf: no
    /// id→request hash map, window fills are O(batch)).
    queue: EdfQueues,
    dropped: Vec<(Request, Outcome)>,
    /// Point estimate of the solo execution time (ms). Clockwork profiles
    /// once offline; we keep a slowly-converging estimate of the mean to
    /// mirror its calibration runs.
    exec_point_ms: f64,
    calibrated: bool,
    /// The window promised to the currently executing batch: planned
    /// completion time.
    window_end: Option<Micros>,
    /// Tolerance before declaring an overrun (fraction of the estimate).
    overrun_tol: f64,
    /// True when the previous batch blew its window: the next planned
    /// batch fails.
    misfire: bool,
    /// Point estimate used for the window most recently planned
    /// (telemetry; see `Scheduler::last_batch_prediction`).
    last_prediction: Option<BatchPrediction>,
}

impl ClockworkScheduler {
    pub fn new(cfg: SchedulerConfig, _seed: u64) -> Self {
        ClockworkScheduler {
            cfg,
            queue: EdfQueues::new(),
            dropped: Vec::new(),
            exec_point_ms: 10.0,
            calibrated: false,
            window_end: None,
            overrun_tol: 0.10,
            misfire: false,
            last_prediction: None,
        }
    }

    /// Install the offline profile (point estimate of solo exec, ms).
    pub fn seed_exec_point(&mut self, ms: f64) {
        self.exec_point_ms = ms;
        self.calibrated = true;
    }

    fn est(&self, bs: usize) -> f64 {
        self.cfg.cost_model.latency(bs, self.exec_point_ms)
    }

    /// Drop queue heads whose window can no longer be met even solo —
    /// the shed `next_batch` performs before planning a window.
    fn shed_hopeless(&mut self, now: Micros) {
        while let Some(head) = self.queue.peek() {
            if us_to_ms(now) + self.est(1) > us_to_ms(head.deadline) {
                let r = self.queue.pop_head().unwrap();
                self.dropped.push((r, Outcome::TimedOut));
            } else {
                break;
            }
        }
    }
}

impl Scheduler for ClockworkScheduler {
    fn name(&self) -> &'static str {
        "clockwork"
    }

    fn seed_app_profile(
        &mut self,
        _model: ModelId,
        _app: crate::core::request::AppId,
        hist: &crate::core::histogram::Histogram,
        _weight: u64,
    ) {
        // Clockwork profiles a point estimate per model. Multiple apps
        // blend into one number — precisely its limitation on dynamic DNNs.
        let m = hist.mean();
        self.exec_point_ms = if self.calibrated {
            0.5 * self.exec_point_ms + 0.5 * m
        } else {
            m
        };
        self.calibrated = true;
    }

    fn on_arrival(&mut self, req: Request, now: Micros) {
        // Admission control: reject requests that cannot meet their SLO
        // even at batch size 1 under the point estimate.
        if us_to_ms(now) + self.est(1) > us_to_ms(req.deadline) {
            self.dropped.push((req, Outcome::TimedOut));
            return;
        }
        self.queue.push(req);
    }

    fn install_model(&mut self, model: ModelId, _cold_start_ms: f64, _now: Micros) {
        // Clockwork's point estimate is per-model-fleet and offline; the
        // cold start is outside its model (precisely its §2.3 blind
        // spot), so only the queue state is created.
        self.queue.ensure_lane(model);
    }

    fn evict_model(&mut self, model: ModelId) -> Vec<Request> {
        self.queue.remove_lane(model)
    }

    fn reap(&mut self, now: Micros) {
        self.shed_hopeless(now);
    }

    fn next_batch(&mut self, now: Micros) -> Option<Vec<Request>> {
        // Drop requests whose window can no longer be met.
        self.shed_hopeless(now);
        let head = self.queue.peek()?;
        let (model, head_deadline) = (head.model, head.deadline);
        let slack_ms = us_to_ms(head_deadline) - us_to_ms(now);
        // Largest batch size whose estimated window fits the head's slack.
        let mut bs = 1usize;
        for &cand in &self.cfg.batch_sizes {
            if self.est(cand) <= slack_ms && cand > bs {
                bs = cand;
            }
        }
        // EDF fill restricted to the head's model (a planned window
        // executes exactly one model); other models' lanes are untouched.
        let take = bs.min(self.queue.pending_for(model).max(1));
        let batch = self.queue.drain_model(model, take);
        if batch.is_empty() {
            return None;
        }
        if self.misfire {
            // The slot this batch was planned into has already been blown
            // by the previous overrun: it fails (§2.3).
            self.misfire = false;
            for r in batch {
                self.dropped.push((r, Outcome::Aborted));
            }
            return None;
        }
        let est = self.est(batch.len());
        self.window_end = Some(now + crate::clock::ms_to_us(est * (1.0 + self.overrun_tol)));
        // Clockwork believes the point estimate is near-exact: its band is
        // exactly the overrun tolerance around the planned window.
        self.last_prediction = Some(BatchPrediction::point(est, self.overrun_tol));
        Some(batch)
    }

    fn on_batch_complete(&mut self, batch: &[Request], _batch_ms: f64, now: Micros) {
        if let Some(end) = self.window_end.take() {
            if now > end {
                self.misfire = true;
            }
        }
        // Calibration: converge the point estimate slowly (profiling runs).
        if !self.calibrated {
            for r in batch {
                self.exec_point_ms = 0.9 * self.exec_point_ms + 0.1 * r.exec_ms;
            }
        }
    }

    fn drain_dropped(&mut self) -> Vec<(Request, Outcome)> {
        std::mem::take(&mut self.dropped)
    }

    fn wake_hint(&self, _now: Micros) -> Option<Micros> {
        self.queue.min_deadline()
    }

    fn earliest_deadline(&self) -> Option<Micros> {
        self.queue.min_deadline()
    }

    fn pending(&self) -> usize {
        self.queue.len()
    }

    fn pending_for(&self, model: ModelId) -> usize {
        self.queue.pending_for(model)
    }

    fn backlog_estimate(&mut self, model: ModelId) -> f64 {
        // Plan-ahead drain time: queued windows at the max batch size,
        // each costing the profiled point estimate.
        let n = self.queue.pending_for(model);
        if n == 0 {
            return 0.0;
        }
        let bs = *self.cfg.batch_sizes.iter().max().unwrap_or(&1);
        n.div_ceil(bs) as f64 * self.est(bs)
    }

    fn last_batch_prediction(&self) -> Option<BatchPrediction> {
        self.last_prediction
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::ms_to_us;
    use crate::core::batchmodel::BatchCostModel;
    use crate::core::request::AppId;

    fn cfg() -> SchedulerConfig {
        SchedulerConfig {
            cost_model: BatchCostModel::new(0.0, 1.0),
            batch_sizes: vec![1, 2, 4],
            ..Default::default()
        }
    }

    fn req(id: u64, release: Micros, slo_ms: f64, exec_ms: f64) -> Request {
        Request::new(id, AppId(0), release, ms_to_us(slo_ms), exec_ms)
    }

    fn seeded() -> ClockworkScheduler {
        let mut s = ClockworkScheduler::new(cfg(), 0);
        s.seed_exec_point(10.0);
        s
    }

    #[test]
    fn edf_order_and_batch_fit() {
        let mut s = seeded();
        s.on_arrival(req(1, 0, 500.0, 10.0), 0);
        s.on_arrival(req(2, 0, 50.0, 10.0), 0);
        s.on_arrival(req(3, 0, 200.0, 10.0), 0);
        // Head slack 50ms → est(4)=40 fits → bs 4, take 3.
        let b = s.next_batch(0).unwrap();
        assert_eq!(b.len(), 3);
        assert_eq!(b[0].id.0, 2, "EDF head first");
    }

    #[test]
    fn admission_control_rejects_impossible() {
        let mut s = seeded();
        s.on_arrival(req(1, 0, 5.0, 10.0), 0); // est(1)=10 > 5
        assert_eq!(s.pending(), 0);
        assert_eq!(s.drain_dropped().len(), 1);
    }

    #[test]
    fn overrun_aborts_next_batch() {
        let mut s = seeded();
        for i in 0..8 {
            s.on_arrival(req(i, 0, 10_000.0, 10.0), 0);
        }
        let b1 = s.next_batch(0).unwrap();
        assert_eq!(b1.len(), 4);
        let est = s.est(b1.len());
        // Batch takes 3× its estimate → window blown.
        let done = ms_to_us(est * 3.0);
        s.on_batch_complete(&b1, est * 3.0, done);
        // Next planned batch is aborted.
        assert!(s.next_batch(done).is_none());
        let d = s.drain_dropped();
        assert!(!d.is_empty());
        assert!(d.iter().all(|(_, o)| *o == Outcome::Aborted));
    }

    #[test]
    fn windows_are_model_pure() {
        let mut s = seeded();
        for i in 0..6 {
            let m = ModelId((i % 2) as u32);
            s.on_arrival(req(i, 0, 500.0, 10.0).with_model(m), 0);
        }
        let b = s.next_batch(0).unwrap();
        assert!(b.iter().all(|r| r.model == b[0].model));
        assert_eq!(b.len(), 3, "only the head's model fills the window");
        assert_eq!(s.pending(), 3);
        let other = if b[0].model == ModelId(0) {
            ModelId(1)
        } else {
            ModelId(0)
        };
        assert_eq!(s.pending_for(other), 3);
    }

    #[test]
    fn on_time_completion_keeps_planning() {
        let mut s = seeded();
        for i in 0..8 {
            s.on_arrival(req(i, 0, 10_000.0, 10.0), 0);
        }
        let b1 = s.next_batch(0).unwrap();
        let est = s.est(b1.len());
        let done = ms_to_us(est * 0.99);
        s.on_batch_complete(&b1, est * 0.99, done);
        let b2 = s.next_batch(done);
        assert!(b2.is_some(), "no misfire on accurate prediction");
    }
}
