//! Clipper-style reactive scheduler (Crankshaw et al., NSDI'17; paper §2.3).
//!
//! Clipper has no plan-ahead: it serves FIFO with an *adaptively tuned*
//! batch size. The adaptive batching controller is an AIMD loop on the
//! measured batch latency versus the SLO budget (Clipper's actual design:
//! explore batch size upward until latency violates the objective, then
//! back off multiplicatively). Clipper has no deadline awareness inside
//! the batching queue: requests are served FIFO even when already late
//! (lateness only shows up in the finish-rate metric; only hopelessly old
//! entries are shed as overflow protection). That is exactly why its
//! finish rate collapses under tight SLOs on high-variance workloads in
//! the paper's §2.3/§5 experiments: by the time the measured latency
//! reacts, the queue is full of doomed requests.

use crate::clock::{us_to_ms, Micros};
use crate::core::request::{ModelId, Outcome, Request};
use crate::scheduler::{BatchPrediction, FifoQueues, Scheduler, SchedulerConfig};

pub struct ClipperScheduler {
    cfg: SchedulerConfig,
    /// Per-model FIFO lanes sharing one arrival order (§Perf: model-pure
    /// batch fills are O(batch) pops, not O(n) scans).
    queue: FifoQueues,
    dropped: Vec<(Request, Outcome)>,
    /// Current AIMD batch-size target (float so additive increase is
    /// fractional and robust).
    target: f64,
    /// Exponentially weighted p99-ish latency tracker (max-decay).
    lat_track: f64,
    /// Mean observed SLO (budget reference), EWMA.
    slo_track_ms: f64,
    /// Controller's latency belief at the last batch formation
    /// (telemetry; see `Scheduler::last_batch_prediction`).
    last_prediction: Option<BatchPrediction>,
}

impl ClipperScheduler {
    pub fn new(cfg: SchedulerConfig, _seed: u64) -> Self {
        ClipperScheduler {
            cfg,
            queue: FifoQueues::new(),
            dropped: Vec::new(),
            target: 1.0,
            lat_track: 0.0,
            slo_track_ms: 0.0,
            last_prediction: None,
        }
    }

    fn max_bs(&self) -> usize {
        *self.cfg.batch_sizes.iter().max().unwrap_or(&1)
    }

    /// Shed only requests that are *hopelessly* late (one full SLO past
    /// their deadline) — queue-overflow protection, not deadline awareness.
    fn drop_expired(&mut self, now: Micros) {
        while let Some(front) = self.queue.front() {
            if now > front.deadline + front.slo() {
                let r = self.queue.pop_front().unwrap();
                self.dropped.push((r, Outcome::TimedOut));
            } else {
                break;
            }
        }
    }
}

impl Scheduler for ClipperScheduler {
    fn name(&self) -> &'static str {
        "clipper"
    }

    fn install_model(&mut self, model: ModelId, _cold_start_ms: f64, _now: Micros) {
        // Reactive system: no plan-ahead to charge the cold start into;
        // the AIMD controller reacts to the slow first batch on its own.
        self.queue.ensure_lane(model);
    }

    fn evict_model(&mut self, model: ModelId) -> Vec<Request> {
        self.queue.remove_lane(model)
    }

    fn reap(&mut self, now: Micros) {
        // Exactly the next_batch-top shed: hopelessly-old front entries.
        self.drop_expired(now);
    }

    fn on_arrival(&mut self, req: Request, now: Micros) {
        if req.expired(now) {
            self.dropped.push((req, Outcome::TimedOut));
            return;
        }
        if self.slo_track_ms == 0.0 {
            self.slo_track_ms = us_to_ms(req.slo());
        } else {
            self.slo_track_ms = 0.95 * self.slo_track_ms + 0.05 * us_to_ms(req.slo());
        }
        self.queue.push(req);
    }

    fn next_batch(&mut self, now: Micros) -> Option<Vec<Request>> {
        self.drop_expired(now);
        let model = self.queue.front()?.model;
        let want = (self.target.floor() as usize).clamp(1, self.max_bs());
        // FIFO within the head's model: other co-located models keep their
        // queue positions (a batch executes exactly one model).
        let take = want.min(self.queue.pending_for(model).max(1));
        // Clipper's only latency belief is the controller's decaying-max
        // tracker — record it as the formation-time prediction (wide ±50%
        // band: a reactive point estimate carries no distribution).
        self.last_prediction = Some(BatchPrediction::point(self.lat_track, 0.5));
        Some(self.queue.drain_model(model, take))
    }

    fn on_batch_complete(&mut self, _batch: &[Request], batch_ms: f64, _now: Micros) {
        // Latency tracker: decaying max (approximates the p99 Clipper's
        // controller uses).
        self.lat_track = (self.lat_track * 0.95).max(batch_ms);
        let budget = self.slo_track_ms.max(1e-3);
        if self.lat_track > budget {
            // Multiplicative decrease.
            self.target = (self.target * 0.5).max(1.0);
        } else {
            // Additive increase.
            self.target = (self.target + 1.0).min(self.max_bs() as f64);
        }
    }

    fn drain_dropped(&mut self) -> Vec<(Request, Outcome)> {
        std::mem::take(&mut self.dropped)
    }

    fn wake_hint(&self, _now: Micros) -> Option<Micros> {
        self.queue.front().map(|r| r.deadline)
    }

    fn earliest_deadline(&self) -> Option<Micros> {
        // FIFO discipline: the head is the request this policy acts on
        // next, so its deadline bounds the useful idle advance.
        self.queue.front().map(|r| r.deadline)
    }

    fn pending(&self) -> usize {
        self.queue.len()
    }

    fn pending_for(&self, model: ModelId) -> usize {
        self.queue.pending_for(model)
    }

    fn backlog_estimate(&mut self, model: ModelId) -> f64 {
        // Drain time under the controller's own beliefs: the queued lane
        // served in AIMD-target-sized batches, each costing the decaying-
        // max latency tracker (cost-model fallback before the first batch
        // lands).
        let n = self.queue.pending_for(model);
        if n == 0 {
            return 0.0;
        }
        let bs = (self.target.floor() as usize).clamp(1, self.max_bs());
        let per_batch = if self.lat_track > 0.0 {
            self.lat_track
        } else {
            self.cfg.cost_model.latency(bs, 10.0)
        };
        n.div_ceil(bs) as f64 * per_batch
    }

    fn last_batch_prediction(&self) -> Option<BatchPrediction> {
        self.last_prediction
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::ms_to_us;
    use crate::core::request::AppId;

    fn req(id: u64, release: Micros, slo_ms: f64) -> Request {
        Request::new(id, AppId(0), release, ms_to_us(slo_ms), 10.0)
    }

    #[test]
    fn fifo_order() {
        let mut s = ClipperScheduler::new(SchedulerConfig::default(), 0);
        s.target = 4.0;
        for i in 0..4 {
            s.on_arrival(req(i, i * 10, 1000.0), i * 10);
        }
        let b = s.next_batch(100).unwrap();
        assert_eq!(b.iter().map(|r| r.id.0).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn aimd_backoff_and_growth() {
        let mut s = ClipperScheduler::new(SchedulerConfig::default(), 0);
        s.on_arrival(req(0, 0, 100.0), 0); // SLO 100 ms
        let t0 = s.target;
        // Fast batches → grow.
        for _ in 0..5 {
            s.on_batch_complete(&[], 10.0, 0);
        }
        assert!(s.target > t0);
        let grown = s.target;
        // One slow batch above budget → halve.
        s.on_batch_complete(&[], 500.0, 0);
        assert!(s.target < grown);
    }

    #[test]
    fn late_requests_still_served_fifo() {
        // Clipper has no deadline awareness: a request past its deadline
        // is still served (and will count as Late), it is only shed once
        // hopelessly old.
        let mut s = ClipperScheduler::new(SchedulerConfig::default(), 0);
        s.target = 4.0;
        s.on_arrival(req(0, 0, 5.0), 0);
        s.on_arrival(req(1, 0, 1000.0), 0);
        let b = s.next_batch(ms_to_us(8.0)).unwrap();
        assert_eq!(b.len(), 2, "late head still batched");
        assert_eq!(b[0].id.0, 0);
    }

    #[test]
    fn hopeless_requests_shed() {
        let mut s = ClipperScheduler::new(SchedulerConfig::default(), 0);
        s.on_arrival(req(0, 0, 5.0), 0);
        s.on_arrival(req(1, 0, 1000.0), 0);
        // 0 is > 2×SLO past release → shed at dequeue.
        let b = s.next_batch(ms_to_us(11.0)).unwrap();
        assert_eq!(b.len(), 1);
        assert_eq!(b[0].id.0, 1);
        let d = s.drain_dropped();
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].1, Outcome::TimedOut);
    }

    #[test]
    fn model_pure_fifo_batches() {
        let mut s = ClipperScheduler::new(SchedulerConfig::default(), 0);
        s.target = 4.0;
        for i in 0..6 {
            let m = ModelId((i % 2) as u32);
            s.on_arrival(req(i, 0, 1000.0).with_model(m), 0);
        }
        let b = s.next_batch(0).unwrap();
        // Head is model 0; its three requests batch together in FIFO order.
        assert_eq!(b.iter().map(|r| r.id.0).collect::<Vec<_>>(), vec![0, 2, 4]);
        assert!(b.iter().all(|r| r.model == ModelId(0)));
        assert_eq!(s.pending(), 3);
        assert_eq!(s.pending_for(ModelId(1)), 3);
        let b2 = s.next_batch(0).unwrap();
        assert!(b2.iter().all(|r| r.model == ModelId(1)));
    }

    #[test]
    fn evict_drains_fifo_and_reap_sheds_hopeless_front() {
        let mut s = ClipperScheduler::new(SchedulerConfig::default(), 0);
        s.on_arrival(req(0, 0, 5.0), 0);
        for i in 1..4 {
            let m = ModelId((i % 2) as u32);
            s.on_arrival(req(i, 0, 1000.0).with_model(m), 0);
        }
        // Evicting model 1 drains its lane in arrival order.
        let drained = s.evict_model(ModelId(1));
        assert_eq!(drained.iter().map(|r| r.id.0).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(s.pending(), 2);
        assert_eq!(s.pending_for(ModelId(1)), 0);
        // Reap sheds only the hopelessly-old front (id 0: >2×SLO past
        // release at 11 ms), exactly like the next_batch-top shed.
        s.reap(ms_to_us(11.0));
        let d = s.drain_dropped();
        assert_eq!(d.len(), 1);
        assert_eq!(d[0].0.id.0, 0);
        assert_eq!(s.pending(), 1);
        // install_model pre-creates an empty lane (no-op for counts).
        s.install_model(ModelId(5), 0.0, 0);
        assert_eq!(s.pending_for(ModelId(5)), 0);
    }

    #[test]
    fn batch_capped_by_target() {
        let mut s = ClipperScheduler::new(SchedulerConfig::default(), 0);
        for i in 0..20 {
            s.on_arrival(req(i, 0, 1000.0), 0);
        }
        let b = s.next_batch(0).unwrap();
        assert_eq!(b.len(), 1, "initial target is 1");
        assert_eq!(s.pending(), 19);
    }
}
