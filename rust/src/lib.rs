//! # Orloj — predictably serving unpredictable DNNs
//!
//! A reproduction of *"Orloj: Predictably Serving Unpredictable DNNs"*
//! (Yu, Qiu, Chowdhury, Jin — 2022) as a three-layer Rust + JAX + Pallas
//! serving stack:
//!
//! * **L3 (this crate)**: the distribution-aware batch scheduler — the
//!   paper's contribution — plus the baselines it is evaluated against
//!   (Clipper / Nexus / Clockwork-style policies), workload generators, a
//!   discrete-event evaluation harness, and a threaded serving runtime.
//! * **L2/L1 (`python/compile/`)**: an early-exit transformer (JAX) whose
//!   block hot path is a Pallas kernel; AOT-lowered per (depth, batch)
//!   variant to HLO text at build time.
//! * **Runtime (`runtime`)**: loads the AOT artifacts via the PJRT C API
//!   (`xla` crate) and executes batches on the request path — Python is
//!   never on the request path.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod baselines;
pub mod clock;
pub mod core;
pub mod ds;
pub mod experiments;
/// The PJRT execution path needs the `xla` FFI crate (not available in
/// the offline build) — see the `pjrt` feature in Cargo.toml.
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod scheduler;
pub mod serve;
pub mod server;
pub mod sim;
pub mod telemetry;
pub mod util;
pub mod workload;

pub use crate::clock::{Clock, Micros, RealClock, VirtualClock};
pub use crate::core::request::{AppId, Completion, ModelId, Outcome, Request, RequestId};
