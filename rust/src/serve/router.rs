//! Routers: the cluster's admission front-end (DESIGN.md §3).
//!
//! A [`Router`] picks the replica that will serve each arrival. The
//! contract:
//!
//! * `route` is called once per arrival, before the request is handed to
//!   any scheduler, with the *model-constrained candidate set*: a load
//!   snapshot covering every replica hosting the request's model
//!   (`loads.len() >= 1`; `loads[i].worker` is the replica id, which is
//!   not necessarily `i` under a non-trivial placement).
//! * It must return an index `< loads.len()` into the candidate set; the
//!   core dispatches to `loads[i].worker`. Routing is final — the core
//!   does not migrate queued requests between replicas (the paper's
//!   per-replica scheduler owns its queue).
//! * Routers may keep internal state (`&mut self`) but must be
//!   deterministic given the same call sequence, so simulated runs stay
//!   replayable.
//! * Load ties are broken by *rotation*, not by lowest id — always
//!   picking the first minimum herds every equal-load arrival burst onto
//!   worker 0 (all loads are equal at startup).

use super::WorkerLoad;
use crate::core::request::{ModelId, Request};

/// Replica-selection policy for arrivals.
pub trait Router: Send {
    fn name(&self) -> &'static str;

    /// Pick the candidate index for `req` given the current per-replica
    /// load of every replica hosting `req.model`.
    fn route(&mut self, req: &Request, loads: &[WorkerLoad]) -> usize;

    /// Whether this router's decisions depend only on the *candidate set*
    /// (its size and order) and the arrival sequence — never on the live
    /// load fields. Load-oblivious routers can be replayed by the sharded
    /// pump's coordinator before any scheduler state exists, which is what
    /// lets shards run without a barrier at every arrival (DESIGN.md §11).
    /// A router answering `true` here must not read `pending`,
    /// `pending_model` or `in_flight` in `route`.
    fn load_oblivious(&self) -> bool {
        false
    }
}

/// Among the candidates minimizing `key`, pick one on a rotating cursor
/// (round-robin across ties) and advance the cursor.
fn rotate_min(loads: &[WorkerLoad], rot: &mut usize, key: impl Fn(&WorkerLoad) -> usize) -> usize {
    let best = match loads.iter().map(&key).min() {
        Some(b) => b,
        None => return 0,
    };
    let ties = loads.iter().filter(|l| key(l) == best).count();
    let k = *rot % ties;
    *rot = rot.wrapping_add(1);
    loads
        .iter()
        .enumerate()
        .filter(|(_, l)| key(l) == best)
        .nth(k)
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Cycle through the candidate set in order, ignoring load. One cursor
/// per model: a shared cursor would let a cold model's small candidate
/// set disturb (or, reduced modulo its size, outright reset) the hot
/// model's rotation and starve high-index workers.
#[derive(Default)]
pub struct RoundRobin {
    cursors: Vec<(ModelId, usize)>,
}

impl RoundRobin {
    pub fn new() -> Self {
        RoundRobin::default()
    }
}

impl Router for RoundRobin {
    fn name(&self) -> &'static str {
        "round_robin"
    }

    fn route(&mut self, req: &Request, loads: &[WorkerLoad]) -> usize {
        let idx = match self.cursors.iter().position(|(m, _)| *m == req.model) {
            Some(i) => i,
            None => {
                self.cursors.push((req.model, 0));
                self.cursors.len() - 1
            }
        };
        let cursor = &mut self.cursors[idx].1;
        let i = *cursor % loads.len();
        *cursor = cursor.wrapping_add(1);
        i
    }

    fn load_oblivious(&self) -> bool {
        true
    }
}

/// Send to the candidate with the fewest queued requests *of the routed
/// request's model* (classic JSQ with per-model load accounting — equal
/// to total queued on single-model clusters; ties rotate).
#[derive(Default)]
pub struct JoinShortestQueue {
    rot: usize,
}

impl JoinShortestQueue {
    pub fn new() -> Self {
        JoinShortestQueue { rot: 0 }
    }
}

impl Router for JoinShortestQueue {
    fn name(&self) -> &'static str {
        "join_shortest_queue"
    }

    fn route(&mut self, _req: &Request, loads: &[WorkerLoad]) -> usize {
        rotate_min(loads, &mut self.rot, |l| l.pending_model)
    }
}

/// Send to the candidate with the least total work in the system — queued
/// plus in-flight batch size (ties rotate). Unlike JSQ this avoids piling
/// onto a replica that just emptied its queue into a large running batch.
#[derive(Default)]
pub struct LeastLoaded {
    rot: usize,
}

impl LeastLoaded {
    pub fn new() -> Self {
        LeastLoaded { rot: 0 }
    }
}

impl Router for LeastLoaded {
    fn name(&self) -> &'static str {
        "least_loaded"
    }

    fn route(&mut self, _req: &Request, loads: &[WorkerLoad]) -> usize {
        rotate_min(loads, &mut self.rot, |l| l.total())
    }
}

/// All router names, in documentation order.
pub const ROUTERS: [&str; 3] = ["round_robin", "least_loaded", "join_shortest_queue"];

/// Construct a router by name (short aliases accepted).
pub fn by_name(name: &str) -> Option<Box<dyn Router>> {
    match name {
        "round_robin" | "rr" => Some(Box::new(RoundRobin::new())),
        "least_loaded" | "ll" => Some(Box::new(LeastLoaded::new())),
        "join_shortest_queue" | "jsq" => Some(Box::new(JoinShortestQueue::new())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::request::AppId;

    fn req() -> Request {
        Request::new(0, AppId(0), 0, 1_000_000, 5.0)
    }

    fn loads(spec: &[(usize, usize)]) -> Vec<WorkerLoad> {
        spec.iter()
            .enumerate()
            .map(|(w, &(pending, in_flight))| WorkerLoad {
                worker: w,
                pending,
                pending_model: pending,
                in_flight,
            })
            .collect()
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = RoundRobin::new();
        let ls = loads(&[(0, 0), (9, 9), (0, 0)]);
        let picks: Vec<usize> = (0..6).map(|_| r.route(&req(), &ls)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn jsq_picks_shortest_queue_ignoring_inflight() {
        let mut r = JoinShortestQueue::new();
        // Worker 1 has the shortest queue even though it has a big batch
        // in flight.
        let ls = loads(&[(3, 0), (1, 16), (2, 0)]);
        assert_eq!(r.route(&req(), &ls), 1);
    }

    #[test]
    fn least_loaded_counts_inflight() {
        let mut r = LeastLoaded::new();
        // Worker 1's in-flight batch makes worker 2 the least loaded.
        let ls = loads(&[(3, 0), (1, 16), (2, 0)]);
        assert_eq!(r.route(&req(), &ls), 2);
    }

    #[test]
    fn ties_rotate_instead_of_herding() {
        // All-equal loads (the startup burst): successive picks must cycle
        // through the tied candidates, not herd onto index 0.
        let ls = loads(&[(2, 0), (2, 0), (2, 0)]);
        let mut jsq = JoinShortestQueue::new();
        let picks: Vec<usize> = (0..6).map(|_| jsq.route(&req(), &ls)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        let mut ll = LeastLoaded::new();
        let picks: Vec<usize> = (0..6).map(|_| ll.route(&req(), &ls)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn round_robin_cursor_survives_smaller_candidate_sets() {
        // A cold model's 1-candidate set must not disturb the hot model's
        // rotation (per-model cursors) — the skewed-placement pathology
        // where interleaved cold arrivals starved high-index workers of
        // hot-model traffic.
        let mut r = RoundRobin::new();
        let hot_req = req(); // model 0
        let cold_req = Request::new(1, AppId(0), 0, 1_000_000, 5.0).with_model(ModelId(1));
        let hot = loads(&[(0, 0), (0, 0), (0, 0), (0, 0)]);
        let cold = loads(&[(0, 0)]);
        let mut picks = Vec::new();
        for _ in 0..4 {
            picks.push(r.route(&hot_req, &hot));
            assert_eq!(r.route(&cold_req, &cold), 0);
        }
        assert_eq!(picks, vec![0, 1, 2, 3], "all four hot workers cycled");
    }

    #[test]
    fn jsq_keys_on_per_model_depth() {
        // Worker 0 has the shorter total queue but the longer queue for
        // the routed model; per-model JSQ prefers worker 1.
        let mut ls = loads(&[(2, 0), (5, 0)]);
        ls[0].pending_model = 2;
        ls[1].pending_model = 0;
        let mut r = JoinShortestQueue::new();
        assert_eq!(r.route(&req(), &ls), 1);
    }

    #[test]
    fn rotation_skips_non_tied_candidates() {
        // Only workers 0 and 2 are tied at the minimum; the rotation
        // alternates between them and never picks the loaded worker 1.
        let ls = loads(&[(1, 0), (5, 0), (1, 0)]);
        let mut jsq = JoinShortestQueue::new();
        let picks: Vec<usize> = (0..4).map(|_| jsq.route(&req(), &ls)).collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);
    }

    #[test]
    fn only_round_robin_is_load_oblivious() {
        // The sharded pump's coordinator replays load-oblivious routers
        // ahead of execution; a load-aware router claiming obliviousness
        // would silently change sharded routing decisions.
        assert!(by_name("round_robin").unwrap().load_oblivious());
        assert!(!by_name("least_loaded").unwrap().load_oblivious());
        assert!(!by_name("join_shortest_queue").unwrap().load_oblivious());
    }

    #[test]
    fn registry_resolves_names_and_aliases() {
        for name in ROUTERS {
            assert!(by_name(name).is_some(), "{name} missing from registry");
        }
        assert_eq!(by_name("rr").unwrap().name(), "round_robin");
        assert_eq!(by_name("jsq").unwrap().name(), "join_shortest_queue");
        assert_eq!(by_name("ll").unwrap().name(), "least_loaded");
        assert!(by_name("random").is_none());
    }
}
