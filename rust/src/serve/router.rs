//! Routers: the cluster's admission front-end (DESIGN.md §3).
//!
//! A [`Router`] picks the replica that will serve each arrival. The
//! contract:
//!
//! * `route` is called once per arrival, before the request is handed to
//!   any scheduler, with the *model-constrained candidate set*: a load
//!   snapshot covering every replica hosting the request's model
//!   (`loads.len() >= 1`; `loads[i].worker` is the replica id, which is
//!   not necessarily `i` under a non-trivial placement).
//! * It must return an index `< loads.len()` into the candidate set; the
//!   core dispatches to `loads[i].worker`. Routing is final — the core
//!   does not migrate queued requests between replicas (the paper's
//!   per-replica scheduler owns its queue).
//! * Routers may keep internal state (`&mut self`) but must be
//!   deterministic given the same call sequence, so simulated runs stay
//!   replayable.
//! * Load ties are broken by *rotation*, not by lowest id — always
//!   picking the first minimum herds every equal-load arrival burst onto
//!   worker 0 (all loads are equal at startup).

use std::sync::atomic::{AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use super::WorkerLoad;
use crate::core::request::{ModelId, Request};

/// Replica-selection policy for arrivals.
pub trait Router: Send {
    fn name(&self) -> &'static str;

    /// Pick the candidate index for `req` given the current per-replica
    /// load of every replica hosting `req.model`.
    fn route(&mut self, req: &Request, loads: &[WorkerLoad]) -> usize;

    /// Whether this router's decisions depend only on the *candidate set*
    /// (its size and order) and the arrival sequence — never on the live
    /// load fields. Load-oblivious routers can be replayed by the sharded
    /// pump's coordinator before any scheduler state exists, which is what
    /// lets shards run without a barrier at every arrival (DESIGN.md §11).
    /// A router answering `true` here must not read `pending`,
    /// `pending_model` or `in_flight` in `route`.
    fn load_oblivious(&self) -> bool {
        false
    }
}

/// Among the candidates minimizing `key`, pick one on a rotating cursor
/// (round-robin across ties) and advance the cursor.
fn rotate_min(loads: &[WorkerLoad], rot: &mut usize, key: impl Fn(&WorkerLoad) -> usize) -> usize {
    let best = match loads.iter().map(&key).min() {
        Some(b) => b,
        None => return 0,
    };
    let ties = loads.iter().filter(|l| key(l) == best).count();
    let k = *rot % ties;
    *rot = rot.wrapping_add(1);
    loads
        .iter()
        .enumerate()
        .filter(|(_, l)| key(l) == best)
        .nth(k)
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Cycle through the candidate set in order, ignoring load. One cursor
/// per model: a shared cursor would let a cold model's small candidate
/// set disturb (or, reduced modulo its size, outright reset) the hot
/// model's rotation and starve high-index workers.
#[derive(Default)]
pub struct RoundRobin {
    cursors: Vec<(ModelId, usize)>,
}

impl RoundRobin {
    pub fn new() -> Self {
        RoundRobin::default()
    }
}

impl Router for RoundRobin {
    fn name(&self) -> &'static str {
        "round_robin"
    }

    fn route(&mut self, req: &Request, loads: &[WorkerLoad]) -> usize {
        let idx = match self.cursors.iter().position(|(m, _)| *m == req.model) {
            Some(i) => i,
            None => {
                self.cursors.push((req.model, 0));
                self.cursors.len() - 1
            }
        };
        let cursor = &mut self.cursors[idx].1;
        let i = *cursor % loads.len();
        *cursor = cursor.wrapping_add(1);
        i
    }

    fn load_oblivious(&self) -> bool {
        true
    }
}

/// Send to the candidate with the fewest queued requests *of the routed
/// request's model* (classic JSQ with per-model load accounting — equal
/// to total queued on single-model clusters; ties rotate).
#[derive(Default)]
pub struct JoinShortestQueue {
    rot: usize,
}

impl JoinShortestQueue {
    pub fn new() -> Self {
        JoinShortestQueue { rot: 0 }
    }
}

impl Router for JoinShortestQueue {
    fn name(&self) -> &'static str {
        "join_shortest_queue"
    }

    fn route(&mut self, _req: &Request, loads: &[WorkerLoad]) -> usize {
        rotate_min(loads, &mut self.rot, |l| l.pending_model)
    }
}

/// Send to the candidate with the least total work in the system — queued
/// plus in-flight batch size (ties rotate). Unlike JSQ this avoids piling
/// onto a replica that just emptied its queue into a large running batch.
#[derive(Default)]
pub struct LeastLoaded {
    rot: usize,
}

impl LeastLoaded {
    pub fn new() -> Self {
        LeastLoaded { rot: 0 }
    }
}

impl Router for LeastLoaded {
    fn name(&self) -> &'static str {
        "least_loaded"
    }

    fn route(&mut self, _req: &Request, loads: &[WorkerLoad]) -> usize {
        rotate_min(loads, &mut self.rot, |l| l.total())
    }
}

/// All router names, in documentation order.
pub const ROUTERS: [&str; 3] = ["round_robin", "least_loaded", "join_shortest_queue"];

/// Construct a router by name (short aliases accepted).
pub fn by_name(name: &str) -> Option<Box<dyn Router>> {
    match name {
        "round_robin" | "rr" => Some(Box::new(RoundRobin::new())),
        "least_loaded" | "ll" => Some(Box::new(LeastLoaded::new())),
        "join_shortest_queue" | "jsq" => Some(Box::new(JoinShortestQueue::new())),
        _ => None,
    }
}

/// One replica's published load, padded to its own cache line so shards
/// publishing to adjacent replicas never false-share (same reasoning as
/// the ring's padded cursors, DESIGN.md §12).
#[repr(align(128))]
#[derive(Default)]
struct BoardSlot {
    /// Requests queued at the replica's scheduler (not yet in a batch).
    queued: AtomicU32,
    /// Requests inside the currently executing batch (0 when idle).
    inflight: AtomicU32,
    /// Estimated outstanding work in microseconds (queued + inflight
    /// scaled by the owning shard's exec-time EWMA).
    est_work_us: AtomicU64,
}

/// Lock-free per-replica load board for sharded routing (DESIGN.md §13).
///
/// Each scheduling shard *owns* a contiguous range of replicas and is the
/// only writer for their slots: it publishes authoritative snapshots after
/// every dispatch/completion sweep (`publish`). Any shard may read any
/// slot at route time (`queued`/`inflight`/`est_work_us`) — reads are
/// approximate by design, staleness is bounded by one sweep of the owning
/// shard. `note_routed` is the one cross-shard write: an optimistic
/// `queued += 1` so that a burst routed between two publishes of the
/// owner does not herd onto the same momentarily-idle replica; the next
/// authoritative `publish` overwrites it (overwrite, not reconcile — the
/// board is a hint, conservation never depends on it).
pub struct LoadBoard {
    slots: Box<[BoardSlot]>,
}

impl LoadBoard {
    pub fn new(workers: usize) -> Self {
        LoadBoard {
            slots: (0..workers).map(|_| BoardSlot::default()).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Authoritative snapshot write by the owning shard.
    pub fn publish(&self, worker: usize, queued: usize, inflight: usize, est_work_us: u64) {
        let s = &self.slots[worker];
        let clamp = |v: usize| v.min(u32::MAX as usize) as u32;
        s.queued.store(clamp(queued), Ordering::Release);
        s.inflight.store(clamp(inflight), Ordering::Release);
        s.est_work_us.store(est_work_us, Ordering::Release);
    }

    /// Optimistic bump between publishes; see the type-level contract.
    pub fn note_routed(&self, worker: usize) {
        self.slots[worker].queued.fetch_add(1, Ordering::AcqRel);
    }

    pub fn queued(&self, worker: usize) -> usize {
        self.slots[worker].queued.load(Ordering::Acquire) as usize
    }

    pub fn inflight(&self, worker: usize) -> usize {
        self.slots[worker].inflight.load(Ordering::Acquire) as usize
    }

    pub fn est_work_us(&self, worker: usize) -> u64 {
        self.slots[worker].est_work_us.load(Ordering::Acquire)
    }
}

/// Load-aware policies re-expressed against [`LoadBoard`] snapshots, for
/// routing decisions taken outside the replica-owning thread. Mirrors the
/// [`Router`] registry: every policy here has the same keying as its
/// sequential counterpart.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BoardPolicy {
    RoundRobin,
    LeastLoaded,
    JoinShortestQueue,
}

impl BoardPolicy {
    /// Map a [`Router::name`] onto its board-backed equivalent. `None`
    /// means the router has no lock-free re-implementation and the
    /// sharded pump must fall back to the sequential path.
    pub fn from_router_name(name: &str) -> Option<BoardPolicy> {
        match name {
            "round_robin" => Some(BoardPolicy::RoundRobin),
            "least_loaded" => Some(BoardPolicy::LeastLoaded),
            "join_shortest_queue" => Some(BoardPolicy::JoinShortestQueue),
            _ => None,
        }
    }
}

/// Shared, lock-free router for the sharded wall-clock pump: picks among
/// *global* worker ids by reading [`LoadBoard`] snapshots. Tie-breaking
/// rotates on one shared atomic cursor — approximate fairness (shards
/// race on the cursor) standing in for `rotate_min`'s exact rotation;
/// like the board itself this trades exactness for never blocking.
pub struct BoardRouter {
    board: Arc<LoadBoard>,
    policy: BoardPolicy,
    rot: AtomicUsize,
}

impl BoardRouter {
    pub fn new(board: Arc<LoadBoard>, policy: BoardPolicy) -> Self {
        BoardRouter {
            board,
            policy,
            rot: AtomicUsize::new(0),
        }
    }

    pub fn board(&self) -> &LoadBoard {
        &self.board
    }

    /// Pick a worker from `candidates` (global ids, all hosting the
    /// request's model). Allocation-free: two passes over the candidate
    /// slice. Returns the chosen *global* worker id.
    pub fn pick(&self, candidates: &[usize]) -> usize {
        debug_assert!(!candidates.is_empty());
        let key = |w: usize| -> usize {
            match self.policy {
                BoardPolicy::RoundRobin => 0,
                // LeastLoaded keys total work in the system, JSQ queue
                // depth only — same keys as the sequential routers (the
                // board has no per-model queue split; DESIGN.md §13).
                BoardPolicy::LeastLoaded => self.board.queued(w) + self.board.inflight(w),
                BoardPolicy::JoinShortestQueue => self.board.queued(w),
            }
        };
        if self.policy == BoardPolicy::RoundRobin {
            let k = self.rot.fetch_add(1, Ordering::Relaxed);
            return candidates[k % candidates.len()];
        }
        let best = candidates.iter().map(|&w| key(w)).min().unwrap_or(0);
        let ties = candidates.iter().filter(|&&w| key(w) == best).count();
        let k = self.rot.fetch_add(1, Ordering::Relaxed) % ties.max(1);
        candidates
            .iter()
            .copied()
            .filter(|&w| key(w) == best)
            .nth(k)
            .unwrap_or(candidates[0])
    }
}

/// Internal router for a scheduling shard's sub-core: the shard has
/// already picked the global worker via [`BoardRouter`], so the sub-core
/// must deliver to exactly that replica. The shard stores the *local*
/// replica id before pushing each arrival; `route` just finds it in the
/// candidate snapshot.
pub struct Pinned {
    target: Arc<AtomicUsize>,
}

impl Pinned {
    pub fn new(target: Arc<AtomicUsize>) -> Self {
        Pinned { target }
    }
}

impl Router for Pinned {
    fn name(&self) -> &'static str {
        "pinned"
    }

    fn route(&mut self, _req: &Request, loads: &[WorkerLoad]) -> usize {
        let t = self.target.load(Ordering::Acquire);
        loads.iter().position(|l| l.worker == t).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::request::AppId;

    fn req() -> Request {
        Request::new(0, AppId(0), 0, 1_000_000, 5.0)
    }

    fn loads(spec: &[(usize, usize)]) -> Vec<WorkerLoad> {
        spec.iter()
            .enumerate()
            .map(|(w, &(pending, in_flight))| WorkerLoad {
                worker: w,
                pending,
                pending_model: pending,
                in_flight,
            })
            .collect()
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = RoundRobin::new();
        let ls = loads(&[(0, 0), (9, 9), (0, 0)]);
        let picks: Vec<usize> = (0..6).map(|_| r.route(&req(), &ls)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn jsq_picks_shortest_queue_ignoring_inflight() {
        let mut r = JoinShortestQueue::new();
        // Worker 1 has the shortest queue even though it has a big batch
        // in flight.
        let ls = loads(&[(3, 0), (1, 16), (2, 0)]);
        assert_eq!(r.route(&req(), &ls), 1);
    }

    #[test]
    fn least_loaded_counts_inflight() {
        let mut r = LeastLoaded::new();
        // Worker 1's in-flight batch makes worker 2 the least loaded.
        let ls = loads(&[(3, 0), (1, 16), (2, 0)]);
        assert_eq!(r.route(&req(), &ls), 2);
    }

    #[test]
    fn ties_rotate_instead_of_herding() {
        // All-equal loads (the startup burst): successive picks must cycle
        // through the tied candidates, not herd onto index 0.
        let ls = loads(&[(2, 0), (2, 0), (2, 0)]);
        let mut jsq = JoinShortestQueue::new();
        let picks: Vec<usize> = (0..6).map(|_| jsq.route(&req(), &ls)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        let mut ll = LeastLoaded::new();
        let picks: Vec<usize> = (0..6).map(|_| ll.route(&req(), &ls)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn round_robin_cursor_survives_smaller_candidate_sets() {
        // A cold model's 1-candidate set must not disturb the hot model's
        // rotation (per-model cursors) — the skewed-placement pathology
        // where interleaved cold arrivals starved high-index workers of
        // hot-model traffic.
        let mut r = RoundRobin::new();
        let hot_req = req(); // model 0
        let cold_req = Request::new(1, AppId(0), 0, 1_000_000, 5.0).with_model(ModelId(1));
        let hot = loads(&[(0, 0), (0, 0), (0, 0), (0, 0)]);
        let cold = loads(&[(0, 0)]);
        let mut picks = Vec::new();
        for _ in 0..4 {
            picks.push(r.route(&hot_req, &hot));
            assert_eq!(r.route(&cold_req, &cold), 0);
        }
        assert_eq!(picks, vec![0, 1, 2, 3], "all four hot workers cycled");
    }

    #[test]
    fn jsq_keys_on_per_model_depth() {
        // Worker 0 has the shorter total queue but the longer queue for
        // the routed model; per-model JSQ prefers worker 1.
        let mut ls = loads(&[(2, 0), (5, 0)]);
        ls[0].pending_model = 2;
        ls[1].pending_model = 0;
        let mut r = JoinShortestQueue::new();
        assert_eq!(r.route(&req(), &ls), 1);
    }

    #[test]
    fn rotation_skips_non_tied_candidates() {
        // Only workers 0 and 2 are tied at the minimum; the rotation
        // alternates between them and never picks the loaded worker 1.
        let ls = loads(&[(1, 0), (5, 0), (1, 0)]);
        let mut jsq = JoinShortestQueue::new();
        let picks: Vec<usize> = (0..4).map(|_| jsq.route(&req(), &ls)).collect();
        assert_eq!(picks, vec![0, 2, 0, 2]);
    }

    #[test]
    fn only_round_robin_is_load_oblivious() {
        // The sharded pump's coordinator replays load-oblivious routers
        // ahead of execution; a load-aware router claiming obliviousness
        // would silently change sharded routing decisions.
        assert!(by_name("round_robin").unwrap().load_oblivious());
        assert!(!by_name("least_loaded").unwrap().load_oblivious());
        assert!(!by_name("join_shortest_queue").unwrap().load_oblivious());
    }

    #[test]
    fn registry_resolves_names_and_aliases() {
        for name in ROUTERS {
            assert!(by_name(name).is_some(), "{name} missing from registry");
        }
        assert_eq!(by_name("rr").unwrap().name(), "round_robin");
        assert_eq!(by_name("jsq").unwrap().name(), "join_shortest_queue");
        assert_eq!(by_name("ll").unwrap().name(), "least_loaded");
        assert!(by_name("random").is_none());
    }

    #[test]
    fn board_policy_covers_every_registered_router() {
        // Every name in the registry must either map onto a board policy
        // or the sharded pump knowingly falls back; today all three map.
        for name in ROUTERS {
            assert!(
                BoardPolicy::from_router_name(name).is_some(),
                "{name} has no board-backed equivalent"
            );
        }
        assert!(BoardPolicy::from_router_name("pinned").is_none());
    }

    #[test]
    fn board_router_keys_match_sequential_routers() {
        let board = Arc::new(LoadBoard::new(3));
        // worker 0: 3 queued; worker 1: 1 queued + 16 in flight;
        // worker 2: 2 queued — same scenario as the sequential tests.
        board.publish(0, 3, 0, 0);
        board.publish(1, 1, 16, 0);
        board.publish(2, 2, 0, 0);
        let jsq = BoardRouter::new(board.clone(), BoardPolicy::JoinShortestQueue);
        assert_eq!(jsq.pick(&[0, 1, 2]), 1, "JSQ ignores in-flight");
        let ll = BoardRouter::new(board, BoardPolicy::LeastLoaded);
        assert_eq!(ll.pick(&[0, 1, 2]), 2, "least-loaded counts in-flight");
    }

    #[test]
    fn board_router_ties_rotate_and_round_robin_cycles() {
        let board = Arc::new(LoadBoard::new(3));
        let rr = BoardRouter::new(board.clone(), BoardPolicy::RoundRobin);
        let picks: Vec<usize> = (0..6).map(|_| rr.pick(&[0, 1, 2])).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        // All-zero board: ties must cycle, not herd onto the first id.
        let ll = BoardRouter::new(board, BoardPolicy::LeastLoaded);
        let picks: Vec<usize> = (0..6).map(|_| ll.pick(&[0, 1, 2])).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn note_routed_bumps_until_next_publish_overwrites() {
        let board = Arc::new(LoadBoard::new(2));
        board.publish(0, 0, 0, 0);
        board.publish(1, 0, 0, 0);
        let ll = BoardRouter::new(board.clone(), BoardPolicy::LeastLoaded);
        // The optimistic bump steers the next pick away from worker 0...
        board.note_routed(0);
        assert_eq!(board.queued(0), 1);
        assert_eq!(ll.pick(&[0, 1]), 1);
        // ...and the owner's next authoritative publish overwrites it.
        board.publish(0, 0, 0, 0);
        assert_eq!(board.queued(0), 0);
    }

    #[test]
    fn pinned_router_finds_global_id_in_candidate_snapshot() {
        let target = Arc::new(AtomicUsize::new(2));
        let mut r = Pinned::new(target.clone());
        // Candidate set under a placement: global workers {1, 2, 5}.
        let mut ls = loads(&[(0, 0), (0, 0), (0, 0)]);
        ls[0].worker = 1;
        ls[1].worker = 2;
        ls[2].worker = 5;
        assert_eq!(r.route(&req(), &ls), 1, "global id 2 sits at index 1");
        target.store(5, Ordering::Release);
        assert_eq!(r.route(&req(), &ls), 2);
    }

    #[test]
    fn board_snapshot_roundtrip() {
        let board = LoadBoard::new(1);
        assert_eq!(board.len(), 1);
        assert!(!board.is_empty());
        board.publish(0, 7, 3, 12_500);
        assert_eq!(board.queued(0), 7);
        assert_eq!(board.inflight(0), 3);
        assert_eq!(board.est_work_us(0), 12_500);
    }
}
