//! Routers: the cluster's admission front-end (DESIGN.md §3).
//!
//! A [`Router`] picks the replica that will serve each arrival. The
//! contract:
//!
//! * `route` is called once per arrival, before the request is handed to
//!   any scheduler, with a load snapshot covering every replica
//!   (`loads.len() >= 1`, `loads[i].worker == i`).
//! * It must return a `WorkerId < loads.len()`. Routing is final — the
//!   core does not migrate queued requests between replicas (the paper's
//!   per-replica scheduler owns its queue).
//! * Routers may keep internal state (`&mut self`) but must be
//!   deterministic given the same call sequence, so simulated runs stay
//!   replayable.

use super::{WorkerId, WorkerLoad};
use crate::core::request::Request;

/// Replica-selection policy for arrivals.
pub trait Router: Send {
    fn name(&self) -> &'static str;

    /// Pick the replica for `req` given the current per-replica load.
    fn route(&mut self, req: &Request, loads: &[WorkerLoad]) -> WorkerId;
}

/// Cycle through replicas in order, ignoring load.
pub struct RoundRobin {
    next: usize,
}

impl RoundRobin {
    pub fn new() -> Self {
        RoundRobin { next: 0 }
    }
}

impl Default for RoundRobin {
    fn default() -> Self {
        Self::new()
    }
}

impl Router for RoundRobin {
    fn name(&self) -> &'static str {
        "round_robin"
    }

    fn route(&mut self, _req: &Request, loads: &[WorkerLoad]) -> WorkerId {
        let w = self.next % loads.len();
        self.next = (w + 1) % loads.len();
        w
    }
}

/// Send to the replica with the fewest *queued* requests (classic JSQ;
/// ties break toward the lower id).
pub struct JoinShortestQueue;

impl Router for JoinShortestQueue {
    fn name(&self) -> &'static str {
        "join_shortest_queue"
    }

    fn route(&mut self, _req: &Request, loads: &[WorkerLoad]) -> WorkerId {
        loads
            .iter()
            .min_by_key(|l| (l.pending, l.worker))
            .map(|l| l.worker)
            .unwrap_or(0)
    }
}

/// Send to the replica with the least total work in the system — queued
/// plus in-flight batch size (ties break toward the lower id). Unlike JSQ
/// this avoids piling onto a replica that just emptied its queue into a
/// large running batch.
pub struct LeastLoaded;

impl Router for LeastLoaded {
    fn name(&self) -> &'static str {
        "least_loaded"
    }

    fn route(&mut self, _req: &Request, loads: &[WorkerLoad]) -> WorkerId {
        loads
            .iter()
            .min_by_key(|l| (l.total(), l.worker))
            .map(|l| l.worker)
            .unwrap_or(0)
    }
}

/// All router names, in documentation order.
pub const ROUTERS: [&str; 3] = ["round_robin", "least_loaded", "join_shortest_queue"];

/// Construct a router by name (short aliases accepted).
pub fn by_name(name: &str) -> Option<Box<dyn Router>> {
    match name {
        "round_robin" | "rr" => Some(Box::new(RoundRobin::new())),
        "least_loaded" | "ll" => Some(Box::new(LeastLoaded)),
        "join_shortest_queue" | "jsq" => Some(Box::new(JoinShortestQueue)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::request::AppId;

    fn req() -> Request {
        Request::new(0, AppId(0), 0, 1_000_000, 5.0)
    }

    fn loads(spec: &[(usize, usize)]) -> Vec<WorkerLoad> {
        spec.iter()
            .enumerate()
            .map(|(w, &(pending, in_flight))| WorkerLoad {
                worker: w,
                pending,
                in_flight,
            })
            .collect()
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = RoundRobin::new();
        let ls = loads(&[(0, 0), (9, 9), (0, 0)]);
        let picks: Vec<usize> = (0..6).map(|_| r.route(&req(), &ls)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn jsq_picks_shortest_queue_ignoring_inflight() {
        let mut r = JoinShortestQueue;
        // Worker 1 has the shortest queue even though it has a big batch
        // in flight.
        let ls = loads(&[(3, 0), (1, 16), (2, 0)]);
        assert_eq!(r.route(&req(), &ls), 1);
    }

    #[test]
    fn least_loaded_counts_inflight() {
        let mut r = LeastLoaded;
        // Worker 1's in-flight batch makes worker 2 the least loaded.
        let ls = loads(&[(3, 0), (1, 16), (2, 0)]);
        assert_eq!(r.route(&req(), &ls), 2);
    }

    #[test]
    fn ties_break_to_lowest_id() {
        let mut jsq = JoinShortestQueue;
        let mut ll = LeastLoaded;
        let ls = loads(&[(2, 0), (2, 0), (2, 0)]);
        assert_eq!(jsq.route(&req(), &ls), 0);
        assert_eq!(ll.route(&req(), &ls), 0);
    }

    #[test]
    fn registry_resolves_names_and_aliases() {
        for name in ROUTERS {
            assert!(by_name(name).is_some(), "{name} missing from registry");
        }
        assert_eq!(by_name("rr").unwrap().name(), "round_robin");
        assert_eq!(by_name("jsq").unwrap().name(), "join_shortest_queue");
        assert_eq!(by_name("ll").unwrap().name(), "least_loaded");
        assert!(by_name("random").is_none());
    }
}
