//! Bounded lock-free MPSC ring — the arrival path from the ingress shards
//! into the serving core (DESIGN.md §12), vendored in-crate like every
//! other utility (the offline set has no crossbeam).
//!
//! A fixed-capacity Vyukov-style bounded queue: every cell carries a
//! sequence number, producers claim a slot with one CAS on the head
//! counter, and the single consumer advances the tail with plain stores.
//! All storage is allocated at construction; `push`/`pop` never touch the
//! allocator, never block, and never spin unboundedly — a full ring fails
//! the push immediately (`Err(item)` back to the caller), which is the
//! backpressure contract at the wire: ring-full ⇒ counted early drop,
//! never a stalled shard loop.
//!
//! The same type doubles as the per-shard *reply* ring (single producer —
//! the pump — single consumer — the shard): MPSC is a superset of SPSC,
//! and one vetted ring beats two. Two more reuses arrived with the
//! sharded pump (DESIGN.md §13): the arrival ring is now one partition
//! per ingress shard (each with a single consuming scheduling shard, so
//! the single-consumer discipline survives S consumers), and the
//! cross-shard *handoff* rings carry `(worker, request)` between
//! scheduling shards — there the full-ring contract flips from
//! counted-drop to spin-not-drop, because past the arrival pop a request
//! is in the conservation ledger (see §13 for the deadlock argument).

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Pads the head and tail counters onto their own cache lines so
/// producers and the consumer don't false-share.
#[repr(align(64))]
struct Pad<T>(T);

struct Slot<T> {
    /// Vyukov sequence: `pos` when empty and claimable by the producer of
    /// ticket `pos`, `pos + 1` when filled, `pos + capacity` after the
    /// consumer frees it for the next lap.
    seq: AtomicUsize,
    val: UnsafeCell<MaybeUninit<T>>,
}

/// Bounded lock-free multi-producer single-consumer ring.
pub struct ArrivalRing<T> {
    mask: usize,
    slots: Box<[Slot<T>]>,
    head: Pad<AtomicUsize>,
    tail: Pad<AtomicUsize>,
}

// SAFETY: slots are handed off between threads through the seq protocol
// (Release on publish, Acquire on observe); a value is owned by exactly
// one side at a time, so Send on T is all that's required.
unsafe impl<T: Send> Send for ArrivalRing<T> {}
unsafe impl<T: Send> Sync for ArrivalRing<T> {}

impl<T> ArrivalRing<T> {
    /// A ring holding at least `capacity` items (rounded up to a power of
    /// two, minimum 2). All storage is allocated here, once.
    pub fn new(capacity: usize) -> ArrivalRing<T> {
        let cap = capacity.max(2).next_power_of_two();
        let slots: Box<[Slot<T>]> = (0..cap)
            .map(|i| Slot {
                seq: AtomicUsize::new(i),
                val: UnsafeCell::new(MaybeUninit::uninit()),
            })
            .collect();
        ArrivalRing {
            mask: cap - 1,
            slots,
            head: Pad(AtomicUsize::new(0)),
            tail: Pad(AtomicUsize::new(0)),
        }
    }

    pub fn capacity(&self) -> usize {
        self.mask + 1
    }

    /// Items currently queued (approximate under concurrent pushes —
    /// exact when producers are quiescent).
    pub fn len(&self) -> usize {
        self.head
            .0
            .load(Ordering::Acquire)
            .saturating_sub(self.tail.0.load(Ordering::Acquire))
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Multi-producer push. `Err(item)` when the ring is full — the caller
    /// owns the drop decision; this never blocks or allocates.
    pub fn push(&self, item: T) -> Result<(), T> {
        let mut pos = self.head.0.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[pos & self.mask];
            let seq = slot.seq.load(Ordering::Acquire);
            let dif = seq as isize - pos as isize;
            if dif == 0 {
                match self.head.0.compare_exchange_weak(
                    pos,
                    pos + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        // SAFETY: the CAS claimed ticket `pos`; no other
                        // producer writes this slot until seq wraps a lap.
                        unsafe { (*slot.val.get()).write(item) };
                        slot.seq.store(pos + 1, Ordering::Release);
                        return Ok(());
                    }
                    Err(now) => pos = now,
                }
            } else if dif < 0 {
                // The slot still holds the previous lap's value: full.
                return Err(item);
            } else {
                // Another producer claimed this ticket; reload and retry.
                pos = self.head.0.load(Ordering::Relaxed);
            }
        }
    }

    /// Single-consumer pop. Only one thread may call this (the serving
    /// pump); never blocks or allocates.
    pub fn pop(&self) -> Option<T> {
        let pos = self.tail.0.load(Ordering::Relaxed);
        let slot = &self.slots[pos & self.mask];
        let seq = slot.seq.load(Ordering::Acquire);
        if (seq as isize) - ((pos + 1) as isize) < 0 {
            return None; // empty (or the producer hasn't published yet)
        }
        self.tail.0.store(pos + 1, Ordering::Relaxed);
        // SAFETY: seq == pos + 1 means the producer published this value
        // and no other consumer exists.
        let val = unsafe { (*slot.val.get()).assume_init_read() };
        slot.seq.store(pos + self.mask + 1, Ordering::Release);
        Some(val)
    }
}

impl<T> Drop for ArrivalRing<T> {
    fn drop(&mut self) {
        // Run destructors for anything still queued.
        while self.pop().is_some() {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn fifo_within_one_producer() {
        let r: ArrivalRing<u64> = ArrivalRing::new(8);
        for i in 0..8 {
            r.push(i).unwrap();
        }
        assert!(r.push(99).is_err(), "full ring rejects");
        for i in 0..8 {
            assert_eq!(r.pop(), Some(i));
        }
        assert_eq!(r.pop(), None);
    }

    #[test]
    fn capacity_rounds_up_and_wraps() {
        let r: ArrivalRing<u32> = ArrivalRing::new(5);
        assert_eq!(r.capacity(), 8);
        // Several laps through the ring keep FIFO order.
        let mut next_in = 0u32;
        let mut next_out = 0u32;
        for _ in 0..5 {
            while r.push(next_in).is_ok() {
                next_in += 1;
            }
            while let Some(v) = r.pop() {
                assert_eq!(v, next_out);
                next_out += 1;
            }
        }
        assert_eq!(next_in, next_out);
        assert!(next_in >= 40);
    }

    #[test]
    fn drop_releases_queued_items() {
        let token = Arc::new(());
        {
            let r: ArrivalRing<Arc<()>> = ArrivalRing::new(4);
            for _ in 0..3 {
                r.push(token.clone()).unwrap();
            }
            assert_eq!(Arc::strong_count(&token), 4);
        }
        assert_eq!(Arc::strong_count(&token), 1, "Drop drains the ring");
    }

    #[test]
    fn multi_producer_conserves_items() {
        let r = Arc::new(ArrivalRing::<u64>::new(64));
        let producers = 4u64;
        let per = 5_000u64;
        let mut sums = Vec::new();
        std::thread::scope(|s| {
            let mut handles = Vec::new();
            for p in 0..producers {
                let r = r.clone();
                handles.push(s.spawn(move || {
                    let mut pushed_sum = 0u64;
                    for i in 0..per {
                        let v = p * per + i;
                        let mut item = v;
                        loop {
                            match r.push(item) {
                                Ok(()) => {
                                    pushed_sum += v;
                                    break;
                                }
                                Err(back) => {
                                    item = back;
                                    std::thread::yield_now();
                                }
                            }
                        }
                    }
                    pushed_sum
                }));
            }
            // Consumer on this thread.
            let mut got = 0u64;
            let mut sum = 0u64;
            while got < producers * per {
                match r.pop() {
                    Some(v) => {
                        sum += v;
                        got += 1;
                    }
                    None => std::thread::yield_now(),
                }
            }
            assert_eq!(r.pop(), None);
            sums.push(sum);
            for h in handles {
                sums.push(h.join().unwrap());
            }
        });
        let consumed = sums[0];
        let pushed: u64 = sums[1..].iter().sum();
        assert_eq!(consumed, pushed, "every pushed item popped exactly once");
    }
}
