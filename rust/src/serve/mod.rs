//! The unified serving core (DESIGN.md §3): one clock-generic, event-driven
//! loop shared by the discrete-event simulator and the real-time server.
//!
//! The paper's scheduler is per-GPU ("scale-out runs one scheduler per
//! model replica", §3.1). This module is the scale-out half of that
//! sentence: a [`Cluster`] holds N replicas, each a scheduler instance
//! (built via the `baselines::by_name` registry) paired by the pump with
//! its own executor, and a [`Router`] front-end admits arrivals and picks
//! the replica that will serve each request. A [`Placement`] records
//! which *models* each replica hosts — arrivals are only ever routed to a
//! replica hosting their model, and batches are model-pure.
//!
//! The core is deliberately execution-agnostic: [`ServingLoop::on_event`]
//! consumes [`Event`]s and returns [`Dispatch`] decisions; a *pump* owns
//! the workers and turns dispatches into batch executions —
//! [`replay`] in virtual time (the evaluation sweeps), [`realtime`] on
//! wall-clock threads (the PJRT serving path). All completion, drop and
//! outcome bookkeeping lives here, once.

pub mod placement;
pub mod realtime;
pub mod replay;
pub mod router;

use crate::baselines;
use crate::clock::{Clock, Micros};
use crate::core::histogram::Histogram;
use crate::core::request::{AppId, Completion, ModelId, Outcome, Request};
use crate::scheduler::{Scheduler, SchedulerConfig};
pub use placement::Placement;
pub use router::Router;

/// Identifies one replica (scheduler + worker pair) in a cluster.
pub type WorkerId = usize;

/// Events driving the serving loop (the whole event model).
#[derive(Debug)]
pub enum Event {
    /// A request entered the system; the router assigns it to a replica
    /// hosting its model.
    Arrival(Request),
    /// A worker finished its in-flight batch; `batch_ms` is the measured
    /// (or simulated) batch wall time fed back to the online profilers.
    BatchDone { worker: WorkerId, batch_ms: f64 },
    /// Timer poll: drain scheduler drops and dispatch to idle workers.
    /// Pumps send this after ingesting every batch of due events.
    Wake,
}

/// A dispatch decision: run `batch` on `worker`. Produced by the loop,
/// executed by the pump (virtual time: cost model; real time: worker
/// thread). The pump must answer with `Event::BatchDone` for this worker.
/// Batches are model-pure: every request names the same model.
#[derive(Debug)]
pub struct Dispatch {
    pub worker: WorkerId,
    pub batch: Vec<Request>,
}

/// Per-replica load snapshot handed to routers (see the [`Router`]
/// contract in [`router`]).
#[derive(Debug, Clone, Copy)]
pub struct WorkerLoad {
    pub worker: WorkerId,
    /// Requests queued in this replica's scheduler (all models).
    pub pending: usize,
    /// Requests queued for the routed request's model specifically
    /// (per-model load accounting; equals `pending` on single-model
    /// clusters).
    pub pending_model: usize,
    /// Size of the batch currently executing (0 = idle).
    pub in_flight: usize,
}

impl WorkerLoad {
    /// Total work in the system at this replica.
    pub fn total(&self) -> usize {
        self.pending + self.in_flight
    }
}

/// Per-replica execution counters, reported by both pumps.
#[derive(Debug, Clone)]
pub struct WorkerStats {
    pub worker: WorkerId,
    /// Batches executed by this replica.
    pub batches: usize,
    /// Total busy time (µs).
    pub busy_us: Micros,
}

impl WorkerStats {
    /// Busy fraction of the run (`end_time` = run length in µs).
    pub fn utilization(&self, end_time: Micros) -> f64 {
        if end_time == 0 {
            0.0
        } else {
            self.busy_us as f64 / end_time as f64
        }
    }
}

struct InFlight {
    batch: Vec<Request>,
    started_at: Micros,
}

struct Slot<S> {
    sched: S,
    inflight: Option<InFlight>,
    batches: usize,
    busy_us: Micros,
}

/// N scheduling replicas plus the model placement across them. Each slot
/// owns one [`Scheduler`] instance; the pump pairs slot *i* with worker
/// *i*.
pub struct Cluster<S> {
    slots: Vec<Slot<S>>,
    placement: Placement,
}

impl<S: Scheduler> Cluster<S> {
    /// One replica per scheduler, every replica hosting every model (the
    /// historical single-model behaviour). Panics on an empty list.
    pub fn new(scheds: Vec<S>) -> Self {
        let placement = Placement::unconstrained(scheds.len().max(1));
        Cluster::with_placement(scheds, placement)
    }

    /// One replica per scheduler with an explicit model placement.
    pub fn with_placement(scheds: Vec<S>, placement: Placement) -> Self {
        assert!(!scheds.is_empty(), "a cluster needs at least one replica");
        assert_eq!(
            placement.workers(),
            scheds.len(),
            "placement must cover exactly the cluster's replicas"
        );
        Cluster {
            slots: scheds
                .into_iter()
                .map(|sched| Slot {
                    sched,
                    inflight: None,
                    batches: 0,
                    busy_us: 0,
                })
                .collect(),
            placement,
        }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    pub fn placement(&self) -> &Placement {
        &self.placement
    }

    /// Install deployment-time historical data for one (model, app) class
    /// on every replica hosting the model.
    pub fn seed_app_profile(&mut self, model: ModelId, app: AppId, hist: &Histogram, weight: u64) {
        let placement = &self.placement;
        for (w, slot) in self.slots.iter_mut().enumerate() {
            if placement.hosts(w, model) {
                slot.sched.seed_app_profile(model, app, hist, weight);
            }
        }
    }
}

impl Cluster<Box<dyn Scheduler>> {
    /// Build `n` replicas of one system via the baselines registry, with
    /// decorrelated per-replica seeds (replica 0 keeps `seed` so a
    /// single-worker cluster reproduces the historical single-loop runs).
    pub fn build(system: &str, cfg: &SchedulerConfig, seed: u64, n: usize) -> Option<Self> {
        Self::build_placed(system, cfg, seed, Placement::unconstrained(n))
    }

    /// Like [`Cluster::build`], but with an explicit model placement; the
    /// replica count is the placement's worker count.
    pub fn build_placed(
        system: &str,
        cfg: &SchedulerConfig,
        seed: u64,
        placement: Placement,
    ) -> Option<Self> {
        let n = placement.workers().max(1);
        let mut scheds = Vec::with_capacity(n);
        for w in 0..n {
            scheds.push(baselines::by_name(system, cfg.clone(), seed ^ ((w as u64) << 24))?);
        }
        Some(Cluster::with_placement(scheds, placement))
    }
}

/// The clock-generic serving loop: routing, dispatch decisions, and all
/// completion/drop/outcome bookkeeping for a cluster of replicas.
pub struct ServingLoop<C: Clock, S: Scheduler> {
    clock: C,
    cluster: Cluster<S>,
    router: Box<dyn Router>,
    completions: Vec<Completion>,
    /// Reused per-arrival candidate snapshot (routing sits on the dispatch
    /// hot path — one request, one route call; no allocation).
    loads_buf: Vec<WorkerLoad>,
}

impl<C: Clock, S: Scheduler> ServingLoop<C, S> {
    pub fn new(clock: C, cluster: Cluster<S>, router: Box<dyn Router>) -> Self {
        let n = cluster.len();
        ServingLoop {
            clock,
            cluster,
            router,
            completions: Vec::new(),
            loads_buf: Vec::with_capacity(n),
        }
    }

    pub fn clock(&self) -> &C {
        &self.clock
    }

    /// Current time on this loop's clock (µs since its epoch).
    pub fn now(&self) -> Micros {
        self.clock.now()
    }

    /// Number of replicas.
    pub fn workers(&self) -> usize {
        self.cluster.len()
    }

    /// The cluster's model placement.
    pub fn placement(&self) -> &Placement {
        self.cluster.placement()
    }

    /// Requests queued (not executing) across all replicas.
    pub fn pending(&self) -> usize {
        self.cluster.slots.iter().map(|s| s.sched.pending()).sum()
    }

    /// Number of replicas with a batch in flight.
    pub fn in_flight(&self) -> usize {
        self.cluster
            .slots
            .iter()
            .filter(|s| s.inflight.is_some())
            .count()
    }

    fn slot_load(w: WorkerId, s: &Slot<S>, model: Option<ModelId>) -> WorkerLoad {
        let pending = s.sched.pending();
        WorkerLoad {
            worker: w,
            pending,
            pending_model: model.map_or(pending, |m| s.sched.pending_for(m)),
            in_flight: s.inflight.as_ref().map_or(0, |f| f.batch.len()),
        }
    }

    /// Per-replica load snapshot (what routers see); `pending_model`
    /// mirrors `pending` since no model is being routed.
    pub fn loads(&self) -> Vec<WorkerLoad> {
        self.cluster
            .slots
            .iter()
            .enumerate()
            .map(|(w, s)| Self::slot_load(w, s, None))
            .collect()
    }

    /// Rebuild the reusable routing snapshot in place, restricted to the
    /// replicas hosting `req`'s model.
    fn refresh_candidates(&mut self, req: &Request) {
        let slots = &self.cluster.slots;
        let placement = &self.cluster.placement;
        self.loads_buf.clear();
        self.loads_buf.extend(
            slots
                .iter()
                .enumerate()
                .filter(|(w, _)| placement.hosts(*w, req.model))
                .map(|(w, s)| Self::slot_load(w, s, Some(req.model))),
        );
    }

    /// Feed one event; returns the dispatch decisions the pump must
    /// execute. `Arrival` and `BatchDone` only update state — dispatching
    /// happens on `Wake`, so a pump can ingest a burst of same-time events
    /// before the schedulers are asked to form batches (exactly what both
    /// historical loops did).
    pub fn on_event(&mut self, ev: Event) -> Vec<Dispatch> {
        let now = self.clock.now();
        match ev {
            Event::Arrival(req) => {
                self.refresh_candidates(&req);
                if self.loads_buf.is_empty() {
                    // No replica hosts this model: terminal drop (the
                    // request still completes exactly once, as TimedOut —
                    // `Placement::parse` rejects placements that leave a
                    // model unhosted, so this only fires on ad-hoc traces).
                    self.completions.push(Completion {
                        request: req,
                        outcome: Outcome::TimedOut,
                        at: now,
                        batch_size: 0,
                        worker: None,
                    });
                    return Vec::new();
                }
                let n = self.loads_buf.len();
                let i = self.router.route(&req, &self.loads_buf);
                debug_assert!(i < n, "router returned candidate {i} of {n}");
                let w = self.loads_buf[i.min(n - 1)].worker;
                self.cluster.slots[w].sched.on_arrival(req, now);
                Vec::new()
            }
            Event::BatchDone { worker, batch_ms } => {
                self.finish(worker, batch_ms, now);
                Vec::new()
            }
            Event::Wake => {
                let mut out = Vec::new();
                for w in 0..self.cluster.len() {
                    self.drain_dropped(w, now);
                    if let Some(d) = self.dispatch_from(w, now) {
                        out.push(d);
                    }
                }
                out
            }
        }
    }

    /// Next time any idle replica with queued work wants to be polled:
    /// its scheduler's wake hint, or a default 1 ms cadence (milestones /
    /// forced partial batches / window ends). Busy replicas don't need
    /// wakes — their `BatchDone` is the next event.
    pub fn next_wake(&self, now: Micros) -> Option<Micros> {
        let mut next: Option<Micros> = None;
        for slot in &self.cluster.slots {
            if slot.inflight.is_none() && slot.sched.pending() > 0 {
                let h = slot
                    .sched
                    .wake_hint(now)
                    .filter(|&h| h > now)
                    .unwrap_or(now + 1_000);
                next = Some(next.map_or(h, |n| n.min(h)));
            }
        }
        next
    }

    /// Final drop sweep (call once when the pump decides the run is over).
    pub fn drain_all(&mut self) {
        let now = self.clock.now();
        for w in 0..self.cluster.len() {
            self.drain_dropped(w, now);
        }
    }

    /// Completions recorded so far.
    pub fn completions(&self) -> &[Completion] {
        &self.completions
    }

    /// Consume the loop, yielding completions and per-replica counters.
    pub fn into_completions(self) -> (Vec<Completion>, Vec<WorkerStats>) {
        let stats = self
            .cluster
            .slots
            .iter()
            .enumerate()
            .map(|(w, s)| WorkerStats {
                worker: w,
                batches: s.batches,
                busy_us: s.busy_us,
            })
            .collect();
        (self.completions, stats)
    }

    /// Book a finished batch: label outcomes against deadlines, account
    /// busy time, feed the measured latency back to the scheduler.
    fn finish(&mut self, w: WorkerId, batch_ms: f64, now: Micros) {
        let slot = &mut self.cluster.slots[w];
        let Some(f) = slot.inflight.take() else {
            debug_assert!(false, "BatchDone for idle worker {w}");
            return;
        };
        let bs = f.batch.len();
        for r in &f.batch {
            let outcome = if now <= r.deadline {
                Outcome::Finished
            } else {
                Outcome::Late
            };
            self.completions.push(Completion {
                request: r.clone(),
                outcome,
                at: now,
                batch_size: bs,
                worker: Some(w),
            });
        }
        slot.busy_us += now.saturating_sub(f.started_at);
        slot.batches += 1;
        slot.sched.on_batch_complete(&f.batch, batch_ms, now);
        self.drain_dropped(w, now);
    }

    /// If replica `w` is idle, ask its scheduler for a batch — repeating
    /// while the scheduler's state changes (e.g. Clockwork aborting a
    /// planned batch frees it to plan another immediately).
    fn dispatch_from(&mut self, w: WorkerId, now: Micros) -> Option<Dispatch> {
        if self.cluster.slots[w].inflight.is_some() {
            return None;
        }
        loop {
            match self.cluster.slots[w].sched.next_batch(now) {
                Some(batch) => {
                    debug_assert!(
                        batch.iter().all(|r| r.model == batch[0].model),
                        "scheduler {w} formed a mixed-model batch"
                    );
                    debug_assert!(
                        batch
                            .first()
                            .map(|r| self.cluster.placement.hosts(w, r.model))
                            .unwrap_or(true),
                        "worker {w} dispatched a batch for a model it does not host"
                    );
                    self.cluster.slots[w].inflight = Some(InFlight {
                        batch: batch.clone(),
                        started_at: now,
                    });
                    return Some(Dispatch { worker: w, batch });
                }
                None => {
                    if !self.drain_dropped(w, now) {
                        return None;
                    }
                }
            }
        }
    }

    /// Record replica `w`'s scheduler-side drops; true if any.
    fn drain_dropped(&mut self, w: WorkerId, now: Micros) -> bool {
        let dropped = self.cluster.slots[w].sched.drain_dropped();
        let any = !dropped.is_empty();
        for (r, outcome) in dropped {
            self.completions.push(Completion {
                request: r,
                outcome,
                at: now,
                batch_size: 0,
                worker: None,
            });
        }
        any
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::edf::EdfScheduler;
    use crate::clock::{ms_to_us, VirtualClock};
    use crate::core::batchmodel::BatchCostModel;
    use crate::core::request::AppId;

    fn cfg() -> SchedulerConfig {
        SchedulerConfig {
            cost_model: BatchCostModel::new(0.0, 1.0),
            ..Default::default()
        }
    }

    fn sched() -> EdfScheduler {
        let mut s = EdfScheduler::new(cfg(), 0);
        s.seed_exec_mean(10.0);
        s
    }

    fn req(id: u64, release: Micros) -> Request {
        Request::new(id, AppId(0), release, ms_to_us(500.0), 10.0)
    }

    #[test]
    fn arrival_routes_then_wake_dispatches() {
        let clock = VirtualClock::new();
        let cluster = Cluster::new(vec![sched(), sched()]);
        let mut core = ServingLoop::new(
            clock.clone(),
            cluster,
            router::by_name("round_robin").unwrap(),
        );
        assert!(core.on_event(Event::Arrival(req(0, 0))).is_empty());
        assert!(core.on_event(Event::Arrival(req(1, 0))).is_empty());
        assert_eq!(core.pending(), 2);
        let ds = core.on_event(Event::Wake);
        // Round-robin put one request on each replica → two dispatches.
        assert_eq!(ds.len(), 2);
        assert_eq!(core.in_flight(), 2);
        assert_eq!(core.pending(), 0);
    }

    #[test]
    fn batch_done_labels_outcomes_and_counts() {
        let clock = VirtualClock::new();
        let cluster = Cluster::new(vec![sched()]);
        let mut core =
            ServingLoop::new(clock.clone(), cluster, router::by_name("round_robin").unwrap());
        core.on_event(Event::Arrival(req(0, 0)));
        let ds = core.on_event(Event::Wake);
        assert_eq!(ds.len(), 1);
        clock.advance_to(ms_to_us(10.0));
        core.on_event(Event::BatchDone {
            worker: 0,
            batch_ms: 10.0,
        });
        let (completions, stats) = core.into_completions();
        assert_eq!(completions.len(), 1);
        assert_eq!(completions[0].outcome, Outcome::Finished);
        assert_eq!(completions[0].worker, Some(0));
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].batches, 1);
        assert_eq!(stats[0].busy_us, ms_to_us(10.0));
        assert!((stats[0].utilization(ms_to_us(10.0)) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cluster_build_makes_n_replicas() {
        let c = Cluster::build("orloj", &SchedulerConfig::default(), 7, 4).unwrap();
        assert_eq!(c.len(), 4);
        assert!(Cluster::build("no-such-system", &SchedulerConfig::default(), 7, 2).is_none());
    }

    #[test]
    fn placement_constrains_routing() {
        let clock = VirtualClock::new();
        // Worker 0 hosts model 0, worker 1 hosts model 1.
        let placement = Placement::parse("partition", 2, 2).unwrap();
        let cluster = Cluster::with_placement(vec![sched(), sched()], placement);
        let mut core = ServingLoop::new(
            clock.clone(),
            cluster,
            router::by_name("least_loaded").unwrap(),
        );
        for i in 0..4u64 {
            let model = ModelId((i % 2) as u32);
            core.on_event(Event::Arrival(req(i, 0).with_model(model)));
        }
        let ds = core.on_event(Event::Wake);
        assert_eq!(ds.len(), 2);
        for d in &ds {
            for r in &d.batch {
                assert!(
                    core.placement().hosts(d.worker, r.model),
                    "worker {} got model {:?}",
                    d.worker,
                    r.model
                );
            }
        }
    }
}
