//! The unified serving core (DESIGN.md §3): one clock-generic, event-driven
//! loop shared by the discrete-event simulator and the real-time server.
//!
//! The paper's scheduler is per-GPU ("scale-out runs one scheduler per
//! model replica", §3.1). This module is the scale-out half of that
//! sentence: a [`Cluster`] holds N replicas, each a scheduler instance
//! (built via the `baselines::by_name` registry) paired by the pump with
//! its own executor, and a [`Router`] front-end admits arrivals and picks
//! the replica that will serve each request. A [`Placement`] records
//! which *models* each replica hosts — arrivals are only ever routed to a
//! replica hosting their model, and batches are model-pure.
//!
//! The placement can be **elastic** (DESIGN.md §8): a
//! [`PlacementController`] installed via [`ServingLoop::with_elastic`]
//! watches per-model demand and issues `Load`/`Unload` actions under a
//! per-worker capacity budget. Loads are cold starts — the pump answers a
//! [`Dispatch::Load`] with [`Event::PlacementDone`] after the cold-start
//! latency, and the warming replica is not routed to until then. Unloads
//! apply immediately: the model's queued requests drain back through the
//! router to the remaining hosts (the evict-drain invariant) instead of
//! being dropped.
//!
//! The core is deliberately execution-agnostic: [`ServingLoop::on_event`]
//! consumes [`Event`]s and returns [`Dispatch`] decisions; a *pump* owns
//! the workers and turns dispatches into batch executions and model loads
//! — [`replay`] in virtual time (the evaluation sweeps), [`realtime`] on
//! wall-clock threads (the PJRT serving path). All completion, drop and
//! outcome bookkeeping lives here, once.

pub mod admission;
pub mod ingress;
pub mod placement;
pub mod realtime;
pub mod replay;
pub mod ring;
pub mod router;

use crate::baselines;
use crate::clock::{Clock, Micros};
use crate::core::histogram::Histogram;
use crate::core::request::{AppId, Completion, ModelId, Outcome, Request};
use crate::scheduler::{Scheduler, SchedulerConfig};
use crate::telemetry::{EventKind, Recorder};
pub use admission::{AdmissionConfig, AdmissionController, AdmissionStats, Decision};
pub use placement::{
    ColdStartCost, ElasticConfig, Placement, PlacementAction, PlacementController, WorkerView,
};
pub use router::Router;

/// Identifies one replica (scheduler + worker pair) in a cluster.
pub type WorkerId = usize;

/// Events driving the serving loop (the whole event model).
#[derive(Debug)]
pub enum Event {
    /// A request entered the system; the router assigns it to a replica
    /// hosting its model.
    Arrival(Request),
    /// A worker finished its in-flight batch; `batch_ms` is the measured
    /// (or simulated) batch wall time fed back to the online profilers.
    BatchDone { worker: WorkerId, batch_ms: f64 },
    /// A model load finished on `worker` (answering a [`Dispatch::Load`]):
    /// the replica becomes routable for `model`. `load_ms` is the
    /// *measured* load time (virtual workers realize the prediction; the
    /// PJRT worker times the actual runtime load) — it is what the
    /// scheduler's warm-up surcharge charges, not the prediction.
    PlacementDone {
        worker: WorkerId,
        model: ModelId,
        load_ms: f64,
    },
    /// Timer poll: drain scheduler drops, run the placement controller,
    /// and dispatch to idle workers. Pumps send this after ingesting
    /// every batch of due events.
    Wake,
}

/// A decision produced by the loop and executed by the pump:
///
/// * [`Dispatch::Execute`] — run `batch` on `worker` (virtual time: cost
///   model; real time: worker thread). The pump must answer with
///   [`Event::BatchDone`] for this worker. Batches are model-pure: every
///   request names the same model.
/// * [`Dispatch::Load`] — start loading `model` onto `worker` (predicted
///   cold-start `cost_ms`). The pump must answer with
///   [`Event::PlacementDone`]; until then the replica is not routed to
///   for `model`. At most one load is in flight per worker.
/// * [`Dispatch::Unload`] — `model` left `worker`. Already applied inside
///   the core (queue drained and re-routed); pumps may release
///   executor-side state (e.g. a PJRT runtime). No reply event.
#[derive(Debug)]
pub enum Dispatch {
    Execute {
        worker: WorkerId,
        batch: Vec<Request>,
    },
    Load {
        worker: WorkerId,
        model: ModelId,
        cost_ms: f64,
    },
    Unload {
        worker: WorkerId,
        model: ModelId,
    },
}

/// Per-replica load snapshot handed to routers (see the [`Router`]
/// contract in [`router`]).
#[derive(Debug, Clone, Copy)]
pub struct WorkerLoad {
    pub worker: WorkerId,
    /// Requests queued in this replica's scheduler (all models).
    pub pending: usize,
    /// Requests queued for the routed request's model specifically
    /// (per-model load accounting; equals `pending` on single-model
    /// clusters).
    pub pending_model: usize,
    /// Size of the batch currently executing (0 = idle).
    pub in_flight: usize,
}

impl WorkerLoad {
    /// Total work in the system at this replica.
    pub fn total(&self) -> usize {
        self.pending + self.in_flight
    }
}

/// Per-replica execution counters, reported by both pumps.
#[derive(Debug, Clone)]
pub struct WorkerStats {
    pub worker: WorkerId,
    /// Batches executed by this replica.
    pub batches: usize,
    /// Total busy time (µs).
    pub busy_us: Micros,
}

impl WorkerStats {
    /// Busy fraction of the run (`end_time` = run length in µs).
    pub fn utilization(&self, end_time: Micros) -> f64 {
        if end_time == 0 {
            0.0
        } else {
            self.busy_us as f64 / end_time as f64
        }
    }
}

/// Per-run elastic placement counters (all zero on static runs).
#[derive(Debug, Clone, Default)]
pub struct PlacementStats {
    /// `LoadModel` actions issued.
    pub loads: usize,
    /// `UnloadModel` actions issued.
    pub unloads: usize,
    /// Requests drained by evictions and re-routed (not dropped).
    pub rerouted: usize,
    /// Time of the first placement action (µs; 0 = none) — how fast the
    /// controller reacted to the initial demand signal.
    pub first_action_at: Micros,
    /// Time of the last placement action (µs). On a mix that keeps
    /// drifting this tracks the final rotation, not a settling point —
    /// read it together with `first_action_at`.
    pub last_action_at: Micros,
}

impl PlacementStats {
    /// Total placement actions (loads + unloads).
    pub fn actions(&self) -> usize {
        self.loads + self.unloads
    }
}

struct InFlight {
    batch: Vec<Request>,
    /// Telemetry batch id assigned at formation (None when disabled).
    telemetry_batch: Option<u32>,
    /// Formed from the admission controller's best-effort lane: its
    /// completions never count toward the SLO finish rate and its realized
    /// latency is not fed back to the scheduler's profiler.
    best_effort: bool,
}

struct Slot<S> {
    sched: S,
    inflight: Option<InFlight>,
    /// Model load in flight on this worker; at most one at a time.
    loading: Option<ModelId>,
    batches: usize,
    busy_us: Micros,
}

/// N scheduling replicas plus the model placement across them. Each slot
/// owns one [`Scheduler`] instance; the pump pairs slot *i* with worker
/// *i*.
pub struct Cluster<S> {
    slots: Vec<Slot<S>>,
    placement: Placement,
}

impl<S: Scheduler> Cluster<S> {
    /// One replica per scheduler, every replica hosting every model (the
    /// historical single-model behaviour). Panics on an empty list.
    pub fn new(scheds: Vec<S>) -> Self {
        let placement = Placement::unconstrained(scheds.len().max(1));
        Cluster::with_placement(scheds, placement)
    }

    /// One replica per scheduler with an explicit model placement.
    pub fn with_placement(scheds: Vec<S>, placement: Placement) -> Self {
        assert!(!scheds.is_empty(), "a cluster needs at least one replica");
        assert_eq!(
            placement.workers(),
            scheds.len(),
            "placement must cover exactly the cluster's replicas"
        );
        Cluster {
            slots: scheds
                .into_iter()
                .map(|sched| Slot {
                    sched,
                    inflight: None,
                    loading: None,
                    batches: 0,
                    busy_us: 0,
                })
                .collect(),
            placement,
        }
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Take the cluster apart into its (seeded) schedulers and placement
    /// so the sharded pump can re-group them into per-shard sub-clusters.
    /// Only valid on an un-driven cluster: a slot with work in flight
    /// cannot move between event lanes.
    pub(crate) fn into_parts(self) -> (Vec<S>, Placement) {
        let scheds = self
            .slots
            .into_iter()
            .map(|s| {
                assert!(
                    s.inflight.is_none() && s.loading.is_none() && s.batches == 0,
                    "sharding must start from idle replicas"
                );
                s.sched
            })
            .collect();
        (scheds, self.placement)
    }

    pub fn placement(&self) -> &Placement {
        &self.placement
    }

    /// Install deployment-time historical data for one (model, app) class
    /// on every replica hosting the model.
    pub fn seed_app_profile(&mut self, model: ModelId, app: AppId, hist: &Histogram, weight: u64) {
        let placement = &self.placement;
        for (w, slot) in self.slots.iter_mut().enumerate() {
            if placement.hosts(w, model) {
                slot.sched.seed_app_profile(model, app, hist, weight);
            }
        }
    }

    /// Install deployment-time historical data on **every** replica,
    /// hosting or not — the elastic path, where any replica may acquire
    /// the model at runtime and should start from the shared profile
    /// rather than cold.
    pub fn seed_app_profile_everywhere(
        &mut self,
        model: ModelId,
        app: AppId,
        hist: &Histogram,
        weight: u64,
    ) {
        for slot in self.slots.iter_mut() {
            slot.sched.seed_app_profile(model, app, hist, weight);
        }
    }
}

impl Cluster<Box<dyn Scheduler>> {
    /// Build `n` replicas of one system via the baselines registry, with
    /// decorrelated per-replica seeds (replica 0 keeps `seed` so a
    /// single-worker cluster reproduces the historical single-loop runs).
    pub fn build(system: &str, cfg: &SchedulerConfig, seed: u64, n: usize) -> Option<Self> {
        Self::build_placed(system, cfg, seed, Placement::unconstrained(n))
    }

    /// Like [`Cluster::build`], but with an explicit model placement; the
    /// replica count is the placement's worker count.
    pub fn build_placed(
        system: &str,
        cfg: &SchedulerConfig,
        seed: u64,
        placement: Placement,
    ) -> Option<Self> {
        let n = placement.workers().max(1);
        let mut scheds = Vec::with_capacity(n);
        for w in 0..n {
            scheds.push(baselines::by_name(system, cfg.clone(), seed ^ ((w as u64) << 24))?);
        }
        Some(Cluster::with_placement(scheds, placement))
    }
}

struct ElasticState {
    ctl: PlacementController,
    stats: PlacementStats,
}

/// The clock-generic serving loop: routing, dispatch decisions, elastic
/// placement control, and all completion/drop/outcome bookkeeping for a
/// cluster of replicas.
pub struct ServingLoop<C: Clock, S: Scheduler> {
    clock: C,
    cluster: Cluster<S>,
    router: Box<dyn Router>,
    completions: Vec<Completion>,
    /// Elastic placement controller (None = static placement).
    elastic: Option<ElasticState>,
    /// Predictive admission controller (None = every arrival is routed
    /// straight to a scheduler, bit-identical to the pre-admission loop —
    /// the golden snapshots and zero-alloc audit pin this).
    admission: Option<AdmissionController>,
    /// Reused per-arrival candidate snapshot (routing sits on the dispatch
    /// hot path — one request, one route call; no allocation).
    loads_buf: Vec<WorkerLoad>,
    /// Event recorder (None = telemetry off, the default). Every hook is
    /// a single branch on this option, so the disabled hot path stays
    /// allocation-free and bit-identical (the golden snapshots and the
    /// steady-state alloc audit pin this).
    telemetry: Option<Box<Recorder>>,
}

impl<C: Clock, S: Scheduler> ServingLoop<C, S> {
    pub fn new(clock: C, cluster: Cluster<S>, router: Box<dyn Router>) -> Self {
        let n = cluster.len();
        ServingLoop {
            clock,
            cluster,
            router,
            completions: Vec::new(),
            elastic: None,
            admission: None,
            loads_buf: Vec::with_capacity(n),
            telemetry: None,
        }
    }

    /// Enable event recording. The recorder's ring is pre-allocated here,
    /// off the serving path.
    pub fn with_telemetry(mut self, rec: Recorder) -> Self {
        self.telemetry = Some(Box::new(rec));
        self
    }

    pub fn telemetry(&self) -> Option<&Recorder> {
        self.telemetry.as_deref()
    }

    pub fn telemetry_mut(&mut self) -> Option<&mut Recorder> {
        self.telemetry.as_deref_mut()
    }

    /// Detach the recorder (pumps hand it to `EngineResult`/`ServeResult`
    /// before consuming the loop).
    pub fn take_telemetry(&mut self) -> Option<Box<Recorder>> {
        self.telemetry.take()
    }

    /// Enable elastic placement: `ctl` watches per-model demand on every
    /// `Wake` and issues `Load`/`Unload` dispatches. Requires an explicit
    /// placement (the controller mutates per-worker hosting lists).
    pub fn with_elastic(mut self, ctl: PlacementController) -> Self {
        assert!(
            !self.cluster.placement.is_unconstrained(),
            "elastic placement needs an explicit placement (Placement::parse)"
        );
        self.elastic = Some(ElasticState {
            ctl,
            stats: PlacementStats::default(),
        });
        self
    }

    /// Enable predictive admission control (DESIGN.md §10): every arrival
    /// is gated on its estimated P(finish ≤ deadline) and either admitted
    /// to the SLO lane, downgraded to the controller's best-effort lane,
    /// or early-rejected. Seed the controller's profiles before attaching.
    pub fn with_admission(mut self, ctl: AdmissionController) -> Self {
        self.admission = Some(ctl);
        self
    }

    /// Whether an admission controller is installed.
    pub fn admission_enabled(&self) -> bool {
        self.admission.is_some()
    }

    /// Run-level admission tallies (disabled + all-zero when off).
    pub fn admission_stats(&self) -> AdmissionStats {
        self.admission
            .as_ref()
            .map(|c| c.stats())
            .unwrap_or_default()
    }

    pub fn clock(&self) -> &C {
        &self.clock
    }

    /// Current time on this loop's clock (µs since its epoch).
    pub fn now(&self) -> Micros {
        self.clock.now()
    }

    /// Number of replicas.
    pub fn workers(&self) -> usize {
        self.cluster.len()
    }

    /// The cluster's model placement (live under elastic control).
    pub fn placement(&self) -> &Placement {
        self.cluster.placement()
    }

    /// Whether an elastic controller is installed.
    pub fn elastic_enabled(&self) -> bool {
        self.elastic.is_some()
    }

    /// Elastic action counters (all zero on static runs).
    pub fn placement_stats(&self) -> PlacementStats {
        self.elastic
            .as_ref()
            .map(|e| e.stats.clone())
            .unwrap_or_default()
    }

    /// Requests queued (not executing) across all replicas, plus any
    /// parked in the admission controller's best-effort lane — pumps poll
    /// this to decide when the run has drained, so lane residents must
    /// count or they would strand at shutdown.
    pub fn pending(&self) -> usize {
        self.cluster
            .slots
            .iter()
            .map(|s| s.sched.pending())
            .sum::<usize>()
            + self
                .admission
                .as_ref()
                .map_or(0, |c| c.best_effort_pending())
    }

    /// Number of replicas with a batch in flight.
    pub fn in_flight(&self) -> usize {
        self.cluster
            .slots
            .iter()
            .filter(|s| s.inflight.is_some())
            .count()
    }

    /// Number of replicas with a model load in flight.
    pub fn loading(&self) -> usize {
        self.cluster
            .slots
            .iter()
            .filter(|s| s.loading.is_some())
            .count()
    }

    fn slot_load(w: WorkerId, s: &Slot<S>, model: Option<ModelId>) -> WorkerLoad {
        let pending = s.sched.pending();
        WorkerLoad {
            worker: w,
            pending,
            pending_model: model.map_or(pending, |m| s.sched.pending_for(m)),
            in_flight: s.inflight.as_ref().map_or(0, |f| f.batch.len()),
        }
    }

    /// Per-replica load snapshot (what routers see); `pending_model`
    /// mirrors `pending` since no model is being routed.
    pub fn loads(&self) -> Vec<WorkerLoad> {
        self.cluster
            .slots
            .iter()
            .enumerate()
            .map(|(w, s)| Self::slot_load(w, s, None))
            .collect()
    }

    /// One replica's load snapshot without allocating — the sharded
    /// pump's `LoadBoard` publish source (DESIGN.md §13).
    pub(crate) fn load_of(&self, w: WorkerId) -> WorkerLoad {
        Self::slot_load(w, &self.cluster.slots[w], None)
    }

    /// The installed router's registry name (picks the sharded pump's
    /// board policy; see `serve::router::BoardPolicy::from_router_name`).
    pub fn router_name(&self) -> &'static str {
        self.router.name()
    }

    /// Rebuild the reusable routing snapshot in place, restricted to the
    /// replicas hosting `req`'s model. Warming replicas (load in flight)
    /// are not yet hosting, so they are naturally excluded.
    fn refresh_candidates(&mut self, req: &Request) {
        let slots = &self.cluster.slots;
        let placement = &self.cluster.placement;
        self.loads_buf.clear();
        self.loads_buf.extend(
            slots
                .iter()
                .enumerate()
                .filter(|(w, _)| placement.hosts(*w, req.model))
                .map(|(w, s)| Self::slot_load(w, s, Some(req.model))),
        );
    }

    /// Route one request to a replica hosting its model — the arrival
    /// path, also used to re-route requests drained by an eviction.
    fn route(&mut self, req: Request, now: Micros) {
        self.refresh_candidates(&req);
        if self.loads_buf.is_empty() {
            // No ready replica hosts this model: terminal drop (the
            // request still completes exactly once, as TimedOut —
            // `Placement::parse` rejects placements that leave a model
            // unhosted, and the elastic controller never evicts a model's
            // last ready host, so this only fires on ad-hoc traces).
            if let Some(tel) = self.telemetry.as_mut() {
                tel.record(now, EventKind::RouteDrop { req: req.id });
                tel.record(
                    now,
                    EventKind::Terminal {
                        req: req.id,
                        outcome: Outcome::TimedOut,
                        worker: None,
                    },
                );
            }
            self.completions.push(Completion {
                request: req,
                outcome: Outcome::TimedOut,
                at: now,
                batch_size: 0,
                worker: None,
                best_effort: false,
            });
            return;
        }
        let n = self.loads_buf.len();
        let i = self.router.route(&req, &self.loads_buf);
        debug_assert!(i < n, "router returned candidate {i} of {n}");
        let w = self.loads_buf[i.min(n - 1)].worker;
        if let Some(tel) = self.telemetry.as_mut() {
            tel.record(
                now,
                EventKind::Routed {
                    req: req.id,
                    worker: w as u32,
                },
            );
        }
        self.cluster.slots[w].sched.on_arrival(req, now);
    }

    /// Admission-controlled arrival (DESIGN.md §10): gate the request on
    /// its estimated P(finish ≤ deadline) against the *best* candidate
    /// replica's backlog, then admit / downgrade / early-reject it.
    fn admit(&mut self, req: Request, now: Micros) {
        // Minimum drain estimate over ready replicas hosting the model
        // (each scheduler's estimate includes its cold-start surcharge);
        // no ready host → infinite backlog → hopeless → reject.
        let mut backlog_ms = f64::INFINITY;
        let placement = &self.cluster.placement;
        for (w, slot) in self.cluster.slots.iter_mut().enumerate() {
            if placement.hosts(w, req.model) {
                backlog_ms = backlog_ms.min(slot.sched.backlog_estimate(req.model));
            }
        }
        let ctl = self
            .admission
            .as_mut()
            .expect("admit() is only called with a controller installed");
        let (decision, p) = ctl.decide(&req, backlog_ms, now);
        match decision {
            Decision::Admit => {
                if let Some(tel) = self.telemetry.as_mut() {
                    tel.record(now, EventKind::Admitted { req: req.id, p });
                }
                self.route(req, now);
            }
            Decision::Downgrade => {
                if let Some(tel) = self.telemetry.as_mut() {
                    tel.record(now, EventKind::Downgraded { req: req.id, p });
                }
                // The controller owns the best-effort lane; the request
                // leaves the SLO path here and only executes when a worker
                // would otherwise idle.
                self.admission
                    .as_mut()
                    .expect("controller checked above")
                    .push_best_effort(req);
            }
            Decision::Reject => {
                // Early rejection is terminal: exactly one Terminal event
                // and one Completion, same as every other fate (the
                // conservation invariant covers this path too).
                if let Some(tel) = self.telemetry.as_mut() {
                    tel.record(now, EventKind::EarlyReject { req: req.id, p });
                    tel.record(
                        now,
                        EventKind::Terminal {
                            req: req.id,
                            outcome: Outcome::TimedOut,
                            worker: None,
                        },
                    );
                }
                self.completions.push(Completion {
                    request: req,
                    outcome: Outcome::TimedOut,
                    at: now,
                    batch_size: 0,
                    worker: None,
                    best_effort: false,
                });
            }
        }
    }

    /// Sweep best-effort lane entries whose model lost its last ready
    /// host (an elastic unload can orphan them): they can never execute,
    /// so they terminate now instead of wedging the pumps' drain check.
    fn evict_unhosted_best_effort(&mut self, now: Micros) {
        let Some(ctl) = self.admission.as_mut() else {
            return;
        };
        if ctl.best_effort_pending() == 0 {
            return;
        }
        let placement = &self.cluster.placement;
        let orphans = ctl.evict_unhosted(|m| placement.hosts_anywhere(m));
        for r in orphans {
            if let Some(tel) = self.telemetry.as_mut() {
                tel.record(
                    now,
                    EventKind::Terminal {
                        req: r.id,
                        outcome: Outcome::TimedOut,
                        worker: None,
                    },
                );
            }
            self.completions.push(Completion {
                request: r,
                outcome: Outcome::TimedOut,
                at: now,
                batch_size: 0,
                worker: None,
                best_effort: true,
            });
        }
    }

    /// Feed one event; returns the dispatch decisions the pump must
    /// execute. `Arrival`, `BatchDone` and `PlacementDone` only update
    /// state — dispatching happens on `Wake`, so a pump can ingest a
    /// burst of same-time events before the schedulers are asked to form
    /// batches (exactly what both historical loops did).
    pub fn on_event(&mut self, ev: Event) -> Vec<Dispatch> {
        let now = self.clock.now();
        match ev {
            Event::Arrival(req) => {
                if let Some(el) = &mut self.elastic {
                    el.ctl.note_arrival(req.model);
                }
                if let Some(tel) = self.telemetry.as_mut() {
                    tel.record(
                        now,
                        EventKind::Arrival {
                            req: req.id,
                            model: req.model,
                            app: req.app,
                        },
                    );
                }
                if self.admission.is_some() {
                    self.admit(req, now);
                } else {
                    self.route(req, now);
                }
                Vec::new()
            }
            Event::BatchDone { worker, batch_ms } => {
                self.finish(worker, batch_ms, now);
                Vec::new()
            }
            Event::PlacementDone {
                worker,
                model,
                load_ms,
            } => {
                self.placement_done(worker, model, load_ms, now);
                Vec::new()
            }
            Event::Wake => {
                let mut out = Vec::new();
                if let Some(tel) = self.telemetry.as_mut() {
                    tel.record(now, EventKind::Wake);
                }
                self.sample_telemetry(now);
                self.control_placement(now, &mut out);
                self.evict_unhosted_best_effort(now);
                // Reaping keeps router-visible counts honest: busy
                // replicas never reach `next_batch`, so their queues would
                // hold already-doomed requests until the batch completes —
                // and look busier to load-aware routers than they are.
                // Counts only steer *routing*, so single-replica clusters
                // skip it (there is no routing choice) and keep the
                // historical shed-at-batch-formation timing exactly.
                let reap = self.cluster.len() > 1;
                for w in 0..self.cluster.len() {
                    if let Some(d) = self.poll_slot(w, reap) {
                        out.push(d);
                    }
                }
                out
            }
        }
    }

    /// Next time any idle replica with queued work wants to be polled:
    /// its scheduler's wake hint, or a default 1 ms cadence (milestones /
    /// forced partial batches / window ends). Busy replicas don't need
    /// wakes — their `BatchDone` is the next event. The elastic
    /// controller piggybacks on this cadence (plus every arrival and
    /// completion), so it needs no timer of its own.
    pub fn next_wake(&self, now: Micros) -> Option<Micros> {
        let mut next: Option<Micros> = None;
        for slot in &self.cluster.slots {
            if slot.inflight.is_none() && slot.sched.pending() > 0 {
                // Hint first; with no (future) hint, jump to the earliest
                // deadline the policy tracks — a hintless scheduler would
                // otherwise crawl toward its queued work in 1 ms hops. The
                // 1 ms cadence survives only as the last resort for
                // policies that track neither.
                let h = slot
                    .sched
                    .wake_hint(now)
                    .filter(|&h| h > now)
                    .or_else(|| slot.sched.earliest_deadline().filter(|&d| d > now))
                    .unwrap_or(now + 1_000);
                next = Some(next.map_or(h, |n| n.min(h)));
            }
        }
        // Parked best-effort work also wants an idle worker: keep the
        // default poll cadence alive when the SLO lanes are quiet, or the
        // lane would only drain on the next unrelated event.
        if next.is_none()
            && self
                .admission
                .as_ref()
                .is_some_and(|c| c.best_effort_pending() > 0)
            && self.cluster.slots.iter().any(|s| s.inflight.is_none())
        {
            next = Some(now + 1_000);
        }
        next
    }

    /// Whether this loop's configuration lets the sharded pump run its
    /// replicas in parallel event lanes (DESIGN.md §11): routing must be
    /// replayable by the coordinator before any scheduler state exists
    /// (load-oblivious router), and nothing may mutate global state from
    /// inside a lane (no admission gate, no elastic controller, no shared
    /// telemetry ring). Anything else falls back to the sequential pump,
    /// which is the conservative merge in the limit.
    pub fn parallel_safe(&self) -> bool {
        self.router.load_oblivious()
            && self.elastic.is_none()
            && self.admission.is_none()
            && self.telemetry.is_none()
    }

    /// Poll one replica: reap its doomed queue entries (multi-replica
    /// clusters only — `reap` is the *global* cluster-size gate, passed in
    /// because a shard sees only its own slots), sweep drops, and form the
    /// next batch if the worker is free. This is exactly the per-worker
    /// body of the `Event::Wake` arm, exposed so the per-slot pump can
    /// poll replicas on their own event cadence instead of all at once.
    pub(crate) fn poll_slot(&mut self, w: WorkerId, reap: bool) -> Option<Dispatch> {
        let now = self.clock.now();
        if reap && self.cluster.slots[w].inflight.is_some() {
            self.cluster.slots[w].sched.reap(now);
            if let Some(tel) = self.telemetry.as_mut() {
                tel.record(now, EventKind::Reap { worker: w as u32 });
            }
        }
        self.drain_dropped(w, now);
        self.dispatch_from(w, now)
    }

    /// Next time replica `w` wants to be polled without a delivery of its
    /// own: its scheduler's wake hint, then the earliest tracked deadline,
    /// then the 1 ms last-resort cadence — the per-slot counterpart of
    /// [`ServingLoop::next_wake`]. None = busy (its `BatchDone` is the
    /// next event) or empty (nothing to wake for).
    pub(crate) fn slot_wake(&self, w: WorkerId, now: Micros) -> Option<Micros> {
        let slot = &self.cluster.slots[w];
        if slot.inflight.is_some() || slot.sched.pending() == 0 {
            return None;
        }
        Some(
            slot.sched
                .wake_hint(now)
                .filter(|&h| h > now)
                .or_else(|| slot.sched.earliest_deadline().filter(|&d| d > now))
                .unwrap_or(now + 1_000),
        )
    }

    /// Decompose a freshly built loop into the parts the sharded pump
    /// re-assembles per shard. Only valid before any event was delivered
    /// (shards must start from virgin replicas) and only for
    /// [`ServingLoop::parallel_safe`] configurations.
    pub(crate) fn into_shard_parts(self) -> (C, Vec<S>, Placement, Box<dyn Router>) {
        assert!(
            self.completions.is_empty(),
            "sharding must start from an un-driven loop"
        );
        let (scheds, placement) = self.cluster.into_parts();
        (self.clock, scheds, placement, self.router)
    }

    /// Final drop sweep (call once when the pump decides the run is over).
    /// Flushes the best-effort lane too: still-parked downgrades terminate
    /// unserved, so completion conservation stays exact.
    pub fn drain_all(&mut self) {
        let now = self.clock.now();
        for w in 0..self.cluster.len() {
            self.drain_dropped(w, now);
        }
        let leftover = match self.admission.as_mut() {
            Some(ctl) => ctl.drain_best_effort(),
            None => Vec::new(),
        };
        for r in leftover {
            if let Some(tel) = self.telemetry.as_mut() {
                tel.record(
                    now,
                    EventKind::Terminal {
                        req: r.id,
                        outcome: Outcome::TimedOut,
                        worker: None,
                    },
                );
            }
            self.completions.push(Completion {
                request: r,
                outcome: Outcome::TimedOut,
                at: now,
                batch_size: 0,
                worker: None,
                best_effort: true,
            });
        }
    }

    /// Completions recorded so far.
    pub fn completions(&self) -> &[Completion] {
        &self.completions
    }

    /// Consume the loop, yielding completions and per-replica counters.
    pub fn into_completions(self) -> (Vec<Completion>, Vec<WorkerStats>) {
        let stats = self
            .cluster
            .slots
            .iter()
            .enumerate()
            .map(|(w, s)| WorkerStats {
                worker: w,
                batches: s.batches,
                busy_us: s.busy_us,
            })
            .collect();
        (self.completions, stats)
    }

    /// Once per telemetry window (gated by the recorder), sample queue
    /// depth per worker and backlog per model. One branch when disabled.
    fn sample_telemetry(&mut self, now: Micros) {
        let due = match self.telemetry.as_mut() {
            Some(tel) => tel.sample_due(now),
            None => false,
        };
        if !due {
            return;
        }
        for w in 0..self.cluster.len() {
            let pending = self.cluster.slots[w].sched.pending() as u32;
            if let Some(tel) = self.telemetry.as_mut() {
                tel.record(
                    now,
                    EventKind::QueueSample {
                        worker: w as u32,
                        pending,
                    },
                );
            }
        }
        // Index-based iteration: `model_at` returns by value, so the
        // recorder is free to be borrowed mutably again for the record.
        let n_models = self.telemetry.as_ref().map_or(0, |t| t.models_len());
        for i in 0..n_models {
            let m = match self.telemetry.as_ref() {
                Some(tel) => tel.model_at(i),
                None => continue,
            };
            let pending: usize = self
                .cluster
                .slots
                .iter()
                .map(|s| s.sched.pending_for(m))
                .sum();
            if let Some(tel) = self.telemetry.as_mut() {
                tel.record(
                    now,
                    EventKind::ModelBacklog {
                        model: m,
                        pending: pending as u32,
                    },
                );
            }
        }
    }

    /// Run the placement controller (elastic runs only): apply unloads
    /// (evict + drain + re-route) and emit load dispatches.
    fn control_placement(&mut self, now: Micros, out: &mut Vec<Dispatch>) {
        let Some(mut el) = self.elastic.take() else {
            return;
        };
        if now >= el.ctl.next_decision_at() {
            let views = self.worker_views();
            for a in el.ctl.actions(now, &views) {
                if el.stats.loads + el.stats.unloads == 0 {
                    el.stats.first_action_at = now;
                }
                match a {
                    PlacementAction::Load { worker, model } => {
                        let cost_ms = el.ctl.cold_start().load_ms(model);
                        debug_assert!(
                            self.cluster.slots[worker].loading.is_none(),
                            "worker {worker} already has a load in flight"
                        );
                        self.cluster.slots[worker].loading = Some(model);
                        el.stats.loads += 1;
                        el.stats.last_action_at = now;
                        if let Some(tel) = self.telemetry.as_mut() {
                            tel.record(
                                now,
                                EventKind::Load {
                                    worker: worker as u32,
                                    model,
                                    cost_ms,
                                },
                            );
                        }
                        out.push(Dispatch::Load {
                            worker,
                            model,
                            cost_ms,
                        });
                    }
                    PlacementAction::Unload { worker, model } => {
                        // Applied immediately: dropping weights is cheap
                        // next to loading them. The drained queue goes
                        // back through the router, not to the floor.
                        self.cluster.placement.evict(worker, model);
                        let evicted = self.cluster.slots[worker].sched.evict_model(model);
                        el.stats.unloads += 1;
                        el.stats.last_action_at = now;
                        el.stats.rerouted += evicted.len();
                        if let Some(tel) = self.telemetry.as_mut() {
                            tel.record(
                                now,
                                EventKind::Unload {
                                    worker: worker as u32,
                                    model,
                                },
                            );
                        }
                        for r in evicted {
                            self.route(r, now);
                        }
                        out.push(Dispatch::Unload { worker, model });
                    }
                }
            }
        }
        self.elastic = Some(el);
    }

    /// Per-worker snapshot for the controller.
    fn worker_views(&self) -> Vec<WorkerView> {
        self.cluster
            .slots
            .iter()
            .enumerate()
            .map(|(w, s)| {
                let hosted: Vec<ModelId> = self
                    .cluster
                    .placement
                    .hosted_on(w)
                    .map(|h| h.to_vec())
                    .unwrap_or_default();
                let queued: Vec<usize> =
                    hosted.iter().map(|&m| s.sched.pending_for(m)).collect();
                WorkerView {
                    worker: w,
                    hosted,
                    loading: s.loading,
                    queued,
                }
            })
            .collect()
    }

    /// A model load completed: the replica becomes routable for `model`,
    /// and the scheduler is told so it can create the model's queue state
    /// and charge the *measured* cold start into its first batch's SLO
    /// math.
    fn placement_done(&mut self, w: WorkerId, model: ModelId, load_ms: f64, now: Micros) {
        let slot = &mut self.cluster.slots[w];
        let Some(loading_model) = slot.loading.take() else {
            debug_assert!(false, "PlacementDone for worker {w} with no load in flight");
            return;
        };
        debug_assert_eq!(
            loading_model, model,
            "PlacementDone model mismatch on worker {w}"
        );
        slot.sched.install_model(model, load_ms, now);
        self.cluster.placement.install(w, model);
        if let Some(tel) = self.telemetry.as_mut() {
            tel.record(
                now,
                EventKind::LoadDone {
                    worker: w as u32,
                    model,
                    load_ms,
                },
            );
        }
    }

    /// Book a finished batch: label outcomes against deadlines, account
    /// busy time, feed the measured latency back to the scheduler.
    fn finish(&mut self, w: WorkerId, batch_ms: f64, now: Micros) {
        let slot = &mut self.cluster.slots[w];
        let Some(f) = slot.inflight.take() else {
            debug_assert!(false, "BatchDone for idle worker {w}");
            return;
        };
        let bs = f.batch.len();
        if let Some(tel) = self.telemetry.as_mut() {
            if let Some(b) = f.telemetry_batch {
                tel.record(
                    now,
                    EventKind::BatchDone {
                        batch: b,
                        worker: w as u32,
                        batch_ms,
                    },
                );
            }
        }
        for r in &f.batch {
            let outcome = if now <= r.deadline {
                Outcome::Finished
            } else {
                Outcome::Late
            };
            if let Some(tel) = self.telemetry.as_mut() {
                tel.record(
                    now,
                    EventKind::Terminal {
                        req: r.id,
                        outcome,
                        worker: Some(w as u32),
                    },
                );
            }
            self.completions.push(Completion {
                request: r.clone(),
                outcome,
                at: now,
                batch_size: bs,
                worker: Some(w),
                best_effort: f.best_effort,
            });
        }
        // Busy time is the *execution* time, not dispatch-to-completion
        // wall time: with elastic loads serializing ahead of a batch on
        // the worker, the wall interval would book the load wait as batch
        // busy time and inflate utilization. In a static replay the two
        // are identical (BatchDone lands exactly dispatch + batch_ms).
        slot.busy_us += crate::clock::ms_to_us(batch_ms);
        slot.batches += 1;
        if !f.best_effort {
            // Best-effort batches bypass the scheduler entirely — feeding
            // their latency back would pollute its online profile (AIMD
            // targets, Welford means, Orloj's per-class histograms) with
            // traffic it never planned.
            slot.sched.on_batch_complete(&f.batch, batch_ms, now);
        }
        self.drain_dropped(w, now);
    }

    /// If replica `w` is idle, ask its scheduler for a batch — repeating
    /// while the scheduler's state changes (e.g. Clockwork aborting a
    /// planned batch frees it to plan another immediately). Only when the
    /// SLO lane has truly nothing does the admission controller's
    /// best-effort lane get the worker (DESIGN.md §10: best-effort work
    /// never delays admitted work).
    fn dispatch_from(&mut self, w: WorkerId, now: Micros) -> Option<Dispatch> {
        if self.cluster.slots[w].inflight.is_some() {
            return None;
        }
        loop {
            match self.cluster.slots[w].sched.next_batch(now) {
                Some(batch) => {
                    debug_assert!(
                        batch.iter().all(|r| r.model == batch[0].model),
                        "scheduler {w} formed a mixed-model batch"
                    );
                    debug_assert!(
                        batch
                            .first()
                            .map(|r| self.cluster.placement.hosts(w, r.model))
                            .unwrap_or(true),
                        "worker {w} dispatched a batch for a model it does not host"
                    );
                    return Some(self.install_dispatch(w, batch, false, now));
                }
                None => {
                    if self.drain_dropped(w, now) {
                        continue;
                    }
                    // SLO lane idle: offer the slot to the best-effort
                    // lane (model-pure FIFO over the models `w` hosts).
                    let be = match self.admission.as_mut() {
                        Some(ctl) => {
                            let placement = &self.cluster.placement;
                            ctl.next_best_effort(|m| placement.hosts(w, m))
                        }
                        None => None,
                    };
                    return be.map(|batch| self.install_dispatch(w, batch, true, now));
                }
            }
        }
    }

    /// Record a batch's formation (telemetry) and install it as `w`'s
    /// in-flight work, yielding the pump's dispatch.
    fn install_dispatch(
        &mut self,
        w: WorkerId,
        batch: Vec<Request>,
        best_effort: bool,
        now: Micros,
    ) -> Dispatch {
        let telemetry_batch = match self.telemetry.as_mut() {
            Some(tel) => {
                let id = tel.begin_batch(w);
                // The scheduler stored its prediction for this batch when
                // forming it; a policy that does not predict — and the
                // best-effort lane, which bypasses the scheduler — reports
                // a zero-width nothing.
                let (pm, lo, hi) = if best_effort {
                    (0.0, 0.0, 0.0)
                } else {
                    match self.cluster.slots[w].sched.last_batch_prediction() {
                        Some(p) => (p.ms, p.lo_ms, p.hi_ms),
                        None => (0.0, 0.0, 0.0),
                    }
                };
                tel.record(
                    now,
                    EventKind::BatchFormed {
                        batch: id,
                        worker: w as u32,
                        model: batch[0].model,
                        app: batch[0].app,
                        size: batch.len() as u32,
                        predicted_ms: pm,
                        lo_ms: lo,
                        hi_ms: hi,
                    },
                );
                for r in &batch {
                    tel.record(
                        now,
                        EventKind::InBatch {
                            req: r.id,
                            batch: id,
                        },
                    );
                }
                Some(id)
            }
            None => None,
        };
        self.cluster.slots[w].inflight = Some(InFlight {
            batch: batch.clone(),
            telemetry_batch,
            best_effort,
        });
        Dispatch::Execute { worker: w, batch }
    }

    /// Record replica `w`'s scheduler-side drops; true if any.
    fn drain_dropped(&mut self, w: WorkerId, now: Micros) -> bool {
        let dropped = self.cluster.slots[w].sched.drain_dropped();
        let any = !dropped.is_empty();
        for (r, outcome) in dropped {
            if let Some(tel) = self.telemetry.as_mut() {
                tel.record(
                    now,
                    EventKind::Terminal {
                        req: r.id,
                        outcome,
                        worker: None,
                    },
                );
            }
            self.completions.push(Completion {
                request: r,
                outcome,
                at: now,
                batch_size: 0,
                worker: None,
                best_effort: false,
            });
        }
        any
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::edf::EdfScheduler;
    use crate::clock::{ms_to_us, VirtualClock};
    use crate::core::batchmodel::BatchCostModel;
    use crate::core::request::AppId;

    fn cfg() -> SchedulerConfig {
        SchedulerConfig {
            cost_model: BatchCostModel::new(0.0, 1.0),
            ..Default::default()
        }
    }

    fn sched() -> EdfScheduler {
        let mut s = EdfScheduler::new(cfg(), 0);
        s.seed_exec_mean(10.0);
        s
    }

    fn req(id: u64, release: Micros) -> Request {
        Request::new(id, AppId(0), release, ms_to_us(500.0), 10.0)
    }

    #[test]
    fn arrival_routes_then_wake_dispatches() {
        let clock = VirtualClock::new();
        let cluster = Cluster::new(vec![sched(), sched()]);
        let mut core = ServingLoop::new(
            clock.clone(),
            cluster,
            router::by_name("round_robin").unwrap(),
        );
        assert!(core.on_event(Event::Arrival(req(0, 0))).is_empty());
        assert!(core.on_event(Event::Arrival(req(1, 0))).is_empty());
        assert_eq!(core.pending(), 2);
        let ds = core.on_event(Event::Wake);
        // Round-robin put one request on each replica → two dispatches.
        assert_eq!(ds.len(), 2);
        assert_eq!(core.in_flight(), 2);
        assert_eq!(core.pending(), 0);
        assert_eq!(core.loading(), 0);
    }

    #[test]
    fn batch_done_labels_outcomes_and_counts() {
        let clock = VirtualClock::new();
        let cluster = Cluster::new(vec![sched()]);
        let mut core =
            ServingLoop::new(clock.clone(), cluster, router::by_name("round_robin").unwrap());
        core.on_event(Event::Arrival(req(0, 0)));
        let ds = core.on_event(Event::Wake);
        assert_eq!(ds.len(), 1);
        clock.advance_to(ms_to_us(10.0));
        core.on_event(Event::BatchDone {
            worker: 0,
            batch_ms: 10.0,
        });
        let (completions, stats) = core.into_completions();
        assert_eq!(completions.len(), 1);
        assert_eq!(completions[0].outcome, Outcome::Finished);
        assert_eq!(completions[0].worker, Some(0));
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].batches, 1);
        assert_eq!(stats[0].busy_us, ms_to_us(10.0));
        assert!((stats[0].utilization(ms_to_us(10.0)) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn cluster_build_makes_n_replicas() {
        let c = Cluster::build("orloj", &SchedulerConfig::default(), 7, 4).unwrap();
        assert_eq!(c.len(), 4);
        assert!(Cluster::build("no-such-system", &SchedulerConfig::default(), 7, 2).is_none());
    }

    #[test]
    fn placement_constrains_routing() {
        let clock = VirtualClock::new();
        // Worker 0 hosts model 0, worker 1 hosts model 1.
        let placement = Placement::parse("partition", 2, 2).unwrap();
        let cluster = Cluster::with_placement(vec![sched(), sched()], placement);
        let mut core = ServingLoop::new(
            clock.clone(),
            cluster,
            router::by_name("least_loaded").unwrap(),
        );
        for i in 0..4u64 {
            let model = ModelId((i % 2) as u32);
            core.on_event(Event::Arrival(req(i, 0).with_model(model)));
        }
        let ds = core.on_event(Event::Wake);
        assert_eq!(ds.len(), 2);
        for d in &ds {
            let Dispatch::Execute { worker, batch } = d else {
                panic!("static run produced a placement dispatch: {d:?}");
            };
            for r in batch {
                assert!(
                    core.placement().hosts(*worker, r.model),
                    "worker {} got model {:?}",
                    worker,
                    r.model
                );
            }
        }
    }

    fn elastic_cfg() -> ElasticConfig {
        ElasticConfig {
            capacity: 2,
            interval_us: 1,
            alpha: 1.0,
            min_dwell_us: 0,
            cold_start: ColdStartCost::new(5.0, 5.0),
        }
    }

    #[test]
    fn elastic_load_becomes_routable_only_after_done() {
        let clock = VirtualClock::new();
        let placement = Placement::parse("partition", 2, 2).unwrap();
        let cluster = Cluster::with_placement(vec![sched(), sched()], placement);
        let mut core = ServingLoop::new(
            clock.clone(),
            cluster,
            router::by_name("least_loaded").unwrap(),
        )
        .with_elastic(PlacementController::new(elastic_cfg()));
        assert!(core.elastic_enabled());
        // Heavy model-0 demand: the controller should replicate model 0
        // onto worker 1 (capacity 2 leaves room next to model 1).
        for i in 0..6 {
            core.on_event(Event::Arrival(req(i, 0)));
        }
        let ds = core.on_event(Event::Wake);
        assert!(
            ds.iter().any(|d| matches!(
                d,
                Dispatch::Load { worker: 1, model: ModelId(0), .. }
            )),
            "expected a load of model 0 onto worker 1: {ds:?}"
        );
        assert_eq!(core.loading(), 1);
        assert!(
            !core.placement().hosts(1, ModelId(0)),
            "warming replica must not be routable yet"
        );
        core.on_event(Event::PlacementDone {
            worker: 1,
            model: ModelId(0),
            load_ms: 10.0,
        });
        assert_eq!(core.loading(), 0);
        assert!(core.placement().hosts(1, ModelId(0)));
        let stats = core.placement_stats();
        assert_eq!(stats.loads, 1);
        assert_eq!(stats.unloads, 0);
    }

    #[test]
    fn evict_drains_back_through_the_router() {
        let clock = VirtualClock::new();
        // Three workers: w0 hosts model 0; w1 and w2 host model 1.
        let placement = Placement::parse("0;1;1", 3, 2).unwrap();
        let cluster = Cluster::with_placement(vec![sched(), sched(), sched()], placement);
        let mut cfg = elastic_cfg();
        cfg.capacity = 1;
        let mut core = ServingLoop::new(
            clock.clone(),
            cluster,
            router::by_name("least_loaded").unwrap(),
        )
        .with_elastic(PlacementController::new(cfg));
        // Model-1 backlog spread over w1/w2, plus dominant model-0 demand
        // → the controller reclaims one model-1 replica for model 0,
        // draining its queue back through the router.
        for i in 0..5u64 {
            core.on_event(Event::Arrival(req(i, 0).with_model(ModelId(1))));
        }
        for i in 5..15u64 {
            core.on_event(Event::Arrival(req(i, 0)));
        }
        let total = 15usize;
        let ds = core.on_event(Event::Wake);
        let stats = core.placement_stats();
        assert_eq!(stats.unloads, 1, "{ds:?}");
        assert!(stats.rerouted >= 1, "evicted queue must be re-routed");
        assert!(
            ds.iter()
                .any(|d| matches!(d, Dispatch::Unload { model: ModelId(1), .. })),
            "pump must see the unload: {ds:?}"
        );
        // Conservation: everything is still queued, in flight, or
        // completed — nothing fell on the floor during the re-route.
        let dispatched: usize = ds.iter().map(|d| batch_len(d)).sum();
        assert_eq!(
            core.pending() + dispatched + core.completions().len(),
            total
        );
        // Model 1 still has a ready host.
        assert!(core.placement().hosts_anywhere(ModelId(1)));
    }

    fn batch_len(d: &Dispatch) -> usize {
        match d {
            Dispatch::Execute { batch, .. } => batch.len(),
            _ => 0,
        }
    }

    #[test]
    fn static_wake_emits_no_placement_dispatches() {
        let clock = VirtualClock::new();
        let cluster = Cluster::new(vec![sched(), sched()]);
        let mut core = ServingLoop::new(
            clock.clone(),
            cluster,
            router::by_name("round_robin").unwrap(),
        );
        for i in 0..8 {
            core.on_event(Event::Arrival(req(i, 0)));
        }
        for d in core.on_event(Event::Wake) {
            assert!(
                matches!(d, Dispatch::Execute { .. }),
                "static run produced {d:?}"
            );
        }
        assert_eq!(core.placement_stats().actions(), 0);
    }
}
