//! Cluster placement: which workers host which models, and the elastic
//! controller that changes the answer at runtime (DESIGN.md §3, §8).
//!
//! Production clusters multiplex many models across their workers the way
//! Clockwork does per-model placement; a [`Placement`] records the
//! worker→models assignment the router must respect — an arrival is only
//! ever routed to a worker hosting its model. The default
//! ([`Placement::unconstrained`]) hosts every model everywhere, which is
//! exactly the historical single-model behaviour.
//!
//! Under *elastic* placement the assignment is live: a
//! [`PlacementController`] tracks per-model demand (arrival counts plus
//! router-side queue-depth snapshots), decides `Load`/`Unload` actions
//! under a per-worker capacity budget, and models each load as a
//! Clockwork-style cold start ([`ColdStartCost`]: fixed fetch plus
//! per-weight transfer). A warming worker is not routed to until its
//! load completes (`serve::Event::PlacementDone`); an eviction drains the
//! model's queued requests back to the router for re-routing rather than
//! dropping them (the evict-drain invariant, DESIGN.md §8).

use crate::clock::Micros;
use crate::core::request::ModelId;

/// Worker→models assignment for a cluster.
#[derive(Debug, Clone)]
pub struct Placement {
    workers: usize,
    /// `hosted[w]` = sorted model ids on worker `w`. Empty outer vec =
    /// unconstrained (every worker hosts every model).
    hosted: Vec<Vec<ModelId>>,
}

/// The named placement presets `parse` accepts, in documentation order.
pub const PLACEMENTS: [&str; 3] = ["all", "partition", "skewed"];

impl Placement {
    /// Every worker hosts every model (single-model clusters, and the
    /// default when no placement is configured).
    pub fn unconstrained(workers: usize) -> Self {
        Placement {
            workers: workers.max(1),
            hosted: Vec::new(),
        }
    }

    /// Explicit per-worker model lists. Panics if empty.
    pub fn new(hosted: Vec<Vec<ModelId>>) -> Self {
        assert!(!hosted.is_empty(), "a placement needs at least one worker");
        let mut hosted = hosted;
        for ms in &mut hosted {
            ms.sort_unstable();
            ms.dedup();
        }
        Placement {
            workers: hosted.len(),
            hosted,
        }
    }

    /// Build a placement from a spec string, for models `0..models`:
    ///
    /// * `all` — every worker hosts every model;
    /// * `partition` — disjoint-ish round-robin: worker `w` hosts model
    ///   `w % models`, and model `m` is guaranteed a host on worker
    ///   `m % workers`;
    /// * `skewed` — model 0 (the hot model) is hosted everywhere; each
    ///   model `m > 0` only on worker `m % workers`;
    /// * explicit `"0,1;1;0"` — semicolon-separated per-worker model
    ///   lists (must name exactly `workers` groups; a model may appear
    ///   at most once per group — duplicates would silently double-count
    ///   against capacity budgets).
    ///
    /// Returns None for an unknown spec, a malformed explicit list, or an
    /// explicit list that leaves some model `< models` unhosted; see
    /// [`Placement::parse_checked`] for the error message.
    pub fn parse(spec: &str, workers: usize, models: usize) -> Option<Placement> {
        Self::parse_checked(spec, workers, models).ok()
    }

    /// [`Placement::parse`] with a human-readable rejection reason.
    pub fn parse_checked(spec: &str, workers: usize, models: usize) -> Result<Placement, String> {
        let (workers, models) = (workers.max(1), models.max(1));
        let hosted: Vec<Vec<ModelId>> = match spec {
            "all" => (0..workers)
                .map(|_| (0..models).map(|m| ModelId(m as u32)).collect())
                .collect(),
            "partition" => {
                let mut hosted: Vec<Vec<ModelId>> =
                    (0..workers).map(|w| vec![ModelId((w % models) as u32)]).collect();
                for m in 0..models {
                    hosted[m % workers].push(ModelId(m as u32));
                }
                hosted
            }
            "skewed" => {
                let mut hosted: Vec<Vec<ModelId>> =
                    (0..workers).map(|_| vec![ModelId(0)]).collect();
                for m in 1..models {
                    hosted[m % workers].push(ModelId(m as u32));
                }
                hosted
            }
            explicit => {
                let groups: Vec<&str> = explicit.split(';').collect();
                if groups.len() != workers {
                    return Err(format!(
                        "placement '{explicit}' names {} worker group(s), cluster has {workers}",
                        groups.len()
                    ));
                }
                let mut hosted = Vec::with_capacity(workers);
                for (w, g) in groups.iter().enumerate() {
                    let mut ms: Vec<ModelId> = Vec::new();
                    for tok in g.split(',').map(str::trim).filter(|t| !t.is_empty()) {
                        let id = tok.parse::<u32>().map_err(|_| {
                            format!("placement '{explicit}': worker {w} lists bad model id '{tok}'")
                        })?;
                        if ms.contains(&ModelId(id)) {
                            return Err(format!(
                                "placement '{explicit}': worker {w} lists model {id} more than \
                                 once — duplicates would double-count against the capacity budget"
                            ));
                        }
                        ms.push(ModelId(id));
                    }
                    hosted.push(ms);
                }
                hosted
            }
        };
        let p = Placement::new(hosted);
        // Every model must be hosted somewhere, or its requests could
        // never be served.
        for m in 0..models {
            if !p.hosts_anywhere(ModelId(m as u32)) {
                return Err(format!("placement '{spec}' leaves model {m} unhosted"));
            }
        }
        Ok(p)
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Does worker `w` host `model`?
    pub fn hosts(&self, w: usize, model: ModelId) -> bool {
        if self.hosted.is_empty() {
            return w < self.workers;
        }
        self.hosted.get(w).is_some_and(|ms| ms.contains(&model))
    }

    /// Does any worker host `model`?
    pub fn hosts_anywhere(&self, model: ModelId) -> bool {
        self.hosted.is_empty() || self.hosted.iter().any(|ms| ms.contains(&model))
    }

    /// Models hosted on worker `w` (None = unconstrained, i.e. all).
    pub fn hosted_on(&self, w: usize) -> Option<&[ModelId]> {
        if self.hosted.is_empty() {
            None
        } else {
            self.hosted.get(w).map(|v| v.as_slice())
        }
    }

    /// Every model named by the placement, sorted (empty when
    /// unconstrained — the model set is open).
    pub fn models(&self) -> Vec<ModelId> {
        let mut all: Vec<ModelId> = self.hosted.iter().flatten().copied().collect();
        all.sort_unstable();
        all.dedup();
        all
    }

    /// True for [`Placement::unconstrained`] placements (no explicit
    /// worker→models lists; elastic control needs an explicit one).
    pub fn is_unconstrained(&self) -> bool {
        self.hosted.is_empty()
    }

    /// Number of models hosted on worker `w` (0 when unconstrained — the
    /// model set is open, so capacity budgets do not apply).
    pub fn hosted_count(&self, w: usize) -> usize {
        self.hosted.get(w).map_or(0, |ms| ms.len())
    }

    /// Install `model` on worker `w` (elastic placement; no-op when
    /// already hosted). Panics on an unconstrained placement — it has no
    /// per-worker lists to mutate; parse an explicit one first.
    pub fn install(&mut self, w: usize, model: ModelId) {
        assert!(
            !self.hosted.is_empty(),
            "cannot mutate an unconstrained placement"
        );
        let ms = &mut self.hosted[w];
        if let Err(pos) = ms.binary_search(&model) {
            ms.insert(pos, model);
        }
    }

    /// Remove `model` from worker `w` (elastic placement; no-op when not
    /// hosted). Panics on an unconstrained placement.
    pub fn evict(&mut self, w: usize, model: ModelId) {
        assert!(
            !self.hosted.is_empty(),
            "cannot mutate an unconstrained placement"
        );
        if let Some(ms) = self.hosted.get_mut(w) {
            ms.retain(|m| *m != model);
        }
    }
}

// ---------------------------------------------------------------------
// Elastic placement: cold-start cost model + controller (DESIGN.md §8)
// ---------------------------------------------------------------------

/// Clockwork-style model-load cost curve: a fixed fetch latency plus a
/// per-weight-unit transfer term. Weight units default to 1.0 per model
/// (override per model for heterogeneous fleets).
#[derive(Debug, Clone)]
pub struct ColdStartCost {
    /// Fixed fetch/setup latency per load (ms).
    pub fetch_ms: f64,
    /// Transfer latency per weight unit (ms).
    pub per_weight_ms: f64,
    /// Per-model weight units (unlisted models weigh 1.0).
    weights: Vec<(u32, f64)>,
}

impl ColdStartCost {
    pub fn new(fetch_ms: f64, per_weight_ms: f64) -> Self {
        assert!(fetch_ms >= 0.0 && per_weight_ms >= 0.0);
        ColdStartCost {
            fetch_ms,
            per_weight_ms,
            weights: Vec::new(),
        }
    }

    /// Override one model's weight units.
    pub fn with_weight(mut self, model: ModelId, units: f64) -> Self {
        assert!(units >= 0.0);
        match self.weights.iter_mut().find(|(m, _)| *m == model.0) {
            Some((_, u)) => *u = units,
            None => self.weights.push((model.0, units)),
        }
        self
    }

    /// Weight units of one model (1.0 unless overridden).
    pub fn weight(&self, model: ModelId) -> f64 {
        self.weights
            .iter()
            .find(|(m, _)| *m == model.0)
            .map_or(1.0, |(_, u)| *u)
    }

    /// Predicted load latency for one model (ms).
    pub fn load_ms(&self, model: ModelId) -> f64 {
        self.fetch_ms + self.per_weight_ms * self.weight(model)
    }
}

impl Default for ColdStartCost {
    /// ~200 ms per load: 50 ms fetch + 150 ms transfer per weight unit
    /// (the order of magnitude Clockwork reports for PCIe model loads).
    fn default() -> Self {
        ColdStartCost::new(50.0, 150.0)
    }
}

/// Elastic-controller knobs.
#[derive(Debug, Clone)]
pub struct ElasticConfig {
    /// Max models per worker, counting warming loads (0 = unlimited).
    pub capacity: usize,
    /// Controller decision interval (µs). Decisions piggyback on serve
    /// events (`Wake`), so the effective cadence is `max(interval,
    /// inter-event gap)`.
    pub interval_us: Micros,
    /// EWMA weight of the newest demand observation (0..1].
    pub alpha: f64,
    /// Minimum dwell after a load before the same (worker, model) pair
    /// may be unloaded (anti-thrash hysteresis, µs).
    pub min_dwell_us: Micros,
    /// Cold-start cost curve for loads.
    pub cold_start: ColdStartCost,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        ElasticConfig {
            capacity: 2,
            interval_us: 500_000,
            alpha: 0.4,
            min_dwell_us: 2_000_000,
            cold_start: ColdStartCost::default(),
        }
    }
}

/// One placement action the serving core must apply/dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementAction {
    /// Begin loading `model` onto `worker` (completes asynchronously with
    /// `Event::PlacementDone` after the cold-start latency).
    Load { worker: usize, model: ModelId },
    /// Remove `model` from `worker` immediately, draining its queued
    /// requests back to the router.
    Unload { worker: usize, model: ModelId },
}

/// Per-worker snapshot the controller decides over (built by the serving
/// core; `queued[i]` is the queue depth of `hosted[i]`).
#[derive(Debug, Clone)]
pub struct WorkerView {
    pub worker: usize,
    /// Ready (routing-visible) models.
    pub hosted: Vec<ModelId>,
    /// Model currently warming on this worker, if any (≤1 load in flight
    /// per worker).
    pub loading: Option<ModelId>,
    /// Queued requests per hosted model, aligned with `hosted`.
    pub queued: Vec<usize>,
}

impl WorkerView {
    fn queued_of(&self, model: ModelId) -> usize {
        self.hosted
            .iter()
            .position(|m| *m == model)
            .map_or(0, |i| self.queued[i])
    }

    fn total_queued(&self) -> usize {
        self.queued.iter().sum()
    }
}

/// The live placement controller (DESIGN.md §8).
///
/// Demand per model is an EWMA over decision intervals of `arrivals in
/// the window + queued backlog` (the backlog term is the miss-pressure
/// feedback: a model whose queues grow is under-replicated even at a
/// steady arrival rate). Desired replica counts are a D'Hondt
/// apportionment of the `workers × capacity` slot budget over the demand
/// shares — every known model keeps at least one replica, the rest of
/// the budget follows demand. The diff against the current hosting emits
/// `Unload`s first (freeing budget), then `Load`s onto the emptiest
/// eligible workers. Invariants:
///
/// * a model is never unloaded below one *ready* (non-warming) replica;
/// * at most one load is in flight per worker;
/// * a pair loaded less than `min_dwell_us` ago is not unloaded;
/// * with zero observed demand the controller holds still (no actions on
///   startup before traffic shapes the EWMA).
///
/// All tie-breaks are deterministic (model id, worker index), so elastic
/// runs stay replayable.
pub struct PlacementController {
    cfg: ElasticConfig,
    /// EWMA demand per model, kept sorted by model id.
    demand: Vec<(ModelId, f64)>,
    /// Arrivals per model since the last decision.
    window: Vec<(ModelId, u64)>,
    /// (worker, model, installed_at) dwell records for loads we issued.
    installed: Vec<(usize, ModelId, Micros)>,
    next_decision: Micros,
}

impl PlacementController {
    pub fn new(cfg: ElasticConfig) -> Self {
        assert!(cfg.alpha > 0.0 && cfg.alpha <= 1.0, "alpha in (0, 1]");
        PlacementController {
            cfg,
            demand: Vec::new(),
            window: Vec::new(),
            installed: Vec::new(),
            next_decision: 0,
        }
    }

    pub fn config(&self) -> &ElasticConfig {
        &self.cfg
    }

    pub fn cold_start(&self) -> &ColdStartCost {
        &self.cfg.cold_start
    }

    /// Earliest time `actions` will do anything (cheap pre-gate so the
    /// serving core does not build views on every wake).
    pub fn next_decision_at(&self) -> Micros {
        self.next_decision
    }

    /// Count one arrival of `model` into the demand window.
    pub fn note_arrival(&mut self, model: ModelId) {
        match self.window.iter_mut().find(|(m, _)| *m == model) {
            Some((_, c)) => *c += 1,
            None => self.window.push((model, 1)),
        }
    }

    /// Fold the window into the EWMA demand table.
    fn update_demand(&mut self, views: &[WorkerView]) {
        let mut models: Vec<ModelId> = views
            .iter()
            .flat_map(|v| v.hosted.iter().copied())
            .chain(views.iter().filter_map(|v| v.loading))
            .chain(self.window.iter().map(|(m, _)| *m))
            .chain(self.demand.iter().map(|(m, _)| *m))
            .collect();
        models.sort_unstable();
        models.dedup();
        for m in models {
            let arr = self
                .window
                .iter()
                .find(|(wm, _)| *wm == m)
                .map_or(0, |(_, c)| *c) as f64;
            let queued: usize = views.iter().map(|v| v.queued_of(m)).sum();
            let obs = arr + queued as f64;
            match self.demand.iter_mut().find(|(dm, _)| *dm == m) {
                Some((_, d)) => *d = self.cfg.alpha * obs + (1.0 - self.cfg.alpha) * *d,
                None => {
                    // Keep the table sorted by id for deterministic scans.
                    let pos = self
                        .demand
                        .partition_point(|(dm, _)| *dm < m);
                    self.demand.insert(pos, (m, self.cfg.alpha * obs));
                }
            }
        }
        self.window.clear();
    }

    fn demand_of(&self, model: ModelId) -> f64 {
        self.demand
            .iter()
            .find(|(m, _)| *m == model)
            .map_or(0.0, |(_, d)| *d)
    }

    /// Whether (worker, model) was loaded too recently to unload.
    fn dwell_blocked(&self, w: usize, m: ModelId, now: Micros) -> bool {
        self.installed.iter().any(|(iw, im, at)| {
            *iw == w && *im == m && now.saturating_sub(*at) < self.cfg.min_dwell_us
        })
    }

    /// D'Hondt apportionment of the slot budget over demand shares:
    /// every model starts at one replica, each further slot goes to the
    /// model maximizing `demand / current`, capped at the worker count.
    fn desired(&self, n_workers: usize) -> Vec<(ModelId, usize)> {
        let k = self.demand.len();
        if k == 0 || n_workers == 0 {
            return Vec::new();
        }
        let cap = if self.cfg.capacity == 0 {
            k
        } else {
            self.cfg.capacity.min(k)
        };
        let slots = n_workers * cap;
        let mut desired: Vec<(ModelId, usize)> =
            self.demand.iter().map(|(m, _)| (*m, 1)).collect();
        let mut used = k.min(slots);
        while used < slots {
            let mut best: Option<(f64, usize)> = None;
            for (i, (m, d)) in desired.iter().enumerate() {
                if *d >= n_workers {
                    continue;
                }
                let score = self.demand_of(*m) / *d as f64;
                if score <= 0.0 {
                    continue;
                }
                // Strictly-greater keeps the lowest model id on ties.
                let better = match best {
                    None => true,
                    Some((bs, _)) => score > bs,
                };
                if better {
                    best = Some((score, i));
                }
            }
            match best {
                Some((_, i)) => {
                    desired[i].1 += 1;
                    used += 1;
                }
                None => break,
            }
        }
        desired
    }

    /// Decide the placement actions for this instant. No-op before the
    /// next decision interval or while demand is all-zero.
    pub fn actions(&mut self, now: Micros, views: &[WorkerView]) -> Vec<PlacementAction> {
        if now < self.next_decision {
            return Vec::new();
        }
        debug_assert!(
            views.iter().enumerate().all(|(i, v)| v.worker == i),
            "worker views must be dense and ordered by worker id"
        );
        self.next_decision = now + self.cfg.interval_us.max(1);
        self.update_demand(views);
        if self.demand.iter().all(|(_, d)| *d <= 1e-9) {
            return Vec::new(); // no signal yet — hold the placement still
        }
        let n = views.len();
        let desired = self.desired(n);
        let cap = if self.cfg.capacity == 0 {
            usize::MAX
        } else {
            self.cfg.capacity
        };
        let mut acts = Vec::new();
        // Effective per-worker hosted counts as this round's actions land.
        let mut eff_count: Vec<usize> = views
            .iter()
            .map(|v| v.hosted.len() + v.loading.is_some() as usize)
            .collect();
        let mut load_busy: Vec<bool> = views.iter().map(|v| v.loading.is_some()).collect();
        // Hosting sets mutated by this round's own actions.
        let mut ready: Vec<Vec<usize>> = Vec::with_capacity(desired.len());
        for (m, _) in &desired {
            ready.push(
                views
                    .iter()
                    .filter(|v| v.hosted.contains(m))
                    .map(|v| v.worker)
                    .collect(),
            );
        }

        // Unloads first: free budget before placing loads.
        for (mi, (m, want)) in desired.iter().enumerate() {
            let warming = views.iter().filter(|v| v.loading == Some(*m)).count();
            let mut cur = ready[mi].len() + warming;
            if cur <= *want {
                continue;
            }
            // Candidates: ready hosts past their dwell, cheapest drain
            // first (fewest queued of m, then highest worker index so
            // low-index workers keep stable hosting).
            let mut cands: Vec<(usize, usize)> = ready[mi]
                .iter()
                .filter(|&&w| !self.dwell_blocked(w, *m, now))
                .map(|&w| (views[w].queued_of(*m), w))
                .collect();
            cands.sort_by(|a, b| (a.0, std::cmp::Reverse(a.1)).cmp(&(b.0, std::cmp::Reverse(b.1))));
            for (_, w) in cands {
                // Never drop the last ready replica of a model.
                if cur <= *want || ready[mi].len() <= 1 {
                    break;
                }
                acts.push(PlacementAction::Unload { worker: w, model: *m });
                ready[mi].retain(|&rw| rw != w);
                eff_count[w] = eff_count[w].saturating_sub(1);
                self.installed.retain(|(iw, im, _)| !(*iw == w && *im == *m));
                cur -= 1;
            }
        }

        // Loads: highest-demand models pick workers first.
        let mut order: Vec<usize> = (0..desired.len()).collect();
        order.sort_by(|&a, &b| {
            let (da, db) = (self.demand_of(desired[a].0), self.demand_of(desired[b].0));
            db.partial_cmp(&da)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(desired[a].0.cmp(&desired[b].0))
        });
        for mi in order {
            let (m, want) = desired[mi];
            let warming = views.iter().filter(|v| v.loading == Some(m)).count();
            let mut cur = ready[mi].len() + warming;
            while cur < want {
                // Eligible: no load in flight, not hosting m (including
                // hosts this round just kept), free budget. Pick the
                // emptiest worker (count, then queue, then index).
                let unloaded_m_this_round = |w: usize| {
                    acts.iter().any(|a| {
                        matches!(a, PlacementAction::Unload { worker, model }
                                 if *worker == w && *model == m)
                    })
                };
                let mut best: Option<(usize, usize, usize)> = None; // (count, queued, worker)
                for v in views {
                    let w = v.worker;
                    if load_busy[w]
                        || ready[mi].contains(&w)
                        || unloaded_m_this_round(w)
                        || eff_count[w] >= cap
                    {
                        continue;
                    }
                    let key = (eff_count[w], v.total_queued(), w);
                    let better = match best {
                        None => true,
                        Some(b) => key < b,
                    };
                    if better {
                        best = Some(key);
                    }
                }
                let Some((_, _, w)) = best else { break };
                acts.push(PlacementAction::Load { worker: w, model: m });
                load_busy[w] = true;
                eff_count[w] += 1;
                self.installed.push((w, m, now));
                cur += 1;
            }
        }
        acts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unconstrained_hosts_everything() {
        let p = Placement::unconstrained(3);
        assert_eq!(p.workers(), 3);
        assert!(p.hosts(0, ModelId(0)) && p.hosts(2, ModelId(99)));
        assert!(!p.hosts(3, ModelId(0)), "out-of-range worker");
        assert!(p.hosts_anywhere(ModelId(7)));
        assert!(p.models().is_empty());
        assert!(p.hosted_on(1).is_none());
        assert!(p.is_unconstrained());
        assert_eq!(p.hosted_count(0), 0);
    }

    #[test]
    fn parse_all() {
        let p = Placement::parse("all", 2, 3).unwrap();
        for w in 0..2 {
            for m in 0..3 {
                assert!(p.hosts(w, ModelId(m)));
            }
        }
        assert_eq!(p.models(), vec![ModelId(0), ModelId(1), ModelId(2)]);
        assert!(!p.is_unconstrained());
        assert_eq!(p.hosted_count(1), 3);
    }

    #[test]
    fn parse_partition_covers_all_models() {
        for (workers, models) in [(4, 2), (2, 4), (3, 3), (1, 2)] {
            let p = Placement::parse("partition", workers, models).unwrap();
            for m in 0..models {
                assert!(
                    p.hosts_anywhere(ModelId(m as u32)),
                    "partition {workers}x{models}: model {m} unhosted"
                );
            }
            // Disjoint-ish: at least one worker does NOT host model 0 when
            // there are ≥2 of each.
            if workers >= 2 && models >= 2 {
                assert!(
                    (0..workers).any(|w| !p.hosts(w, ModelId(0))),
                    "partition {workers}x{models} degenerated to all"
                );
            }
        }
    }

    #[test]
    fn parse_skewed_hot_model_everywhere() {
        let p = Placement::parse("skewed", 4, 3).unwrap();
        for w in 0..4 {
            assert!(p.hosts(w, ModelId(0)), "hot model must be on worker {w}");
        }
        assert!(p.hosts(1, ModelId(1)) && p.hosts(2, ModelId(2)));
        assert!(!p.hosts(0, ModelId(1)) && !p.hosts(3, ModelId(2)));
    }

    #[test]
    fn parse_explicit_lists() {
        let p = Placement::parse("0,1;1;0", 3, 2).unwrap();
        assert!(p.hosts(0, ModelId(0)) && p.hosts(0, ModelId(1)));
        assert!(p.hosts(1, ModelId(1)) && !p.hosts(1, ModelId(0)));
        assert!(p.hosts(2, ModelId(0)) && !p.hosts(2, ModelId(1)));
        assert_eq!(p.hosted_on(1), Some(&[ModelId(1)][..]));
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(Placement::parse("nope", 2, 2).is_none(), "unknown word");
        assert!(Placement::parse("0;0;0", 2, 1).is_none(), "wrong worker count");
        assert!(Placement::parse("0;0", 2, 2).is_none(), "model 1 unhosted");
        assert!(Placement::parse("0,x;1", 2, 2).is_none(), "bad model id");
    }

    #[test]
    fn parse_rejects_duplicate_models_in_one_group() {
        // Satellite bugfix: "0,0;1" silently deduped before, which would
        // double-count capacity under a budget. Now a hard error.
        assert!(Placement::parse("0,0;1", 2, 2).is_none());
        let err = Placement::parse_checked("0,0;1", 2, 2).unwrap_err();
        assert!(err.contains("more than once"), "unclear error: {err}");
        let err = Placement::parse_checked("0;1,1,0", 2, 2).unwrap_err();
        assert!(err.contains("worker 1"), "should name the group: {err}");
        // Repetition across different workers is fine (that's replication).
        assert!(Placement::parse("0;0,1", 2, 2).is_some());
    }

    #[test]
    fn parse_checked_reports_reasons() {
        assert!(Placement::parse_checked("0;1", 3, 2)
            .unwrap_err()
            .contains("2 worker group(s)"));
        assert!(Placement::parse_checked("0;0", 2, 2)
            .unwrap_err()
            .contains("unhosted"));
    }

    #[test]
    fn install_and_evict_mutate_hosting() {
        let mut p = Placement::parse("partition", 2, 2).unwrap();
        assert!(!p.hosts(0, ModelId(1)));
        p.install(0, ModelId(1));
        assert!(p.hosts(0, ModelId(1)));
        assert_eq!(p.hosted_count(0), 2);
        p.install(0, ModelId(1)); // idempotent
        assert_eq!(p.hosted_count(0), 2);
        p.evict(0, ModelId(0));
        assert!(!p.hosts(0, ModelId(0)));
        assert!(p.hosts_anywhere(ModelId(0)), "worker 1 still hosts it");
        // Hosted lists stay sorted for binary_search.
        p.install(0, ModelId(0));
        assert_eq!(p.hosted_on(0), Some(&[ModelId(0), ModelId(1)][..]));
    }

    #[test]
    #[should_panic(expected = "unconstrained")]
    fn unconstrained_placements_cannot_mutate() {
        Placement::unconstrained(2).install(0, ModelId(0));
    }

    #[test]
    fn cold_start_cost_curve() {
        let c = ColdStartCost::new(50.0, 100.0).with_weight(ModelId(1), 3.0);
        assert!((c.load_ms(ModelId(0)) - 150.0).abs() < 1e-12);
        assert!((c.load_ms(ModelId(1)) - 350.0).abs() < 1e-12);
        assert!((c.weight(ModelId(9)) - 1.0).abs() < 1e-12);
    }

    fn view(worker: usize, hosted: &[u32], queued: &[usize]) -> WorkerView {
        WorkerView {
            worker,
            hosted: hosted.iter().map(|&m| ModelId(m)).collect(),
            loading: None,
            queued: queued.to_vec(),
        }
    }

    fn drained_cfg() -> ElasticConfig {
        ElasticConfig {
            capacity: 1,
            interval_us: 1_000,
            alpha: 1.0,        // no smoothing: decisions follow the window
            min_dwell_us: 0,   // no hysteresis in unit tests
            cold_start: ColdStartCost::new(10.0, 10.0),
        }
    }

    #[test]
    fn controller_holds_still_without_demand() {
        let mut c = PlacementController::new(drained_cfg());
        let views = vec![view(0, &[0], &[0]), view(1, &[1], &[0])];
        assert!(c.actions(0, &views).is_empty(), "no signal, no actions");
    }

    #[test]
    fn controller_shifts_replicas_toward_the_hot_model() {
        // 4 workers × capacity 1, models {0, 1}, demand 9:1 → desired
        // (3, 1): the controller unloads model 1 from one replica and
        // loads model 0 there.
        let mut c = PlacementController::new(drained_cfg());
        for _ in 0..9 {
            c.note_arrival(ModelId(0));
        }
        c.note_arrival(ModelId(1));
        let views = vec![
            view(0, &[0], &[0]),
            view(1, &[1], &[0]),
            view(2, &[0], &[0]),
            view(3, &[1], &[0]),
        ];
        let acts = c.actions(0, &views);
        // Unload first (frees the slot), then load into it.
        assert_eq!(
            acts,
            vec![
                PlacementAction::Unload { worker: 3, model: ModelId(1) },
                PlacementAction::Load { worker: 3, model: ModelId(0) },
            ],
            "{acts:?}"
        );
    }

    #[test]
    fn controller_never_drops_the_last_ready_host() {
        let mut c = PlacementController::new(drained_cfg());
        for _ in 0..20 {
            c.note_arrival(ModelId(0));
        }
        // Model 1 has zero demand but one host: it must keep it.
        let views = vec![view(0, &[0], &[5]), view(1, &[1], &[0])];
        let acts = c.actions(0, &views);
        assert!(
            !acts.iter().any(|a| matches!(
                a,
                PlacementAction::Unload { model: ModelId(1), .. }
            )),
            "{acts:?}"
        );
    }

    #[test]
    fn controller_respects_capacity_and_loading_slots() {
        let mut cfg = drained_cfg();
        cfg.capacity = 1;
        let mut c = PlacementController::new(cfg);
        for _ in 0..10 {
            c.note_arrival(ModelId(0));
        }
        c.note_arrival(ModelId(1));
        // Worker 1 is already warming model 0: it must not receive a
        // second load, and its slot counts against capacity.
        let mut v1 = view(1, &[], &[]);
        v1.loading = Some(ModelId(0));
        let views = vec![view(0, &[0], &[3]), v1, view(2, &[1], &[0])];
        let acts = c.actions(0, &views);
        for a in &acts {
            if let PlacementAction::Load { worker, .. } = a {
                assert_ne!(*worker, 1, "worker 1 already has a load in flight");
            }
        }
        // Nobody exceeds capacity 1: the only legal load target would be
        // a worker freed by an unload this round.
        assert!(
            acts.len() <= 2,
            "capacity 1 bounds the action set: {acts:?}"
        );
    }

    #[test]
    fn controller_interval_gates_decisions() {
        let mut cfg = drained_cfg();
        cfg.interval_us = 1_000_000;
        let mut c = PlacementController::new(cfg);
        for _ in 0..10 {
            c.note_arrival(ModelId(0));
        }
        let views = vec![view(0, &[0], &[0]), view(1, &[1], &[0])];
        let _ = c.actions(0, &views);
        assert_eq!(c.next_decision_at(), 1_000_000);
        for _ in 0..10 {
            c.note_arrival(ModelId(0));
        }
        assert!(
            c.actions(500_000, &views).is_empty(),
            "inside the decision interval"
        );
    }

    #[test]
    fn dwell_protects_fresh_loads_from_thrash() {
        let mut cfg = drained_cfg();
        cfg.min_dwell_us = 1_000_000;
        cfg.interval_us = 1;
        let mut c = PlacementController::new(cfg);
        // Round 1 (t=0): model 0 is hot → worker 2 sheds model 1 and
        // loads model 0 (recorded as installed at t=0).
        for _ in 0..10 {
            c.note_arrival(ModelId(0));
        }
        c.note_arrival(ModelId(1));
        let views = vec![view(0, &[0], &[0]), view(1, &[1], &[0]), view(2, &[1], &[0])];
        let acts = c.actions(0, &views);
        assert!(
            acts.contains(&PlacementAction::Load { worker: 2, model: ModelId(0) }),
            "hot model should replicate onto the freed worker: {acts:?}"
        );
        // Round 2 (t=10 ms, inside the dwell): demand flips hard to model
        // 1. The fresh (worker 2, model 0) install is dwell-protected, so
        // the rebalance must shed model 0 from worker 0 instead.
        let views = vec![view(0, &[0], &[0]), view(1, &[1], &[0]), view(2, &[0], &[0])];
        for _ in 0..50 {
            c.note_arrival(ModelId(1));
        }
        let acts = c.actions(10_000, &views);
        assert!(
            !acts.contains(&PlacementAction::Unload { worker: 2, model: ModelId(0) }),
            "dwell must protect the fresh load: {acts:?}"
        );
        // Round 3 (t=2 s, dwell expired, same hosting shape): the
        // (worker 2, model 0) pair is now fair game for the rebalance.
        for _ in 0..50 {
            c.note_arrival(ModelId(1));
        }
        let acts = c.actions(2_000_000, &views);
        assert!(
            acts.contains(&PlacementAction::Unload { worker: 2, model: ModelId(0) }),
            "post-dwell rebalance: {acts:?}"
        );
    }
}
