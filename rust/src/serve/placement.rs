//! Cluster placement: which workers host which models (DESIGN.md §3).
//!
//! Production clusters multiplex many models across their workers the way
//! Clockwork does per-model placement; a [`Placement`] records the
//! worker→models assignment the router must respect — an arrival is only
//! ever routed to a worker hosting its model. The default
//! ([`Placement::unconstrained`]) hosts every model everywhere, which is
//! exactly the historical single-model behaviour.

use crate::core::request::ModelId;

/// Worker→models assignment for a cluster.
#[derive(Debug, Clone)]
pub struct Placement {
    workers: usize,
    /// `hosted[w]` = sorted model ids on worker `w`. Empty outer vec =
    /// unconstrained (every worker hosts every model).
    hosted: Vec<Vec<ModelId>>,
}

/// The named placement presets `parse` accepts, in documentation order.
pub const PLACEMENTS: [&str; 3] = ["all", "partition", "skewed"];

impl Placement {
    /// Every worker hosts every model (single-model clusters, and the
    /// default when no placement is configured).
    pub fn unconstrained(workers: usize) -> Self {
        Placement {
            workers: workers.max(1),
            hosted: Vec::new(),
        }
    }

    /// Explicit per-worker model lists. Panics if empty.
    pub fn new(hosted: Vec<Vec<ModelId>>) -> Self {
        assert!(!hosted.is_empty(), "a placement needs at least one worker");
        let mut hosted = hosted;
        for ms in &mut hosted {
            ms.sort_unstable();
            ms.dedup();
        }
        Placement {
            workers: hosted.len(),
            hosted,
        }
    }

    /// Build a placement from a spec string, for models `0..models`:
    ///
    /// * `all` — every worker hosts every model;
    /// * `partition` — disjoint-ish round-robin: worker `w` hosts model
    ///   `w % models`, and model `m` is guaranteed a host on worker
    ///   `m % workers`;
    /// * `skewed` — model 0 (the hot model) is hosted everywhere; each
    ///   model `m > 0` only on worker `m % workers`;
    /// * explicit `"0,1;1;0"` — semicolon-separated per-worker model
    ///   lists (must name exactly `workers` groups).
    ///
    /// Returns None for an unknown spec, a malformed explicit list, or an
    /// explicit list that leaves some model `< models` unhosted.
    pub fn parse(spec: &str, workers: usize, models: usize) -> Option<Placement> {
        let (workers, models) = (workers.max(1), models.max(1));
        let hosted: Vec<Vec<ModelId>> = match spec {
            "all" => (0..workers)
                .map(|_| (0..models).map(|m| ModelId(m as u32)).collect())
                .collect(),
            "partition" => {
                let mut hosted: Vec<Vec<ModelId>> =
                    (0..workers).map(|w| vec![ModelId((w % models) as u32)]).collect();
                for m in 0..models {
                    hosted[m % workers].push(ModelId(m as u32));
                }
                hosted
            }
            "skewed" => {
                let mut hosted: Vec<Vec<ModelId>> =
                    (0..workers).map(|_| vec![ModelId(0)]).collect();
                for m in 1..models {
                    hosted[m % workers].push(ModelId(m as u32));
                }
                hosted
            }
            explicit => {
                let groups: Vec<&str> = explicit.split(';').collect();
                if groups.len() != workers {
                    return None;
                }
                let mut hosted = Vec::with_capacity(workers);
                for g in groups {
                    let mut ms = Vec::new();
                    for tok in g.split(',').map(str::trim).filter(|t| !t.is_empty()) {
                        ms.push(ModelId(tok.parse::<u32>().ok()?));
                    }
                    hosted.push(ms);
                }
                hosted
            }
        };
        let p = Placement::new(hosted);
        // Every model must be hosted somewhere, or its requests could
        // never be served.
        (0..models).all(|m| p.hosts_anywhere(ModelId(m as u32))).then_some(p)
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Does worker `w` host `model`?
    pub fn hosts(&self, w: usize, model: ModelId) -> bool {
        if self.hosted.is_empty() {
            return w < self.workers;
        }
        self.hosted.get(w).is_some_and(|ms| ms.contains(&model))
    }

    /// Does any worker host `model`?
    pub fn hosts_anywhere(&self, model: ModelId) -> bool {
        self.hosted.is_empty() || self.hosted.iter().any(|ms| ms.contains(&model))
    }

    /// Models hosted on worker `w` (None = unconstrained, i.e. all).
    pub fn hosted_on(&self, w: usize) -> Option<&[ModelId]> {
        if self.hosted.is_empty() {
            None
        } else {
            self.hosted.get(w).map(|v| v.as_slice())
        }
    }

    /// Every model named by the placement, sorted (empty when
    /// unconstrained — the model set is open).
    pub fn models(&self) -> Vec<ModelId> {
        let mut all: Vec<ModelId> = self.hosted.iter().flatten().copied().collect();
        all.sort_unstable();
        all.dedup();
        all
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unconstrained_hosts_everything() {
        let p = Placement::unconstrained(3);
        assert_eq!(p.workers(), 3);
        assert!(p.hosts(0, ModelId(0)) && p.hosts(2, ModelId(99)));
        assert!(!p.hosts(3, ModelId(0)), "out-of-range worker");
        assert!(p.hosts_anywhere(ModelId(7)));
        assert!(p.models().is_empty());
        assert!(p.hosted_on(1).is_none());
    }

    #[test]
    fn parse_all() {
        let p = Placement::parse("all", 2, 3).unwrap();
        for w in 0..2 {
            for m in 0..3 {
                assert!(p.hosts(w, ModelId(m)));
            }
        }
        assert_eq!(p.models(), vec![ModelId(0), ModelId(1), ModelId(2)]);
    }

    #[test]
    fn parse_partition_covers_all_models() {
        for (workers, models) in [(4, 2), (2, 4), (3, 3), (1, 2)] {
            let p = Placement::parse("partition", workers, models).unwrap();
            for m in 0..models {
                assert!(
                    p.hosts_anywhere(ModelId(m as u32)),
                    "partition {workers}x{models}: model {m} unhosted"
                );
            }
            // Disjoint-ish: at least one worker does NOT host model 0 when
            // there are ≥2 of each.
            if workers >= 2 && models >= 2 {
                assert!(
                    (0..workers).any(|w| !p.hosts(w, ModelId(0))),
                    "partition {workers}x{models} degenerated to all"
                );
            }
        }
    }

    #[test]
    fn parse_skewed_hot_model_everywhere() {
        let p = Placement::parse("skewed", 4, 3).unwrap();
        for w in 0..4 {
            assert!(p.hosts(w, ModelId(0)), "hot model must be on worker {w}");
        }
        assert!(p.hosts(1, ModelId(1)) && p.hosts(2, ModelId(2)));
        assert!(!p.hosts(0, ModelId(1)) && !p.hosts(3, ModelId(2)));
    }

    #[test]
    fn parse_explicit_lists() {
        let p = Placement::parse("0,1;1;0", 3, 2).unwrap();
        assert!(p.hosts(0, ModelId(0)) && p.hosts(0, ModelId(1)));
        assert!(p.hosts(1, ModelId(1)) && !p.hosts(1, ModelId(0)));
        assert!(p.hosts(2, ModelId(0)) && !p.hosts(2, ModelId(1)));
        assert_eq!(p.hosted_on(1), Some(&[ModelId(1)][..]));
    }

    #[test]
    fn parse_rejects_bad_specs() {
        assert!(Placement::parse("nope", 2, 2).is_none(), "unknown word");
        assert!(Placement::parse("0;0;0", 2, 1).is_none(), "wrong worker count");
        assert!(Placement::parse("0;0", 2, 2).is_none(), "model 1 unhosted");
        assert!(Placement::parse("0,x;1", 2, 2).is_none(), "bad model id");
    }
}
