//! Wire-speed network ingress (DESIGN.md §12): a dependency-free TCP
//! front end feeding the serving core at raw speed.
//!
//! Pure `std::net`, like everything else in the crate (no tokio/mio —
//! the offline constraint of §3): N *shard* threads each own a
//! `try_clone`d nonblocking listener plus every connection they accept,
//! and sweep them with non-blocking reads. Each shard parses the fixed
//! 28-byte request frame straight off its read buffer into a stack
//! [`Request`] — no per-request heap allocation on the warm path — and
//! publishes it to the serving pump over its shard's own bounded
//! lock-free [`ArrivalRing`] partition (one per ingress shard, so a
//! sharded scheduling pump can map partitions onto scheduler shards and
//! a frame goes wire→ring→schedule without crossing threads; DESIGN.md
//! §13). The backpressure contract is explicit: a full partition is a
//! **counted early drop at the wire** (the client gets an immediate
//! `WIRE_DROP` reply), never a block inside a shard loop.
//!
//! Completions flow back through per-shard reply rings and are written
//! on the originating connection, so a request's full wire→wire
//! lifecycle is measurable (telemetry `WireIn`/`WireOut`). Reply routing
//! carries **zero extra state**: the shard packs `(shard, slot,
//! generation, client seq)` into the 64-bit [`RequestId`] at parse time
//! and [`reply_for`] unpacks it from the completion — no maps, no
//! allocation, and a slot generation guard against delivering a stale
//! completion to a recycled connection slot.
//!
//! ## Frame format (all little-endian)
//!
//! Request, 28-byte header + `payload_len` opaque bytes (discarded):
//!
//! ```text
//! 0  magic   u32 = 0x4F52_4C51          16 slo_us      u32 (> 0)
//! 4  seq     u32 (client correlation)   20 exec_us     u32 (solo exec hint)
//! 8  app     u32                        24 payload_len u32 (≤ max_payload)
//! 12 model   u32
//! ```
//!
//! Reply, fixed 24 bytes:
//!
//! ```text
//! 0  magic u32 = 0x4F52_4C50    10 batch_size  u16
//! 4  seq   u32 (echoed)         12 latency_us  u32 (release→done)
//! 8  outcome u8                 16 done_at_us  u64 (server clock)
//! 9  best_effort u8
//! ```
//!
//! Outcome codes: 0 Finished, 1 Late, 2 TimedOut, 3 Aborted,
//! 0xFF wire drop (arrival ring full). A malformed frame (bad magic,
//! zero SLO, oversized payload) closes the connection and counts
//! `proto_errors`; it never panics the shard.

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::clock::{Clock, RealClock};
use crate::core::request::{AppId, Completion, ModelId, Outcome, Request};
use crate::serve::ring::ArrivalRing;

/// Request-frame magic ("ORLQ").
pub const REQ_MAGIC: u32 = 0x4F52_4C51;
/// Reply-frame magic ("ORLP").
pub const REPLY_MAGIC: u32 = 0x4F52_4C50;
/// Request header length in bytes.
pub const REQ_HEADER_LEN: usize = 28;
/// Reply frame length in bytes.
pub const REPLY_LEN: usize = 24;
/// Reply outcome code for an arrival-ring-full early drop.
pub const WIRE_DROP: u8 = 0xFF;

/// Tuning knobs for the ingress front end. All buffers derive from these
/// at bind time; nothing resizes on the warm path.
#[derive(Debug, Clone, Copy)]
pub struct IngressConfig {
    /// Acceptor/reader shard threads.
    pub shards: usize,
    /// Total arrival-ring capacity, split evenly into one partition per
    /// ingress shard (each partition gets `ring_capacity / shards`
    /// slots, minimum 2 — the ring's own floor).
    pub ring_capacity: usize,
    /// Per-shard reply ring capacity (pump → shard).
    pub reply_capacity: usize,
    /// Largest accepted `payload_len`; larger frames are protocol errors.
    pub max_payload: usize,
    /// Per-shard open-connection cap (slot space is u16).
    pub max_conns_per_shard: usize,
}

impl Default for IngressConfig {
    fn default() -> Self {
        IngressConfig {
            shards: 2,
            ring_capacity: 1 << 16,
            reply_capacity: 1 << 15,
            max_payload: 256 * 1024,
            max_conns_per_shard: 16 * 1024,
        }
    }
}

/// Parsed request-frame header.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReqFrame {
    pub seq: u32,
    pub app: u32,
    pub model: u32,
    pub slo_us: u32,
    pub exec_us: u32,
    pub payload_len: u32,
}

/// Why a frame was rejected (connection is closed on any of these).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameError {
    BadMagic,
    ZeroSlo,
    OversizedPayload,
}

/// Decode a 28-byte request header. Allocation-free; `max_payload` bounds
/// the opaque payload a client may attach.
pub fn decode_frame(buf: &[u8; REQ_HEADER_LEN], max_payload: usize) -> Result<ReqFrame, FrameError> {
    let u32_at = |o: usize| u32::from_le_bytes([buf[o], buf[o + 1], buf[o + 2], buf[o + 3]]);
    if u32_at(0) != REQ_MAGIC {
        return Err(FrameError::BadMagic);
    }
    let f = ReqFrame {
        seq: u32_at(4),
        app: u32_at(8),
        model: u32_at(12),
        slo_us: u32_at(16),
        exec_us: u32_at(20),
        payload_len: u32_at(24),
    };
    if f.slo_us == 0 {
        return Err(FrameError::ZeroSlo);
    }
    if f.payload_len as usize > max_payload {
        return Err(FrameError::OversizedPayload);
    }
    Ok(f)
}

/// Encode a request header (loadgen / tests).
pub fn encode_frame(f: &ReqFrame) -> [u8; REQ_HEADER_LEN] {
    let mut b = [0u8; REQ_HEADER_LEN];
    b[0..4].copy_from_slice(&REQ_MAGIC.to_le_bytes());
    b[4..8].copy_from_slice(&f.seq.to_le_bytes());
    b[8..12].copy_from_slice(&f.app.to_le_bytes());
    b[12..16].copy_from_slice(&f.model.to_le_bytes());
    b[16..20].copy_from_slice(&f.slo_us.to_le_bytes());
    b[20..24].copy_from_slice(&f.exec_us.to_le_bytes());
    b[24..28].copy_from_slice(&f.payload_len.to_le_bytes());
    b
}

/// A completion (or wire drop) headed back to one connection. `slot`/`gen`
/// route it inside the shard; the rest is the client-visible frame body.
#[derive(Debug, Clone, Copy)]
pub struct Reply {
    pub slot: u16,
    pub gen: u8,
    pub seq: u32,
    pub outcome: u8,
    pub best_effort: u8,
    pub batch_size: u16,
    pub latency_us: u32,
    pub done_at_us: u64,
}

/// Encode the client-visible 24-byte reply frame.
pub fn encode_reply(r: &Reply) -> [u8; REPLY_LEN] {
    let mut b = [0u8; REPLY_LEN];
    b[0..4].copy_from_slice(&REPLY_MAGIC.to_le_bytes());
    b[4..8].copy_from_slice(&r.seq.to_le_bytes());
    b[8] = r.outcome;
    b[9] = r.best_effort;
    b[10..12].copy_from_slice(&r.batch_size.to_le_bytes());
    b[12..16].copy_from_slice(&r.latency_us.to_le_bytes());
    b[16..24].copy_from_slice(&r.done_at_us.to_le_bytes());
    b
}

/// Decoded reply, as the load generator sees it.
#[derive(Debug, Clone, Copy)]
pub struct ReplyFrame {
    pub seq: u32,
    pub outcome: u8,
    pub best_effort: bool,
    pub batch_size: u16,
    pub latency_us: u32,
    pub done_at_us: u64,
}

/// Decode a 24-byte reply frame (loadgen / tests).
pub fn decode_reply(buf: &[u8; REPLY_LEN]) -> Option<ReplyFrame> {
    let u32_at = |o: usize| u32::from_le_bytes([buf[o], buf[o + 1], buf[o + 2], buf[o + 3]]);
    if u32_at(0) != REPLY_MAGIC {
        return None;
    }
    let mut done = [0u8; 8];
    done.copy_from_slice(&buf[16..24]);
    Some(ReplyFrame {
        seq: u32_at(4),
        outcome: buf[8],
        best_effort: buf[9] != 0,
        batch_size: u16::from_le_bytes([buf[10], buf[11]]),
        latency_us: u32_at(12),
        done_at_us: u64::from_le_bytes(done),
    })
}

// --- RequestId bit-packing -------------------------------------------------
//
// id = shard(8) | slot(16) | gen(8) | seq(32). The id carries everything a
// completion needs to find its way back to the right connection, so the
// reply path keeps no per-request state at all.

/// Pack ingress routing into a `RequestId` payload.
pub fn encode_id(shard: u8, slot: u16, gen: u8, seq: u32) -> u64 {
    ((shard as u64) << 56) | ((slot as u64) << 40) | ((gen as u64) << 32) | seq as u64
}

pub fn id_shard(id: u64) -> u8 {
    (id >> 56) as u8
}

pub fn id_slot(id: u64) -> u16 {
    (id >> 40) as u16
}

pub fn id_gen(id: u64) -> u8 {
    (id >> 32) as u8
}

pub fn id_seq(id: u64) -> u32 {
    id as u32
}

/// Map a serving-core completion back onto its shard + wire reply.
pub fn reply_for(c: &Completion) -> (usize, Reply) {
    let id = c.request.id.0;
    let outcome = match c.outcome {
        Outcome::Finished => 0,
        Outcome::Late => 1,
        Outcome::TimedOut => 2,
        Outcome::Aborted => 3,
    };
    let reply = Reply {
        slot: id_slot(id),
        gen: id_gen(id),
        seq: id_seq(id),
        outcome,
        best_effort: c.best_effort as u8,
        batch_size: c.batch_size.min(u16::MAX as usize) as u16,
        latency_us: c.at.saturating_sub(c.request.release).min(u32::MAX as u64) as u32,
        done_at_us: c.at,
    };
    (id_shard(id) as usize, reply)
}

// --- shared state ----------------------------------------------------------

#[derive(Default)]
struct Stats {
    accepted_conns: AtomicU64,
    open_conns: AtomicU64,
    frames: AtomicU64,
    wire_drops: AtomicU64,
    proto_errors: AtomicU64,
    replies_written: AtomicU64,
    replies_dead: AtomicU64,
    bytes_in: AtomicU64,
    bytes_out: AtomicU64,
}

/// Snapshot of the ingress counters, returned by [`Ingress::finish`].
#[derive(Debug, Clone, Copy, Default)]
pub struct IngressCounts {
    /// Connections ever accepted.
    pub accepted_conns: u64,
    /// Complete request frames parsed off the wire.
    pub frames: u64,
    /// Frames dropped at the wire because the arrival ring was full
    /// (each one got an immediate `WIRE_DROP` reply).
    pub wire_drops: u64,
    /// Malformed frames (connection closed, no reply).
    pub proto_errors: u64,
    /// Reply frames written into connection buffers.
    pub replies_written: u64,
    /// Replies whose connection was already gone (slot freed or
    /// generation mismatch).
    pub replies_dead: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
}

struct Shared {
    /// One arrival partition per ingress shard; each shard pushes only to
    /// its own. The unsharded pump sweeps all of them round-robin
    /// (`pop_cursor`); the sharded pump assigns each partition exactly
    /// one consuming scheduler shard (the ring is single-consumer).
    arrivals: Vec<ArrivalRing<Request>>,
    pop_cursor: AtomicUsize,
    replies: Vec<ArrivalRing<Reply>>,
    /// Listeners accept new connections while set.
    accepting: AtomicBool,
    /// Set by [`IngressController::begin_drain`]: stop reading new frames,
    /// keep flushing replies.
    draining: AtomicBool,
    /// Set by [`Ingress::finish`]: shards flush what they can and exit.
    shutdown: AtomicBool,
    clock: RealClock,
    cfg: IngressConfig,
    stats: Stats,
}

impl Shared {
    fn counts(&self) -> IngressCounts {
        IngressCounts {
            accepted_conns: self.stats.accepted_conns.load(Ordering::Relaxed),
            frames: self.stats.frames.load(Ordering::Relaxed),
            wire_drops: self.stats.wire_drops.load(Ordering::Relaxed),
            proto_errors: self.stats.proto_errors.load(Ordering::Relaxed),
            replies_written: self.stats.replies_written.load(Ordering::Relaxed),
            replies_dead: self.stats.replies_dead.load(Ordering::Relaxed),
            bytes_in: self.stats.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.stats.bytes_out.load(Ordering::Relaxed),
        }
    }
}

/// Shutdown/drain handle, cloneable into watcher threads (SIGINT,
/// `--duration` timers) while the pump owns the [`Ingress`] itself.
#[derive(Clone)]
pub struct IngressController {
    shared: Arc<Shared>,
}

impl IngressController {
    /// Stop accepting and stop reading new frames; in-flight work drains
    /// and replies still flush. The pump observes this via
    /// [`Ingress::drain_requested`] and exits once the core is empty.
    pub fn begin_drain(&self) {
        self.shared.accepting.store(false, Ordering::SeqCst);
        self.shared.draining.store(true, Ordering::SeqCst);
    }

    /// Whether a drain has been requested.
    pub fn draining(&self) -> bool {
        self.shared.draining.load(Ordering::SeqCst)
    }

    /// Live counter snapshot.
    pub fn counts(&self) -> IngressCounts {
        self.shared.counts()
    }
}

/// The bound front end: shard threads + rings. Owned by the serving pump
/// ([`crate::serve::realtime::serve_ingress`]), which pops arrivals,
/// pushes replies, and calls [`Ingress::finish`] on exit.
pub struct Ingress {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    local_addr: SocketAddr,
}

impl Ingress {
    /// Bind `addr` and spawn the shard threads. `clock` must be the same
    /// epoch the serving core stamps with, so `release`/`deadline` are
    /// directly comparable to `ServingLoop::now()`.
    pub fn bind<A: ToSocketAddrs>(
        addr: A,
        cfg: IngressConfig,
        clock: RealClock,
    ) -> io::Result<Ingress> {
        let shards = cfg.shards.max(1);
        let cfg = IngressConfig {
            shards,
            max_conns_per_shard: cfg.max_conns_per_shard.clamp(1, u16::MAX as usize + 1),
            ..cfg
        };
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local_addr = listener.local_addr()?;
        let partition_cap = (cfg.ring_capacity / shards).max(2);
        let shared = Arc::new(Shared {
            arrivals: (0..shards)
                .map(|_| ArrivalRing::new(partition_cap))
                .collect(),
            pop_cursor: AtomicUsize::new(0),
            replies: (0..shards)
                .map(|_| ArrivalRing::new(cfg.reply_capacity))
                .collect(),
            accepting: AtomicBool::new(true),
            draining: AtomicBool::new(false),
            shutdown: AtomicBool::new(false),
            clock,
            cfg,
            stats: Stats::default(),
        });
        let mut handles = Vec::with_capacity(shards);
        for shard in 0..shards {
            let listener = listener.try_clone()?;
            let shared = shared.clone();
            let handle = std::thread::Builder::new()
                .name(format!("ingress-s{shard}"))
                .spawn(move || shard_loop(shard as u8, listener, shared))?;
            handles.push(handle);
        }
        drop(listener);
        Ok(Ingress {
            shared,
            handles,
            local_addr,
        })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Shard count (indexes [`Ingress::push_reply`]).
    pub fn shards(&self) -> usize {
        self.shared.cfg.shards
    }

    /// A cloneable drain handle.
    pub fn controller(&self) -> IngressController {
        IngressController {
            shared: self.shared.clone(),
        }
    }

    /// Single-consumer arrival drain — only one pump thread may call
    /// this, and it must then be the sole consumer of *every* partition
    /// (don't mix with [`Ingress::pop_arrival_from`]). Sweeps partitions
    /// on a rotating cursor so no ingress shard is starved.
    pub fn pop_arrival(&self) -> Option<Request> {
        let parts = self.shared.arrivals.len();
        let start = self.shared.pop_cursor.load(Ordering::Relaxed);
        for i in 0..parts {
            let p = (start + i) % parts;
            if let Some(req) = self.shared.arrivals[p].pop() {
                self.shared
                    .pop_cursor
                    .store((p + 1) % parts, Ordering::Relaxed);
                return Some(req);
            }
        }
        None
    }

    /// Number of arrival partitions (== ingress shard count).
    pub fn arrival_partitions(&self) -> usize {
        self.shared.arrivals.len()
    }

    /// Pop from one specific partition. The sharded pump maps each
    /// partition onto exactly one scheduler shard; that shard must be
    /// the partition's only consumer (the ring is single-consumer).
    pub fn pop_arrival_from(&self, part: usize) -> Option<Request> {
        self.shared.arrivals[part].pop()
    }

    /// Whether one specific arrival partition is currently empty.
    pub fn arrivals_empty_in(&self, part: usize) -> bool {
        self.shared.arrivals[part].is_empty()
    }

    /// Whether every arrival partition is currently empty.
    pub fn arrivals_empty(&self) -> bool {
        self.shared.arrivals.iter().all(|r| r.is_empty())
    }

    /// Whether [`IngressController::begin_drain`] has been called.
    pub fn drain_requested(&self) -> bool {
        self.shared.draining.load(Ordering::SeqCst)
    }

    /// Queue a reply to `shard`. Spins (yielding) if the reply ring is
    /// momentarily full — the shard drains it every sweep, so this is a
    /// bounded stall on the pump, never a loss.
    pub fn push_reply(&self, shard: usize, reply: Reply) {
        let ring = &self.shared.replies[shard.min(self.shared.replies.len() - 1)];
        let mut r = reply;
        loop {
            match ring.push(r) {
                Ok(()) => return,
                Err(back) => {
                    r = back;
                    std::thread::yield_now();
                }
            }
        }
    }

    /// Live counter snapshot.
    pub fn counts(&self) -> IngressCounts {
        self.shared.counts()
    }

    /// Flush reply rings (bounded grace), stop the shards, join them, and
    /// return the final counters.
    pub fn finish(self) -> IngressCounts {
        let grace = Instant::now() + Duration::from_millis(500);
        while Instant::now() < grace && self.shared.replies.iter().any(|r| !r.is_empty()) {
            std::thread::sleep(Duration::from_micros(200));
        }
        self.shared.shutdown.store(true, Ordering::SeqCst);
        for h in self.handles {
            let _ = h.join();
        }
        self.shared.counts()
    }
}

// --- shard loop ------------------------------------------------------------

/// Per-connection state. Buffers are allocated once at accept and
/// retained for the connection's lifetime — the frame parse/reply path
/// never grows them on the warm path (`wbuf` keeps its capacity across
/// flushes).
struct Conn {
    stream: TcpStream,
    rbuf: Box<[u8]>,
    rlen: usize,
    /// Opaque payload bytes still to discard before the next header.
    skip: usize,
    wbuf: Vec<u8>,
    wpos: usize,
    dead: bool,
}

const RBUF_LEN: usize = 4096;
const ACCEPTS_PER_SWEEP: usize = 64;
const READS_PER_CONN: usize = 4;
const REPLIES_PER_SWEEP: usize = 4096;
/// A connection whose peer stops reading accumulates replies; past this
/// the shard declares it dead rather than buffer without bound.
const WBUF_CAP: usize = 1 << 20;

fn shard_loop(shard: u8, listener: TcpListener, shared: Arc<Shared>) {
    let mut conns: Vec<Option<Conn>> = Vec::new();
    let mut gens: Vec<u8> = Vec::new();
    let mut free: Vec<u16> = Vec::new();
    loop {
        let mut progress = false;
        let shutdown = shared.shutdown.load(Ordering::SeqCst);
        if shared.accepting.load(Ordering::SeqCst) && !shutdown {
            progress |= accept_sweep(&listener, &shared, &mut conns, &mut gens, &mut free);
        }
        let draining = shared.draining.load(Ordering::SeqCst);
        if !draining && !shutdown {
            for slot in 0..conns.len() {
                if let Some(conn) = conns[slot].as_mut() {
                    progress |= read_sweep(&shared, shard, slot as u16, gens[slot], conn);
                }
            }
        }
        progress |= reply_sweep(&shared, shard, &mut conns, &gens);
        for conn in conns.iter_mut().flatten() {
            progress |= flush(&shared, conn);
        }
        for slot in 0..conns.len() {
            if conns[slot].as_ref().is_some_and(|c| c.dead) {
                conns[slot] = None;
                gens[slot] = gens[slot].wrapping_add(1);
                free.push(slot as u16);
                shared.stats.open_conns.fetch_sub(1, Ordering::Relaxed);
            }
        }
        if shutdown {
            // Final courtesy flush of whatever is still buffered, then out.
            let deadline = Instant::now() + Duration::from_millis(500);
            let mut remaining = true;
            while remaining && Instant::now() < deadline {
                remaining = false;
                reply_sweep(&shared, shard, &mut conns, &gens);
                for conn in conns.iter_mut().flatten() {
                    flush(&shared, conn);
                    remaining |= !conn.dead && conn.wbuf.len() > conn.wpos;
                }
                remaining |= !shared.replies[shard as usize].is_empty();
                if remaining {
                    std::thread::sleep(Duration::from_micros(100));
                }
            }
            return;
        }
        if !progress {
            std::thread::sleep(Duration::from_micros(50));
        }
    }
}

fn accept_sweep(
    listener: &TcpListener,
    shared: &Shared,
    conns: &mut Vec<Option<Conn>>,
    gens: &mut Vec<u8>,
    free: &mut Vec<u16>,
) -> bool {
    let mut progress = false;
    for _ in 0..ACCEPTS_PER_SWEEP {
        match listener.accept() {
            Ok((stream, _peer)) => {
                progress = true;
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                let _ = stream.set_nodelay(true);
                let slot = match free.pop() {
                    Some(s) => s,
                    None if conns.len() < shared.cfg.max_conns_per_shard => {
                        conns.push(None);
                        gens.push(0);
                        (conns.len() - 1) as u16
                    }
                    // Shard full: refuse by dropping the socket (peer
                    // sees EOF before any reply).
                    None => continue,
                };
                conns[slot as usize] = Some(Conn {
                    stream,
                    rbuf: vec![0u8; RBUF_LEN].into_boxed_slice(),
                    rlen: 0,
                    skip: 0,
                    wbuf: Vec::with_capacity(4096),
                    wpos: 0,
                    dead: false,
                });
                shared.stats.accepted_conns.fetch_add(1, Ordering::Relaxed);
                shared.stats.open_conns.fetch_add(1, Ordering::Relaxed);
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => break,
        }
    }
    progress
}

fn read_sweep(shared: &Shared, shard: u8, slot: u16, gen: u8, conn: &mut Conn) -> bool {
    if conn.dead {
        return false;
    }
    let mut progress = false;
    for _ in 0..READS_PER_CONN {
        let n = match conn.stream.read(&mut conn.rbuf[conn.rlen..]) {
            Ok(0) => {
                conn.dead = true;
                break;
            }
            Ok(n) => n,
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
                break;
            }
        };
        progress = true;
        conn.rlen += n;
        shared.stats.bytes_in.fetch_add(n as u64, Ordering::Relaxed);
        if !drain_frames(shared, shard, slot, gen, conn) {
            conn.dead = true;
            break;
        }
    }
    progress
}

/// Parse every complete frame buffered on `conn`, stamping and publishing
/// each request. Returns `false` on a protocol error (caller kills the
/// connection). Allocation-free: requests are built on the stack and
/// moved into the pre-sized arrival ring; wire-drop replies append to the
/// connection's retained write buffer.
fn drain_frames(shared: &Shared, shard: u8, slot: u16, gen: u8, conn: &mut Conn) -> bool {
    let mut rpos = 0usize;
    let mut ok = true;
    loop {
        if conn.skip > 0 {
            let take = conn.skip.min(conn.rlen - rpos);
            rpos += take;
            conn.skip -= take;
            if conn.skip > 0 {
                break;
            }
        }
        if conn.rlen - rpos < REQ_HEADER_LEN {
            break;
        }
        let mut hdr = [0u8; REQ_HEADER_LEN];
        hdr.copy_from_slice(&conn.rbuf[rpos..rpos + REQ_HEADER_LEN]);
        let frame = match decode_frame(&hdr, shared.cfg.max_payload) {
            Ok(f) => f,
            Err(_) => {
                shared.stats.proto_errors.fetch_add(1, Ordering::Relaxed);
                ok = false;
                break;
            }
        };
        rpos += REQ_HEADER_LEN;
        conn.skip = frame.payload_len as usize;
        shared.stats.frames.fetch_add(1, Ordering::Relaxed);
        let release = shared.clock.now();
        let id = encode_id(shard, slot, gen, frame.seq);
        let req = Request::new(
            id,
            AppId(frame.app),
            release,
            frame.slo_us as u64,
            frame.exec_us as f64 / 1000.0,
        )
        .with_model(ModelId(frame.model));
        if shared.arrivals[shard as usize].push(req).is_err() {
            // Backpressure: never block the shard — count the drop and
            // tell the client immediately.
            shared.stats.wire_drops.fetch_add(1, Ordering::Relaxed);
            let drop_reply = Reply {
                slot,
                gen,
                seq: frame.seq,
                outcome: WIRE_DROP,
                best_effort: 0,
                batch_size: 0,
                latency_us: 0,
                done_at_us: release,
            };
            conn.wbuf.extend_from_slice(&encode_reply(&drop_reply));
            shared.stats.replies_written.fetch_add(1, Ordering::Relaxed);
        }
    }
    if rpos > 0 {
        conn.rbuf.copy_within(rpos..conn.rlen, 0);
        conn.rlen -= rpos;
    }
    ok
}

fn reply_sweep(shared: &Shared, shard: u8, conns: &mut [Option<Conn>], gens: &[u8]) -> bool {
    let ring = &shared.replies[shard as usize];
    let mut progress = false;
    for _ in 0..REPLIES_PER_SWEEP {
        let Some(reply) = ring.pop() else { break };
        progress = true;
        let slot = reply.slot as usize;
        let live = slot < conns.len()
            && gens[slot] == reply.gen
            && conns[slot].as_ref().is_some_and(|c| !c.dead);
        if live {
            let conn = conns[slot].as_mut().unwrap();
            conn.wbuf.extend_from_slice(&encode_reply(&reply));
            shared.stats.replies_written.fetch_add(1, Ordering::Relaxed);
        } else {
            shared.stats.replies_dead.fetch_add(1, Ordering::Relaxed);
        }
    }
    progress
}

fn flush(shared: &Shared, conn: &mut Conn) -> bool {
    if conn.dead || conn.wbuf.len() == conn.wpos {
        return false;
    }
    let mut progress = false;
    loop {
        match conn.stream.write(&conn.wbuf[conn.wpos..]) {
            Ok(0) => {
                conn.dead = true;
                break;
            }
            Ok(n) => {
                progress = true;
                conn.wpos += n;
                shared.stats.bytes_out.fetch_add(n as u64, Ordering::Relaxed);
                if conn.wpos == conn.wbuf.len() {
                    conn.wbuf.clear();
                    conn.wpos = 0;
                    break;
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => {
                conn.dead = true;
                break;
            }
        }
    }
    if conn.wbuf.len() - conn.wpos > WBUF_CAP {
        conn.dead = true;
    }
    progress
}

// --- SIGINT latch ----------------------------------------------------------

/// Minimal ctrl-c latch for `serve --listen` (DESIGN.md §12): the handler
/// only sets an atomic (async-signal-safe); a watcher thread polls
/// [`ctrlc::triggered`] and turns it into [`IngressController::begin_drain`],
/// so shutdown reuses the pump's ordinary drain/exit machinery.
pub mod ctrlc {
    use std::sync::atomic::{AtomicBool, Ordering};

    static TRIGGERED: AtomicBool = AtomicBool::new(false);

    #[cfg(unix)]
    extern "C" fn on_sigint(_sig: i32) {
        TRIGGERED.store(true, Ordering::SeqCst);
    }

    /// Install the SIGINT handler. No-op off Unix (callers fall back to
    /// `--duration`-style timers there). Uses the libc `signal` symbol std
    /// already links — no new dependency.
    #[cfg(unix)]
    pub fn install() {
        extern "C" {
            fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
        }
        const SIGINT: i32 = 2;
        unsafe {
            signal(SIGINT, on_sigint);
        }
    }

    #[cfg(not(unix))]
    pub fn install() {}

    /// Whether ctrl-c has been pressed since [`install`].
    pub fn triggered() -> bool {
        TRIGGERED.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::request::RequestId;

    #[test]
    fn frame_roundtrip() {
        let f = ReqFrame {
            seq: 7,
            app: 2,
            model: 3,
            slo_us: 50_000,
            exec_us: 4_000,
            payload_len: 128,
        };
        let bytes = encode_frame(&f);
        assert_eq!(decode_frame(&bytes, 1024).unwrap(), f);
    }

    #[test]
    fn frame_rejects_bad_input() {
        let f = ReqFrame {
            seq: 1,
            app: 0,
            model: 0,
            slo_us: 1_000,
            exec_us: 100,
            payload_len: 0,
        };
        let mut bad_magic = encode_frame(&f);
        bad_magic[0] ^= 0xFF;
        assert_eq!(
            decode_frame(&bad_magic, 1024),
            Err(FrameError::BadMagic)
        );
        let zero_slo = encode_frame(&ReqFrame { slo_us: 0, ..f });
        assert_eq!(decode_frame(&zero_slo, 1024), Err(FrameError::ZeroSlo));
        let big = encode_frame(&ReqFrame {
            payload_len: 2048,
            ..f
        });
        assert_eq!(
            decode_frame(&big, 1024),
            Err(FrameError::OversizedPayload)
        );
    }

    #[test]
    fn reply_roundtrip() {
        let r = Reply {
            slot: 9,
            gen: 3,
            seq: 41,
            outcome: 1,
            best_effort: 1,
            batch_size: 8,
            latency_us: 12_345,
            done_at_us: 999_999,
        };
        let bytes = encode_reply(&r);
        let f = decode_reply(&bytes).unwrap();
        assert_eq!(f.seq, 41);
        assert_eq!(f.outcome, 1);
        assert!(f.best_effort);
        assert_eq!(f.batch_size, 8);
        assert_eq!(f.latency_us, 12_345);
        assert_eq!(f.done_at_us, 999_999);
        let mut bad = bytes;
        bad[1] ^= 0xFF;
        assert!(decode_reply(&bad).is_none());
    }

    #[test]
    fn id_packing_roundtrips() {
        let id = encode_id(5, 60_000, 200, u32::MAX - 3);
        assert_eq!(id_shard(id), 5);
        assert_eq!(id_slot(id), 60_000);
        assert_eq!(id_gen(id), 200);
        assert_eq!(id_seq(id), u32::MAX - 3);
    }

    #[test]
    fn reply_for_unpacks_routing() {
        let id = encode_id(2, 17, 9, 1234);
        let req = Request::new(id, AppId(0), 1_000, 5_000, 1.0);
        assert_eq!(req.id, RequestId(id));
        let c = Completion {
            request: req,
            outcome: Outcome::Late,
            at: 8_000,
            batch_size: 70_000,
            worker: Some(0),
            best_effort: false,
        };
        let (shard, reply) = reply_for(&c);
        assert_eq!(shard, 2);
        assert_eq!(reply.slot, 17);
        assert_eq!(reply.gen, 9);
        assert_eq!(reply.seq, 1234);
        assert_eq!(reply.outcome, 1);
        assert_eq!(reply.batch_size, u16::MAX, "saturates");
        assert_eq!(reply.latency_us, 7_000);
    }
}
