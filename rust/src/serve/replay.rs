//! Virtual-time pump: replays a recorded trace through a
//! [`ServingLoop`](super::ServingLoop) cluster, advancing a shared
//! [`VirtualClock`] from event to event (the discrete-event substrate
//! behind every table and figure reproduction).
//!
//! Batch executions cost zero wall time: worker `w`'s simulated latency
//! schedules a `BatchDone` at `now + latency`, exactly as the historical
//! single-worker `sim::engine` did — but for N replicas at once. Elastic
//! model loads are scheduled the same way: a [`Dispatch::Load`] books a
//! `PlacementDone` at `now + load latency`, so cold starts share the one
//! event heap with batch completions.
//!
//! **Hot loop (§Perf).** The pump is driven by a single min-heap of
//! pending `(finish time, worker)` completions plus a draining iterator
//! over the release-sorted trace: each iteration touches only the events
//! that are actually due, instead of re-scanning every worker slot and
//! re-deriving the next event time from all N of them. Requests are moved
//! out of the trace by value — the historical per-arrival `Request` clone
//! is gone.

use super::{Dispatch, Event, ServingLoop};
use crate::clock::{ms_to_us, Micros, VirtualClock};
use crate::core::request::{ModelId, Request};
use crate::scheduler::Scheduler;
use crate::sim::engine::EngineResult;
use crate::sim::worker::Worker;
use crate::telemetry::EventKind;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Run the trace to completion on a cluster; `workers[i]` executes the
/// batches of replica `i`.
pub fn run_cluster<S: Scheduler, W: Worker>(
    core: ServingLoop<VirtualClock, S>,
    workers: Vec<W>,
    requests: Vec<Request>,
) -> EngineResult {
    run_cluster_traced(core, workers, requests, |_, _| {})
}

/// [`run_cluster`] with a dispatch observer: `on_dispatch(now, d)` fires
/// for every dispatch decision — batch executions *and* placement
/// loads/unloads — in virtual-time order (the golden dispatch-sequence
/// regression tests record these).
pub fn run_cluster_traced<S, W, F>(
    mut core: ServingLoop<VirtualClock, S>,
    mut workers: Vec<W>,
    mut requests: Vec<Request>,
    mut on_dispatch: F,
) -> EngineResult
where
    S: Scheduler,
    W: Worker,
    F: FnMut(Micros, &Dispatch),
{
    assert_eq!(
        workers.len(),
        core.workers(),
        "one executor per scheduling replica"
    );
    requests.sort_by_key(|r| r.release);
    let clock = core.clock().clone();
    let n = workers.len();
    // The event heap holds one (finish time, worker) entry per in-flight
    // batch; same-time completions pop in worker order, matching the
    // historical per-worker scan. The measured batch time rides in a side
    // slot (f64 is not Ord). Model loads get their own small heap so the
    // static path's heap discipline is untouched.
    let mut done: BinaryHeap<Reverse<(Micros, usize)>> = BinaryHeap::with_capacity(n);
    let mut done_ms = vec![0.0f64; n];
    let mut loads: BinaryHeap<Reverse<(Micros, usize, u32)>> = BinaryHeap::new();
    let mut loads_ms = vec![0.0f64; n];
    // A worker is one execution resource: loads and batches dispatched to
    // it serialize, exactly like the realtime pump's per-worker channel
    // (a load landing behind a running batch starts when the batch
    // finishes). Static runs only ever dispatch to idle workers, so this
    // never moves a batch completion there.
    let mut busy_until: Vec<Micros> = vec![0; n];
    let mut arrivals = requests.into_iter().peekable();

    loop {
        let now = clock.now();
        // Deliver all arrivals due now, draining the trace in place.
        while arrivals.peek().is_some_and(|r| r.release <= now) {
            core.on_event(Event::Arrival(arrivals.next().unwrap()));
        }
        // Complete every model load that is due (installs must land
        // before dispatching, so a finished replica is routable at once).
        while let Some(&Reverse((t, w, m))) = loads.peek() {
            if t > now {
                break;
            }
            loads.pop();
            core.on_event(Event::PlacementDone {
                worker: w,
                model: ModelId(m),
                load_ms: loads_ms[w],
            });
        }
        // Complete every in-flight batch that is due.
        while let Some(&Reverse((t, w))) = done.peek() {
            if t > now {
                break;
            }
            done.pop();
            core.on_event(Event::BatchDone {
                worker: w,
                batch_ms: done_ms[w],
            });
        }
        // Drain drops, run the placement controller, dispatch.
        for d in core.on_event(Event::Wake) {
            on_dispatch(now, &d);
            match d {
                Dispatch::Execute { worker, batch } => {
                    let ms = workers[worker].execute(&batch);
                    done_ms[worker] = ms;
                    let start = busy_until[worker].max(now);
                    let fin = start + ms_to_us(ms);
                    busy_until[worker] = fin;
                    // Execution begins when the worker frees, not at
                    // dispatch: stamp the span's start accordingly.
                    if let Some(tel) = core.telemetry_mut() {
                        if let Some(b) = tel.last_batch_for(worker) {
                            tel.record(
                                start,
                                EventKind::ExecStart {
                                    batch: b,
                                    worker: worker as u32,
                                },
                            );
                        }
                    }
                    done.push(Reverse((fin, worker)));
                }
                Dispatch::Load {
                    worker,
                    model,
                    cost_ms,
                } => {
                    let ms = workers[worker].load_model(model, cost_ms);
                    loads_ms[worker] = ms;
                    let fin = busy_until[worker].max(now) + ms_to_us(ms).max(1);
                    busy_until[worker] = fin;
                    loads.push(Reverse((fin, worker, model.0)));
                }
                Dispatch::Unload { worker, model } => {
                    workers[worker].unload_model(model);
                }
            }
        }
        // Everything delivered and drained → done.
        if arrivals.peek().is_none()
            && done.is_empty()
            && loads.is_empty()
            && core.pending() == 0
        {
            core.drain_all();
            break;
        }
        // Advance to the next event: arrival, completion, load, or wake.
        let mut next: Option<Micros> = arrivals.peek().map(|r| r.release);
        if let Some(&Reverse((t, _))) = done.peek() {
            next = Some(next.map_or(t, |v| v.min(t)));
        }
        if let Some(&Reverse((t, _, _))) = loads.peek() {
            next = Some(next.map_or(t, |v| v.min(t)));
        }
        if let Some(h) = core.next_wake(now) {
            next = Some(next.map_or(h, |v| v.min(h)));
        }
        match next {
            Some(t) if t > now => clock.advance_to(t),
            Some(_) => clock.advance_to(now + 1), // same-time event loop guard
            None => clock.advance_to(now + 1_000),
        }
    }

    let end_time = clock.now();
    let placement = core.placement_stats();
    let admission = core.admission_stats();
    let telemetry = core.take_telemetry();
    let (completions, per_worker) = core.into_completions();
    let batches = per_worker.iter().map(|w| w.batches).sum();
    let busy_us = per_worker.iter().map(|w| w.busy_us).sum();
    EngineResult {
        completions,
        end_time,
        batches,
        busy_us,
        per_worker,
        placement,
        admission,
        telemetry,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::edf::EdfScheduler;
    use crate::core::batchmodel::BatchCostModel;
    use crate::core::request::{AppId, Outcome};
    use crate::scheduler::SchedulerConfig;
    use crate::serve::{
        router, Cluster, ColdStartCost, ElasticConfig, Placement, PlacementController,
    };
    use crate::sim::worker::SimWorker;

    fn cluster(n: usize) -> Cluster<EdfScheduler> {
        let cfg = SchedulerConfig {
            cost_model: BatchCostModel::new(0.0, 1.0),
            ..Default::default()
        };
        Cluster::new(
            (0..n)
                .map(|_| {
                    let mut s = EdfScheduler::new(cfg.clone(), 0);
                    s.seed_exec_mean(10.0);
                    s
                })
                .collect(),
        )
    }

    fn workers(n: usize) -> Vec<SimWorker> {
        (0..n)
            .map(|w| SimWorker::new(BatchCostModel::new(0.0, 1.0), 0.0, w as u64))
            .collect()
    }

    fn requests(n: u64, gap_ms: f64, slo_ms: f64) -> Vec<Request> {
        (0..n)
            .map(|i| {
                Request::new(
                    i,
                    AppId(0),
                    ms_to_us(i as f64 * gap_ms),
                    ms_to_us(slo_ms),
                    10.0,
                )
            })
            .collect()
    }

    #[test]
    fn two_replicas_split_the_work() {
        let core = ServingLoop::new(
            VirtualClock::new(),
            cluster(2),
            router::by_name("round_robin").unwrap(),
        );
        let res = run_cluster(core, workers(2), requests(60, 5.0, 1_000.0));
        assert_eq!(res.completions.len(), 60);
        assert_eq!(res.per_worker.len(), 2);
        assert!(res.per_worker.iter().all(|w| w.batches > 0));
        assert_eq!(
            res.batches,
            res.per_worker.iter().map(|w| w.batches).sum::<usize>()
        );
        assert_eq!(
            res.busy_us,
            res.per_worker.iter().map(|w| w.busy_us).sum::<u64>()
        );
        assert_eq!(res.placement.actions(), 0, "static runs take no actions");
    }

    #[test]
    fn traced_pump_sees_every_dispatch_in_time_order() {
        let core = ServingLoop::new(
            VirtualClock::new(),
            cluster(2),
            router::by_name("round_robin").unwrap(),
        );
        let mut times: Vec<Micros> = Vec::new();
        let mut dispatched = 0usize;
        let mut batches = 0usize;
        let res = run_cluster_traced(core, workers(2), requests(40, 4.0, 1_000.0), |t, d| {
            times.push(t);
            match d {
                Dispatch::Execute { worker, batch } => {
                    dispatched += batch.len();
                    batches += 1;
                    assert!(*worker < 2);
                    assert!(!batch.is_empty());
                }
                other => panic!("static run produced {other:?}"),
            }
        });
        assert_eq!(batches, res.batches, "observer sees every dispatch");
        let executed = res
            .completions
            .iter()
            .filter(|c| c.outcome == Outcome::Finished || c.outcome == Outcome::Late)
            .count();
        assert_eq!(dispatched, executed, "every executed request was observed");
        assert!(times.windows(2).all(|w| w[0] <= w[1]), "virtual-time order");
    }

    #[test]
    fn more_replicas_rescue_an_overloaded_trace() {
        // 1 req/ms with 10 ms exec: hopeless for one worker, easy for four.
        let finished = |n: usize| {
            let core = ServingLoop::new(
                VirtualClock::new(),
                cluster(n),
                router::by_name("join_shortest_queue").unwrap(),
            );
            let res = run_cluster(core, workers(n), requests(200, 1.0, 60.0));
            assert_eq!(res.completions.len(), 200, "conservation at n={n}");
            res.completions
                .iter()
                .filter(|c| c.outcome == Outcome::Finished)
                .count()
        };
        let one = finished(1);
        let four = finished(4);
        assert!(four > one, "4 workers ({four}) must beat 1 ({one})");
        assert!(four > 150, "4 workers should clear most of the load: {four}");
    }

    #[test]
    fn elastic_load_completes_on_the_virtual_clock() {
        // Two workers, partition placement, single-model trace: the
        // controller replicates model 0 onto worker 1 after a cold start,
        // and the pump books the PlacementDone like a batch completion.
        let cfg = SchedulerConfig {
            cost_model: BatchCostModel::new(0.0, 1.0),
            ..Default::default()
        };
        let scheds: Vec<EdfScheduler> = (0..2)
            .map(|_| {
                let mut s = EdfScheduler::new(cfg.clone(), 0);
                s.seed_exec_mean(10.0);
                s
            })
            .collect();
        let placement = Placement::parse("partition", 2, 2).unwrap();
        let cluster = Cluster::with_placement(scheds, placement);
        let ctl = PlacementController::new(ElasticConfig {
            capacity: 2,
            interval_us: 10_000,
            alpha: 1.0,
            min_dwell_us: 0,
            cold_start: ColdStartCost::new(10.0, 10.0),
        });
        let core = ServingLoop::new(
            VirtualClock::new(),
            cluster,
            router::by_name("least_loaded").unwrap(),
        )
        .with_elastic(ctl);
        let mut load_seen_at: Option<Micros> = None;
        let mut first_exec_w1: Option<Micros> = None;
        let res = run_cluster_traced(core, workers(2), requests(120, 1.0, 2_000.0), |t, d| {
            match d {
                Dispatch::Load { worker: 1, model: ModelId(0), cost_ms } => {
                    assert!((cost_ms - 20.0).abs() < 1e-9);
                    if load_seen_at.is_none() {
                        load_seen_at = Some(t);
                    }
                }
                Dispatch::Execute { worker: 1, batch } if batch[0].model == ModelId(0) => {
                    if first_exec_w1.is_none() {
                        first_exec_w1 = Some(t);
                    }
                }
                _ => {}
            }
        });
        assert_eq!(res.completions.len(), 120, "conservation under elastic");
        let loaded = load_seen_at.expect("controller should replicate the hot model");
        assert!(res.placement.loads >= 1);
        if let Some(t1) = first_exec_w1 {
            assert!(
                t1 >= loaded + ms_to_us(20.0),
                "worker 1 executed model 0 at {t1} before its load finished ({loaded} + 20ms)"
            );
        }
    }
}
