//! Virtual-time pump: replays a recorded trace through a
//! [`ServingLoop`](super::ServingLoop) cluster, advancing a
//! [`VirtualClock`] from event to event (the discrete-event substrate
//! behind every table and figure reproduction).
//!
//! Batch executions cost zero wall time: worker `w`'s simulated latency
//! schedules a `BatchDone` at `now + latency`, exactly as the historical
//! single-worker `sim::engine` did — but for N replicas at once. Elastic
//! model loads are scheduled the same way: a [`Dispatch::Load`] books a
//! `PlacementDone` at `now + load latency`, so cold starts share the one
//! event heap with batch completions.
//!
//! **Sharded replay (DESIGN.md §11).** Cluster-scale sweeps (hundreds of
//! replicas, millions of requests) are bounded by the single sequential
//! pump, so [`run_cluster_sharded`] partitions the replicas into
//! contiguous *event lanes*, each with its own virtual-time domain,
//! running on std scoped threads. The only cross-lane edge in a
//! [`ServingLoop::parallel_safe`] configuration is the router's arrival
//! stream, and a load-oblivious router's decisions depend only on the
//! arrival sequence and each model's static candidate set — so the
//! coordinator replays the router over the whole trace up front
//! (pre-routing), hands every lane its own arrival sub-stream, and merges
//! the per-lane completion streams afterwards with a stable time-ordered
//! merge. A single lane covering all replicas is the same code driven by
//! the same pre-routed stream, so sharded and sequential runs produce
//! byte-identical completion sequences by construction. Configurations
//! with genuine cross-replica coupling (load-aware routers, admission,
//! elastic placement, telemetry) conservatively collapse to the
//! sequential pump — the merge barrier in the limit.
//!
//! **Hot loop (§Perf).** Each lane is driven by per-slot event state (one
//! optional in-flight completion per replica plus a cached per-slot wake
//! time) and a draining iterator over the release-sorted trace: each
//! iteration touches only the replicas that actually have an event due,
//! instead of re-scanning every slot. Traces arriving already
//! release-sorted (every generator emits them sorted) skip the historical
//! unconditional O(n log n) re-sort.

use super::{Dispatch, Event, Placement, Router, ServingLoop, WorkerLoad};
use crate::clock::{ms_to_us, Micros, VirtualClock};
use crate::core::request::{Completion, ModelId, Outcome, Request};
use crate::scheduler::Scheduler;
use crate::sim::engine::EngineResult;
use crate::sim::worker::Worker;
use crate::telemetry::EventKind;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};

/// Run the trace to completion on a cluster; `workers[i]` executes the
/// batches of replica `i`.
pub fn run_cluster<S: Scheduler, W: Worker>(
    core: ServingLoop<VirtualClock, S>,
    workers: Vec<W>,
    requests: Vec<Request>,
) -> EngineResult {
    run_cluster_sharded(core, workers, requests, 1)
}

/// [`run_cluster`] over `shards` parallel event lanes (DESIGN.md §11).
/// `shards = 1` is the sequential pump; larger values run contiguous
/// replica ranges on scoped threads when the configuration is
/// [`ServingLoop::parallel_safe`], and conservatively fall back to the
/// sequential pump otherwise. Either way the completion sequence is
/// byte-identical to the sequential run's.
pub fn run_cluster_sharded<S: Scheduler, W: Worker>(
    core: ServingLoop<VirtualClock, S>,
    workers: Vec<W>,
    requests: Vec<Request>,
    shards: usize,
) -> EngineResult {
    assert_eq!(
        workers.len(),
        core.workers(),
        "one executor per scheduling replica"
    );
    if core.parallel_safe() {
        run_prerouted(core, workers, requests, shards, &mut |_, _| {})
    } else {
        run_sequential(core, workers, requests, &mut |_, _| {})
    }
}

/// [`run_cluster`] with a dispatch observer: `on_dispatch(now, d)` fires
/// for every dispatch decision — batch executions *and* placement
/// loads/unloads — in virtual-time order (the golden dispatch-sequence
/// regression tests record these). Observed runs always use a single
/// event lane: the observer is one global time-ordered stream.
pub fn run_cluster_traced<S, W, F>(
    core: ServingLoop<VirtualClock, S>,
    workers: Vec<W>,
    requests: Vec<Request>,
    mut on_dispatch: F,
) -> EngineResult
where
    S: Scheduler,
    W: Worker,
    F: FnMut(Micros, &Dispatch),
{
    assert_eq!(
        workers.len(),
        core.workers(),
        "one executor per scheduling replica"
    );
    if core.parallel_safe() {
        run_prerouted(core, workers, requests, 1, &mut on_dispatch)
    } else {
        run_sequential(core, workers, requests, &mut on_dispatch)
    }
}

/// Sort by release only when the trace is not already sorted: every
/// generator emits release-sorted streams, so million-request traces
/// skip the O(n log n) re-sort and stream straight into the pump.
fn ensure_release_sorted(requests: &mut [Request]) {
    if !requests.windows(2).all(|w| w[0].release <= w[1].release) {
        requests.sort_by_key(|r| r.release);
    }
}

/// The sequential pump: one event loop, one virtual-time domain, every
/// coupling (load-aware routing, admission, elastic placement, telemetry)
/// observed at exact global event order. This is the reference semantics
/// the sharded pump must reproduce.
fn run_sequential<S, W, F>(
    mut core: ServingLoop<VirtualClock, S>,
    mut workers: Vec<W>,
    mut requests: Vec<Request>,
    on_dispatch: &mut F,
) -> EngineResult
where
    S: Scheduler,
    W: Worker,
    F: FnMut(Micros, &Dispatch),
{
    ensure_release_sorted(&mut requests);
    let clock = core.clock().clone();
    let n = workers.len();
    // The event heap holds one (finish time, worker) entry per in-flight
    // batch; same-time completions pop in worker order, matching the
    // historical per-worker scan. The measured batch time rides in a side
    // slot (f64 is not Ord). Model loads get their own small heap so the
    // static path's heap discipline is untouched.
    let mut done: BinaryHeap<Reverse<(Micros, usize)>> = BinaryHeap::with_capacity(n);
    let mut done_ms = vec![0.0f64; n];
    let mut loads: BinaryHeap<Reverse<(Micros, usize, u32)>> = BinaryHeap::new();
    let mut loads_ms = vec![0.0f64; n];
    // A worker is one execution resource: loads and batches dispatched to
    // it serialize, exactly like the realtime pump's per-worker channel
    // (a load landing behind a running batch starts when the batch
    // finishes). Static runs only ever dispatch to idle workers, so this
    // never moves a batch completion there.
    let mut busy_until: Vec<Micros> = vec![0; n];
    let mut arrivals = requests.into_iter().peekable();
    let mut steps = 0usize;

    loop {
        let now = clock.now();
        // Deliver all arrivals due now, draining the trace in place.
        while arrivals.peek().is_some_and(|r| r.release <= now) {
            core.on_event(Event::Arrival(arrivals.next().unwrap()));
        }
        // Complete every model load that is due (installs must land
        // before dispatching, so a finished replica is routable at once).
        while let Some(&Reverse((t, w, m))) = loads.peek() {
            if t > now {
                break;
            }
            loads.pop();
            core.on_event(Event::PlacementDone {
                worker: w,
                model: ModelId(m),
                load_ms: loads_ms[w],
            });
        }
        // Complete every in-flight batch that is due.
        while let Some(&Reverse((t, w))) = done.peek() {
            if t > now {
                break;
            }
            done.pop();
            core.on_event(Event::BatchDone {
                worker: w,
                batch_ms: done_ms[w],
            });
        }
        // Drain drops, run the placement controller, dispatch.
        for d in core.on_event(Event::Wake) {
            on_dispatch(now, &d);
            match d {
                Dispatch::Execute { worker, batch } => {
                    let ms = workers[worker].execute(&batch);
                    done_ms[worker] = ms;
                    let start = busy_until[worker].max(now);
                    let fin = start + ms_to_us(ms);
                    busy_until[worker] = fin;
                    // Execution begins when the worker frees, not at
                    // dispatch: stamp the span's start accordingly.
                    if let Some(tel) = core.telemetry_mut() {
                        if let Some(b) = tel.last_batch_for(worker) {
                            tel.record(
                                start,
                                EventKind::ExecStart {
                                    batch: b,
                                    worker: worker as u32,
                                },
                            );
                        }
                    }
                    done.push(Reverse((fin, worker)));
                }
                Dispatch::Load {
                    worker,
                    model,
                    cost_ms,
                } => {
                    let ms = workers[worker].load_model(model, cost_ms);
                    loads_ms[worker] = ms;
                    let fin = busy_until[worker].max(now) + ms_to_us(ms).max(1);
                    busy_until[worker] = fin;
                    loads.push(Reverse((fin, worker, model.0)));
                }
                Dispatch::Unload { worker, model } => {
                    workers[worker].unload_model(model);
                }
            }
        }
        // Everything delivered and drained → done.
        if arrivals.peek().is_none()
            && done.is_empty()
            && loads.is_empty()
            && core.pending() == 0
        {
            core.drain_all();
            break;
        }
        // Advance to the next event: arrival, completion, load, or wake.
        // `next_wake` jumps to the earliest tracked deadline when a
        // policy's wake hint is silent, so a sparse trace completes in
        // O(events) advances instead of crawling in 1 ms hops.
        let mut next: Option<Micros> = arrivals.peek().map(|r| r.release);
        if let Some(&Reverse((t, _))) = done.peek() {
            next = Some(next.map_or(t, |v| v.min(t)));
        }
        if let Some(&Reverse((t, _, _))) = loads.peek() {
            next = Some(next.map_or(t, |v| v.min(t)));
        }
        if let Some(h) = core.next_wake(now) {
            next = Some(next.map_or(h, |v| v.min(h)));
        }
        steps += 1;
        match next {
            Some(t) if t > now => clock.advance_to(t),
            Some(_) => clock.advance_to(now + 1), // same-time event loop guard
            // Unreachable in practice (`next_wake` returns Some whenever
            // queued work remains) — kept as a defensive slow crawl.
            None => clock.advance_to(now + 1_000),
        }
    }

    let end_time = clock.now();
    let placement = core.placement_stats();
    let admission = core.admission_stats();
    let telemetry = core.take_telemetry();
    let (completions, per_worker) = core.into_completions();
    let batches = per_worker.iter().map(|w| w.batches).sum();
    let busy_us = per_worker.iter().map(|w| w.busy_us).sum();
    EngineResult {
        completions,
        end_time,
        batches,
        busy_us,
        per_worker,
        placement,
        admission,
        telemetry,
        steps,
    }
}

// ---------------------------------------------------------------------
// Sharded pump (parallel-safe configurations; DESIGN.md §11)
// ---------------------------------------------------------------------

/// Replays the coordinator's pre-computed routing decisions inside a
/// shard's sub-loop: `route` pops the next target slot (in the shard's
/// local ids) and returns its rank in the candidate snapshot. Decisions
/// were made once, globally, in arrival order — this router never
/// re-decides, so shard-local candidate sets cannot skew routing.
struct Prerouted {
    targets: VecDeque<u32>,
}

impl Router for Prerouted {
    fn name(&self) -> &'static str {
        "prerouted"
    }

    fn route(&mut self, _req: &Request, loads: &[WorkerLoad]) -> usize {
        let target = self
            .targets
            .pop_front()
            .expect("one pre-routed target per arrival") as usize;
        loads
            .iter()
            .position(|l| l.worker == target)
            .expect("pre-routed target hosts the model")
    }

    fn load_oblivious(&self) -> bool {
        true
    }
}

/// One lane's results, in the lane's local processing order.
struct ShardOut {
    completions: Vec<Completion>,
    per_worker: Vec<crate::serve::WorkerStats>,
    end_time: Micros,
    steps: usize,
}

/// Drive one shard (a contiguous replica range re-indexed from 0) to
/// completion on its own virtual-time domain. `arrivals` carries each
/// request's pre-routed local slot so the pump knows which replica to
/// poll; `reap` is the *global* multi-replica gate (a one-slot shard of a
/// four-replica cluster still reaps). The per-slot cadence — deliver all
/// of a slot's due events, then poll it once — is identical whether the
/// shard covers one replica or all of them, which is what makes sharded
/// and sequential runs byte-identical.
fn shard_pump<S, W, F>(
    mut core: ServingLoop<VirtualClock, S>,
    mut workers: Vec<W>,
    arrivals: Vec<(Request, u32)>,
    reap: bool,
    on_dispatch: &mut F,
) -> ShardOut
where
    S: Scheduler,
    W: Worker,
    F: FnMut(Micros, &Dispatch),
{
    let clock = core.clock().clone();
    let n = workers.len();
    // Per-slot event state: at most one batch in flight per replica, so a
    // plain option per slot replaces the global heap.
    let mut done: Vec<Option<(Micros, f64)>> = vec![None; n];
    let mut busy_until: Vec<Micros> = vec![0; n];
    // Cached per-slot wake time, recomputed only when the slot's state
    // changes (delivery or poll) — the advance step takes the min without
    // re-asking every scheduler.
    let mut wake: Vec<Option<Micros>> = vec![None; n];
    let mut touched = vec![false; n];
    let mut arrivals = arrivals.into_iter().peekable();
    let mut steps = 0usize;

    loop {
        let now = clock.now();
        // Deliver all arrivals due now; remember which slots they hit.
        while arrivals.peek().is_some_and(|(r, _)| r.release <= now) {
            let (req, slot) = arrivals.next().unwrap();
            touched[slot as usize] = true;
            core.on_event(Event::Arrival(req));
        }
        // Complete every in-flight batch that is due.
        for w in 0..n {
            if done[w].is_some_and(|(t, _)| t <= now) {
                let (_, ms) = done[w].take().unwrap();
                touched[w] = true;
                core.on_event(Event::BatchDone {
                    worker: w,
                    batch_ms: ms,
                });
            }
        }
        // Poll exactly the slots with an event or a due wake: deliver-all-
        // then-poll-once per slot, so same-time arrivals still co-batch.
        for w in 0..n {
            let wake_due = wake[w].is_some_and(|t| t <= now);
            if !(touched[w] || wake_due) {
                continue;
            }
            touched[w] = false;
            if let Some(d) = core.poll_slot(w, reap) {
                on_dispatch(now, &d);
                match d {
                    Dispatch::Execute { worker, batch } => {
                        let ms = workers[worker].execute(&batch);
                        let fin = busy_until[worker].max(now) + ms_to_us(ms);
                        busy_until[worker] = fin;
                        done[worker] = Some((fin, ms));
                    }
                    other => unreachable!("parallel-safe run produced {other:?}"),
                }
            }
            wake[w] = core.slot_wake(w, now);
        }
        // Everything delivered and drained → done.
        if arrivals.peek().is_none() && done.iter().all(Option::is_none) && core.pending() == 0 {
            core.drain_all();
            break;
        }
        // Advance to this lane's next event: arrival, completion, or wake.
        let mut next: Option<Micros> = arrivals.peek().map(|(r, _)| r.release);
        for w in 0..n {
            for t in done[w].map(|(t, _)| t).into_iter().chain(wake[w]) {
                next = Some(next.map_or(t, |v| v.min(t)));
            }
        }
        steps += 1;
        match next {
            Some(t) if t > now => clock.advance_to(t),
            Some(_) => clock.advance_to(now + 1), // same-time event loop guard
            None => unreachable!("no next event but the lane has not drained"),
        }
    }

    let end_time = clock.now();
    let (completions, per_worker) = core.into_completions();
    ShardOut {
        completions,
        per_worker,
        end_time,
        steps,
    }
}

/// The sharded pump for [`ServingLoop::parallel_safe`] configurations:
/// pre-route the whole arrival stream on the coordinator (the router is
/// load-oblivious, so its decisions need only each model's static
/// candidate set), partition the replicas into `shards` contiguous lanes,
/// drive every lane independently — on scoped threads when `shards > 1` —
/// and merge the completion streams with a stable time-ordered merge.
/// One lane reproduces the sequential pump exactly; K lanes reproduce one
/// lane exactly because every decision a lane makes is local to it.
fn run_prerouted<S, W, F>(
    core: ServingLoop<VirtualClock, S>,
    workers: Vec<W>,
    mut requests: Vec<Request>,
    shards: usize,
    on_dispatch: &mut F,
) -> EngineResult
where
    S: Scheduler,
    W: Worker,
    F: FnMut(Micros, &Dispatch),
{
    ensure_release_sorted(&mut requests);
    let n = workers.len();
    let shards = shards.clamp(1, n);
    let (_clock, scheds, placement, mut router) = core.into_shard_parts();

    // Contiguous replica ranges: shard s covers [lo[s], lo[s + 1]).
    let mut lo = vec![0usize; shards + 1];
    for (s, bound) in lo.iter_mut().enumerate().skip(1) {
        *bound = s * n / shards;
    }
    lo[shards] = n;
    let shard_of = |w: usize| -> usize {
        // Ranges are near-equal, so a scan over `shards` entries is fine
        // off the per-arrival path; on it we cache per model below.
        (1..=shards).find(|&s| w < lo[s]).unwrap() - 1
    };

    // Pre-route: replay the router over the whole trace in arrival order.
    // Candidate sets are static (no elastic placement), so they are cached
    // per model; the load fields are zeroed — a load-oblivious router must
    // not read them (`Router::load_oblivious` contract).
    let mut cands: HashMap<u32, Vec<usize>> = HashMap::new();
    let mut loads_buf: Vec<WorkerLoad> = Vec::with_capacity(n);
    let mut coord_drops: Vec<Completion> = Vec::new();
    let mut lanes: Vec<Vec<(Request, u32)>> = (0..shards).map(|_| Vec::new()).collect();
    let mut lane_targets: Vec<VecDeque<u32>> = (0..shards).map(|_| VecDeque::new()).collect();
    for req in requests {
        let c = cands.entry(req.model.0).or_insert_with(|| {
            (0..n).filter(|&w| placement.hosts(w, req.model)).collect()
        });
        if c.is_empty() {
            // No replica hosts this model: terminal drop at the arrival
            // instant, exactly where the sequential route() drops it.
            coord_drops.push(Completion {
                at: req.release,
                request: req,
                outcome: Outcome::TimedOut,
                batch_size: 0,
                worker: None,
                best_effort: false,
            });
            continue;
        }
        loads_buf.clear();
        loads_buf.extend(c.iter().map(|&w| WorkerLoad {
            worker: w,
            pending: 0,
            pending_model: 0,
            in_flight: 0,
        }));
        let i = router.route(&req, &loads_buf);
        assert!(i < c.len(), "router index out of candidate range");
        let w = c[i];
        let s = shard_of(w);
        let local = (w - lo[s]) as u32;
        lanes[s].push((req, local));
        lane_targets[s].push_back(local);
    }

    // Re-assemble per-shard sub-loops from the seeded schedulers. Each
    // lane owns a fresh virtual clock (its own time domain), the replica
    // range's placement restriction, and the pre-routed target stream.
    let reap = n > 1;
    let mut scheds = scheds;
    let mut workers = workers;
    let mut shard_inputs = Vec::with_capacity(shards);
    for s in (0..shards).rev() {
        let scheds_s: Vec<S> = scheds.split_off(lo[s]);
        let workers_s: Vec<W> = workers.split_off(lo[s]);
        let sub_placement = if placement.is_unconstrained() {
            Placement::unconstrained(scheds_s.len())
        } else {
            Placement::new(
                (lo[s]..lo[s + 1])
                    .map(|w| placement.hosted_on(w).map(<[ModelId]>::to_vec).unwrap_or_default())
                    .collect(),
            )
        };
        let sub_core = ServingLoop::new(
            VirtualClock::new(),
            crate::serve::Cluster::with_placement(scheds_s, sub_placement),
            Box::new(Prerouted {
                targets: std::mem::take(&mut lane_targets[s]),
            }),
        );
        shard_inputs.push((sub_core, workers_s, std::mem::take(&mut lanes[s])));
    }
    shard_inputs.reverse();

    let outs: Vec<ShardOut> = if shards == 1 {
        let (sub_core, workers_s, lane) = shard_inputs.pop().unwrap();
        vec![shard_pump(sub_core, workers_s, lane, reap, on_dispatch)]
    } else {
        std::thread::scope(|scope| {
            let handles: Vec<_> = shard_inputs
                .into_iter()
                .map(|(sub_core, workers_s, lane)| {
                    scope.spawn(move || {
                        shard_pump(sub_core, workers_s, lane, reap, &mut |_, _| {})
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("shard lane panicked"))
                .collect()
        })
    };

    // Stable time-ordered merge: every stream is already sorted by
    // completion time, and at equal times the concatenation order
    // (coordinator drops, then lanes in replica order) is exactly the
    // sequential pump's processing order — a stable sort by `at` is the
    // k-way merge.
    let mut completions = coord_drops;
    let mut per_worker = Vec::with_capacity(n);
    let mut end_time = 0;
    let mut steps = 0usize;
    for (s, out) in outs.into_iter().enumerate() {
        completions.extend(out.completions);
        per_worker.extend(out.per_worker.into_iter().map(|mut ws| {
            ws.worker += lo[s];
            ws
        }));
        end_time = end_time.max(out.end_time);
        steps += out.steps;
    }
    completions.sort_by_key(|c| c.at);
    let batches = per_worker.iter().map(|w| w.batches).sum();
    let busy_us = per_worker.iter().map(|w| w.busy_us).sum();
    EngineResult {
        completions,
        end_time,
        batches,
        busy_us,
        per_worker,
        placement: Default::default(),
        admission: Default::default(),
        telemetry: None,
        steps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::edf::EdfScheduler;
    use crate::core::batchmodel::BatchCostModel;
    use crate::core::request::{AppId, Outcome};
    use crate::scheduler::SchedulerConfig;
    use crate::serve::{
        router, Cluster, ColdStartCost, ElasticConfig, Placement, PlacementController,
    };
    use crate::sim::worker::SimWorker;

    fn cluster(n: usize) -> Cluster<EdfScheduler> {
        let cfg = SchedulerConfig {
            cost_model: BatchCostModel::new(0.0, 1.0),
            ..Default::default()
        };
        Cluster::new(
            (0..n)
                .map(|_| {
                    let mut s = EdfScheduler::new(cfg.clone(), 0);
                    s.seed_exec_mean(10.0);
                    s
                })
                .collect(),
        )
    }

    fn workers(n: usize) -> Vec<SimWorker> {
        (0..n)
            .map(|w| SimWorker::new(BatchCostModel::new(0.0, 1.0), 0.0, w as u64))
            .collect()
    }

    fn requests(n: u64, gap_ms: f64, slo_ms: f64) -> Vec<Request> {
        (0..n)
            .map(|i| {
                Request::new(
                    i,
                    AppId(0),
                    ms_to_us(i as f64 * gap_ms),
                    ms_to_us(slo_ms),
                    10.0,
                )
            })
            .collect()
    }

    #[test]
    fn two_replicas_split_the_work() {
        let core = ServingLoop::new(
            VirtualClock::new(),
            cluster(2),
            router::by_name("round_robin").unwrap(),
        );
        let res = run_cluster(core, workers(2), requests(60, 5.0, 1_000.0));
        assert_eq!(res.completions.len(), 60);
        assert_eq!(res.per_worker.len(), 2);
        assert!(res.per_worker.iter().all(|w| w.batches > 0));
        assert_eq!(
            res.batches,
            res.per_worker.iter().map(|w| w.batches).sum::<usize>()
        );
        assert_eq!(
            res.busy_us,
            res.per_worker.iter().map(|w| w.busy_us).sum::<u64>()
        );
        assert_eq!(res.placement.actions(), 0, "static runs take no actions");
        assert!(res.steps > 0, "the pump reports its advance count");
    }

    #[test]
    fn traced_pump_sees_every_dispatch_in_time_order() {
        let core = ServingLoop::new(
            VirtualClock::new(),
            cluster(2),
            router::by_name("round_robin").unwrap(),
        );
        let mut times: Vec<Micros> = Vec::new();
        let mut dispatched = 0usize;
        let mut batches = 0usize;
        let res = run_cluster_traced(core, workers(2), requests(40, 4.0, 1_000.0), |t, d| {
            times.push(t);
            match d {
                Dispatch::Execute { worker, batch } => {
                    dispatched += batch.len();
                    batches += 1;
                    assert!(*worker < 2);
                    assert!(!batch.is_empty());
                }
                other => panic!("static run produced {other:?}"),
            }
        });
        assert_eq!(batches, res.batches, "observer sees every dispatch");
        let executed = res
            .completions
            .iter()
            .filter(|c| c.outcome == Outcome::Finished || c.outcome == Outcome::Late)
            .count();
        assert_eq!(dispatched, executed, "every executed request was observed");
        assert!(times.windows(2).all(|w| w[0] <= w[1]), "virtual-time order");
    }

    #[test]
    fn more_replicas_rescue_an_overloaded_trace() {
        // 1 req/ms with 10 ms exec: hopeless for one worker, easy for four.
        let finished = |n: usize| {
            let core = ServingLoop::new(
                VirtualClock::new(),
                cluster(n),
                router::by_name("join_shortest_queue").unwrap(),
            );
            let res = run_cluster(core, workers(n), requests(200, 1.0, 60.0));
            assert_eq!(res.completions.len(), 200, "conservation at n={n}");
            res.completions
                .iter()
                .filter(|c| c.outcome == Outcome::Finished)
                .count()
        };
        let one = finished(1);
        let four = finished(4);
        assert!(four > one, "4 workers ({four}) must beat 1 ({one})");
        assert!(four > 150, "4 workers should clear most of the load: {four}");
    }

    #[test]
    fn sharded_lanes_match_the_sequential_pump() {
        // The by-construction determinism claim, in miniature: identical
        // completion sequences (order included) for 1, 2 and 4 lanes over
        // a bursty round-robin trace with drops.
        let run = |shards: usize| {
            let core = ServingLoop::new(
                VirtualClock::new(),
                cluster(4),
                router::by_name("round_robin").unwrap(),
            );
            run_cluster_sharded(core, workers(4), requests(300, 0.8, 40.0), shards)
        };
        let seq = run(1);
        assert_eq!(seq.completions.len(), 300, "conservation");
        let seq_dbg = format!("{:?}", seq.completions);
        for shards in [2, 4] {
            let par = run(shards);
            assert_eq!(
                format!("{:?}", par.completions),
                seq_dbg,
                "{shards} lanes must replay the sequential completion sequence"
            );
            assert_eq!(par.end_time, seq.end_time, "{shards} lanes: end time");
            assert_eq!(
                format!("{:?}", par.per_worker),
                format!("{:?}", seq.per_worker),
                "{shards} lanes: per-replica stats"
            );
        }
    }

    #[test]
    fn sharding_a_coupled_config_falls_back_to_sequential() {
        // A load-aware router is a cross-lane edge on every arrival: the
        // sharded entry point must produce the sequential pump's result
        // verbatim (conservative fallback).
        let run = |shards: usize| {
            let core = ServingLoop::new(
                VirtualClock::new(),
                cluster(3),
                router::by_name("least_loaded").unwrap(),
            );
            run_cluster_sharded(core, workers(3), requests(150, 1.5, 200.0), shards)
        };
        let a = format!("{:?}", run(1).completions);
        let b = format!("{:?}", run(4).completions);
        assert_eq!(a, b);
    }

    #[test]
    fn elastic_load_completes_on_the_virtual_clock() {
        // Two workers, partition placement, single-model trace: the
        // controller replicates model 0 onto worker 1 after a cold start,
        // and the pump books the PlacementDone like a batch completion.
        let cfg = SchedulerConfig {
            cost_model: BatchCostModel::new(0.0, 1.0),
            ..Default::default()
        };
        let scheds: Vec<EdfScheduler> = (0..2)
            .map(|_| {
                let mut s = EdfScheduler::new(cfg.clone(), 0);
                s.seed_exec_mean(10.0);
                s
            })
            .collect();
        let placement = Placement::parse("partition", 2, 2).unwrap();
        let cluster = Cluster::with_placement(scheds, placement);
        let ctl = PlacementController::new(ElasticConfig {
            capacity: 2,
            interval_us: 10_000,
            alpha: 1.0,
            min_dwell_us: 0,
            cold_start: ColdStartCost::new(10.0, 10.0),
        });
        let core = ServingLoop::new(
            VirtualClock::new(),
            cluster,
            router::by_name("least_loaded").unwrap(),
        )
        .with_elastic(ctl);
        let mut load_seen_at: Option<Micros> = None;
        let mut first_exec_w1: Option<Micros> = None;
        let res = run_cluster_traced(core, workers(2), requests(120, 1.0, 2_000.0), |t, d| {
            match d {
                Dispatch::Load { worker: 1, model: ModelId(0), cost_ms } => {
                    assert!((cost_ms - 20.0).abs() < 1e-9);
                    if load_seen_at.is_none() {
                        load_seen_at = Some(t);
                    }
                }
                Dispatch::Execute { worker: 1, batch } if batch[0].model == ModelId(0) => {
                    if first_exec_w1.is_none() {
                        first_exec_w1 = Some(t);
                    }
                }
                _ => {}
            }
        });
        assert_eq!(res.completions.len(), 120, "conservation under elastic");
        let loaded = load_seen_at.expect("controller should replicate the hot model");
        assert!(res.placement.loads >= 1);
        if let Some(t1) = first_exec_w1 {
            assert!(
                t1 >= loaded + ms_to_us(20.0),
                "worker 1 executed model 0 at {t1} before its load finished ({loaded} + 20ms)"
            );
        }
    }
}
