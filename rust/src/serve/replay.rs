//! Virtual-time pump: replays a recorded trace through a
//! [`ServingLoop`](super::ServingLoop) cluster, advancing a shared
//! [`VirtualClock`] from event to event (the discrete-event substrate
//! behind every table and figure reproduction).
//!
//! Batch executions cost zero wall time: worker `w`'s simulated latency
//! schedules a `BatchDone` at `now + latency`, exactly as the historical
//! single-worker `sim::engine` did — but for N replicas at once.

use super::{Event, ServingLoop};
use crate::clock::{ms_to_us, Micros, VirtualClock};
use crate::core::request::Request;
use crate::scheduler::Scheduler;
use crate::sim::engine::EngineResult;
use crate::sim::worker::Worker;

/// Run the trace to completion on a cluster; `workers[i]` executes the
/// batches of replica `i`.
pub fn run_cluster<S: Scheduler, W: Worker>(
    mut core: ServingLoop<VirtualClock, S>,
    mut workers: Vec<W>,
    mut requests: Vec<Request>,
) -> EngineResult {
    assert_eq!(
        workers.len(),
        core.workers(),
        "one executor per scheduling replica"
    );
    requests.sort_by_key(|r| r.release);
    let clock = core.clock().clone();
    let n = workers.len();
    // Per-replica pending completion: (virtual finish time, batch ms).
    let mut done_at: Vec<Option<(Micros, f64)>> = vec![None; n];
    let mut next_arrival = 0usize;

    loop {
        let now = clock.now();
        // Deliver all arrivals due now.
        while next_arrival < requests.len() && requests[next_arrival].release <= now {
            core.on_event(Event::Arrival(requests[next_arrival].clone()));
            next_arrival += 1;
        }
        // Complete every in-flight batch that is due.
        for (w, slot) in done_at.iter_mut().enumerate() {
            if let Some((t, ms)) = *slot {
                if t <= now {
                    *slot = None;
                    core.on_event(Event::BatchDone {
                        worker: w,
                        batch_ms: ms,
                    });
                }
            }
        }
        // Drain drops and dispatch to every idle replica.
        for d in core.on_event(Event::Wake) {
            let ms = workers[d.worker].execute(&d.batch);
            done_at[d.worker] = Some((now + ms_to_us(ms), ms));
        }
        // Everything delivered and drained → done.
        if next_arrival >= requests.len()
            && done_at.iter().all(|d| d.is_none())
            && core.pending() == 0
        {
            core.drain_all();
            break;
        }
        // Advance to the next event: arrival, completion, or wake.
        let mut next: Option<Micros> = None;
        let mut consider = |t: Micros| {
            next = Some(match next {
                Some(n) => n.min(t),
                None => t,
            });
        };
        if next_arrival < requests.len() {
            consider(requests[next_arrival].release);
        }
        for slot in &done_at {
            if let Some((t, _)) = *slot {
                consider(t);
            }
        }
        if let Some(h) = core.next_wake(now) {
            consider(h);
        }
        match next {
            Some(t) if t > now => clock.advance_to(t),
            Some(_) => clock.advance_to(now + 1), // same-time event loop guard
            None => clock.advance_to(now + 1_000),
        }
    }

    let end_time = clock.now();
    let (completions, per_worker) = core.into_completions();
    let batches = per_worker.iter().map(|w| w.batches).sum();
    let busy_us = per_worker.iter().map(|w| w.busy_us).sum();
    EngineResult {
        completions,
        end_time,
        batches,
        busy_us,
        per_worker,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::edf::EdfScheduler;
    use crate::core::batchmodel::BatchCostModel;
    use crate::core::request::{AppId, Outcome};
    use crate::scheduler::SchedulerConfig;
    use crate::serve::{router, Cluster};
    use crate::sim::worker::SimWorker;

    fn cluster(n: usize) -> Cluster<EdfScheduler> {
        let cfg = SchedulerConfig {
            cost_model: BatchCostModel::new(0.0, 1.0),
            ..Default::default()
        };
        Cluster::new(
            (0..n)
                .map(|_| {
                    let mut s = EdfScheduler::new(cfg.clone(), 0);
                    s.seed_exec_mean(10.0);
                    s
                })
                .collect(),
        )
    }

    fn workers(n: usize) -> Vec<SimWorker> {
        (0..n)
            .map(|w| SimWorker::new(BatchCostModel::new(0.0, 1.0), 0.0, w as u64))
            .collect()
    }

    fn requests(n: u64, gap_ms: f64, slo_ms: f64) -> Vec<Request> {
        (0..n)
            .map(|i| {
                Request::new(
                    i,
                    AppId(0),
                    ms_to_us(i as f64 * gap_ms),
                    ms_to_us(slo_ms),
                    10.0,
                )
            })
            .collect()
    }

    #[test]
    fn two_replicas_split_the_work() {
        let core = ServingLoop::new(
            VirtualClock::new(),
            cluster(2),
            router::by_name("round_robin").unwrap(),
        );
        let res = run_cluster(core, workers(2), requests(60, 5.0, 1_000.0));
        assert_eq!(res.completions.len(), 60);
        assert_eq!(res.per_worker.len(), 2);
        assert!(res.per_worker.iter().all(|w| w.batches > 0));
        assert_eq!(
            res.batches,
            res.per_worker.iter().map(|w| w.batches).sum::<usize>()
        );
        assert_eq!(
            res.busy_us,
            res.per_worker.iter().map(|w| w.busy_us).sum::<u64>()
        );
    }

    #[test]
    fn more_replicas_rescue_an_overloaded_trace() {
        // 1 req/ms with 10 ms exec: hopeless for one worker, easy for four.
        let finished = |n: usize| {
            let core = ServingLoop::new(
                VirtualClock::new(),
                cluster(n),
                router::by_name("join_shortest_queue").unwrap(),
            );
            let res = run_cluster(core, workers(n), requests(200, 1.0, 60.0));
            assert_eq!(res.completions.len(), 200, "conservation at n={n}");
            res.completions
                .iter()
                .filter(|c| c.outcome == Outcome::Finished)
                .count()
        };
        let one = finished(1);
        let four = finished(4);
        assert!(four > one, "4 workers ({four}) must beat 1 ({one})");
        assert!(four > 150, "4 workers should clear most of the load: {four}");
    }
}
