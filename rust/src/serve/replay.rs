//! Virtual-time pump: replays a recorded trace through a
//! [`ServingLoop`](super::ServingLoop) cluster, advancing a shared
//! [`VirtualClock`] from event to event (the discrete-event substrate
//! behind every table and figure reproduction).
//!
//! Batch executions cost zero wall time: worker `w`'s simulated latency
//! schedules a `BatchDone` at `now + latency`, exactly as the historical
//! single-worker `sim::engine` did — but for N replicas at once.
//!
//! **Hot loop (§Perf).** The pump is driven by a single min-heap of
//! pending `(finish time, worker)` completions plus a draining iterator
//! over the release-sorted trace: each iteration touches only the events
//! that are actually due, instead of re-scanning every worker slot and
//! re-deriving the next event time from all N of them. Requests are moved
//! out of the trace by value — the historical per-arrival `Request` clone
//! is gone.

use super::{Dispatch, Event, ServingLoop};
use crate::clock::{ms_to_us, Micros, VirtualClock};
use crate::core::request::Request;
use crate::scheduler::Scheduler;
use crate::sim::engine::EngineResult;
use crate::sim::worker::Worker;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Run the trace to completion on a cluster; `workers[i]` executes the
/// batches of replica `i`.
pub fn run_cluster<S: Scheduler, W: Worker>(
    core: ServingLoop<VirtualClock, S>,
    workers: Vec<W>,
    requests: Vec<Request>,
) -> EngineResult {
    run_cluster_traced(core, workers, requests, |_, _| {})
}

/// [`run_cluster`] with a dispatch observer: `on_dispatch(now, d)` fires
/// for every dispatch decision in virtual-time order (the golden
/// dispatch-sequence regression tests record these).
pub fn run_cluster_traced<S, W, F>(
    mut core: ServingLoop<VirtualClock, S>,
    mut workers: Vec<W>,
    mut requests: Vec<Request>,
    mut on_dispatch: F,
) -> EngineResult
where
    S: Scheduler,
    W: Worker,
    F: FnMut(Micros, &Dispatch),
{
    assert_eq!(
        workers.len(),
        core.workers(),
        "one executor per scheduling replica"
    );
    requests.sort_by_key(|r| r.release);
    let clock = core.clock().clone();
    let n = workers.len();
    // The event heap holds one (finish time, worker) entry per in-flight
    // batch; same-time completions pop in worker order, matching the
    // historical per-worker scan. The measured batch time rides in a side
    // slot (f64 is not Ord).
    let mut done: BinaryHeap<Reverse<(Micros, usize)>> = BinaryHeap::with_capacity(n);
    let mut done_ms = vec![0.0f64; n];
    let mut arrivals = requests.into_iter().peekable();

    loop {
        let now = clock.now();
        // Deliver all arrivals due now, draining the trace in place.
        while arrivals.peek().is_some_and(|r| r.release <= now) {
            core.on_event(Event::Arrival(arrivals.next().unwrap()));
        }
        // Complete every in-flight batch that is due.
        while let Some(&Reverse((t, w))) = done.peek() {
            if t > now {
                break;
            }
            done.pop();
            core.on_event(Event::BatchDone {
                worker: w,
                batch_ms: done_ms[w],
            });
        }
        // Drain drops and dispatch to every idle replica.
        for d in core.on_event(Event::Wake) {
            let ms = workers[d.worker].execute(&d.batch);
            on_dispatch(now, &d);
            done_ms[d.worker] = ms;
            done.push(Reverse((now + ms_to_us(ms), d.worker)));
        }
        // Everything delivered and drained → done.
        if arrivals.peek().is_none() && done.is_empty() && core.pending() == 0 {
            core.drain_all();
            break;
        }
        // Advance to the next event: arrival, completion, or wake.
        let mut next: Option<Micros> = arrivals.peek().map(|r| r.release);
        if let Some(&Reverse((t, _))) = done.peek() {
            next = Some(next.map_or(t, |v| v.min(t)));
        }
        if let Some(h) = core.next_wake(now) {
            next = Some(next.map_or(h, |v| v.min(h)));
        }
        match next {
            Some(t) if t > now => clock.advance_to(t),
            Some(_) => clock.advance_to(now + 1), // same-time event loop guard
            None => clock.advance_to(now + 1_000),
        }
    }

    let end_time = clock.now();
    let (completions, per_worker) = core.into_completions();
    let batches = per_worker.iter().map(|w| w.batches).sum();
    let busy_us = per_worker.iter().map(|w| w.busy_us).sum();
    EngineResult {
        completions,
        end_time,
        batches,
        busy_us,
        per_worker,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::edf::EdfScheduler;
    use crate::core::batchmodel::BatchCostModel;
    use crate::core::request::{AppId, Outcome};
    use crate::scheduler::SchedulerConfig;
    use crate::serve::{router, Cluster};
    use crate::sim::worker::SimWorker;

    fn cluster(n: usize) -> Cluster<EdfScheduler> {
        let cfg = SchedulerConfig {
            cost_model: BatchCostModel::new(0.0, 1.0),
            ..Default::default()
        };
        Cluster::new(
            (0..n)
                .map(|_| {
                    let mut s = EdfScheduler::new(cfg.clone(), 0);
                    s.seed_exec_mean(10.0);
                    s
                })
                .collect(),
        )
    }

    fn workers(n: usize) -> Vec<SimWorker> {
        (0..n)
            .map(|w| SimWorker::new(BatchCostModel::new(0.0, 1.0), 0.0, w as u64))
            .collect()
    }

    fn requests(n: u64, gap_ms: f64, slo_ms: f64) -> Vec<Request> {
        (0..n)
            .map(|i| {
                Request::new(
                    i,
                    AppId(0),
                    ms_to_us(i as f64 * gap_ms),
                    ms_to_us(slo_ms),
                    10.0,
                )
            })
            .collect()
    }

    #[test]
    fn two_replicas_split_the_work() {
        let core = ServingLoop::new(
            VirtualClock::new(),
            cluster(2),
            router::by_name("round_robin").unwrap(),
        );
        let res = run_cluster(core, workers(2), requests(60, 5.0, 1_000.0));
        assert_eq!(res.completions.len(), 60);
        assert_eq!(res.per_worker.len(), 2);
        assert!(res.per_worker.iter().all(|w| w.batches > 0));
        assert_eq!(
            res.batches,
            res.per_worker.iter().map(|w| w.batches).sum::<usize>()
        );
        assert_eq!(
            res.busy_us,
            res.per_worker.iter().map(|w| w.busy_us).sum::<u64>()
        );
    }

    #[test]
    fn traced_pump_sees_every_dispatch_in_time_order() {
        let core = ServingLoop::new(
            VirtualClock::new(),
            cluster(2),
            router::by_name("round_robin").unwrap(),
        );
        let mut times: Vec<Micros> = Vec::new();
        let mut dispatched = 0usize;
        let mut batches = 0usize;
        let res = run_cluster_traced(core, workers(2), requests(40, 4.0, 1_000.0), |t, d| {
            times.push(t);
            dispatched += d.batch.len();
            batches += 1;
            assert!(d.worker < 2);
            assert!(!d.batch.is_empty());
        });
        assert_eq!(batches, res.batches, "observer sees every dispatch");
        let executed = res
            .completions
            .iter()
            .filter(|c| c.outcome == Outcome::Finished || c.outcome == Outcome::Late)
            .count();
        assert_eq!(dispatched, executed, "every executed request was observed");
        assert!(times.windows(2).all(|w| w[0] <= w[1]), "virtual-time order");
    }

    #[test]
    fn more_replicas_rescue_an_overloaded_trace() {
        // 1 req/ms with 10 ms exec: hopeless for one worker, easy for four.
        let finished = |n: usize| {
            let core = ServingLoop::new(
                VirtualClock::new(),
                cluster(n),
                router::by_name("join_shortest_queue").unwrap(),
            );
            let res = run_cluster(core, workers(n), requests(200, 1.0, 60.0));
            assert_eq!(res.completions.len(), 200, "conservation at n={n}");
            res.completions
                .iter()
                .filter(|c| c.outcome == Outcome::Finished)
                .count()
        };
        let one = finished(1);
        let four = finished(4);
        assert!(four > one, "4 workers ({four}) must beat 1 ({one})");
        assert!(four > 150, "4 workers should clear most of the load: {four}");
    }
}
