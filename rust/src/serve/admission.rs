//! Predictive admission control (DESIGN.md §10).
//!
//! Orloj's thesis — empirical exec-time distributions make deadline
//! feasibility computable — is applied here *at arrival time* instead of
//! batch formation: the controller combines the per-(model, app) solo
//! exec-time distribution with the best candidate replica's backlog
//! estimate ([`Scheduler::backlog_estimate`](crate::scheduler::Scheduler::backlog_estimate),
//! cold-start surcharges included) into P(finish ≤ deadline), then routes
//! each arrival to one of three fates:
//!
//! * **Admit** (p ≥ threshold): the request enters the SLO lane — the
//!   normal router → scheduler path, bit-identical to admission-off.
//! * **Early-reject** (p < threshold·reject_ratio): hopeless under the
//!   current backlog; terminate now instead of wasting queue space and
//!   GPU time on a request that would miss anyway.
//! * **Downgrade** (in between): parked in a best-effort FIFO lane that
//!   is served only when the SLO lane would leave a worker idle; its
//!   completions never count toward the SLO finish rate.
//!
//! A per-app deficit counter guards fairness under sustained overload:
//! every arrival accrues 1/|apps| credit to *each* app, and an admission
//! spends one credit. When the probability gate has been failing recently
//! (the contention signal), an app whose credit is exhausted yields its
//! marginal admissions (downgrade), and an app far *under* its fair share
//! gets its not-hopeless requests admitted anyway — so one hot app cannot
//! starve others of admission. Under light load the guard never bites.
//!
//! The decision path is allocation-free once the per-app table and lane
//! buffers are warm (the zero-alloc audit's bar); the only growth is
//! first-seen app/model entries, same as the telemetry recorder.

use crate::clock::{us_to_ms, Micros};
use crate::core::histogram::Histogram;
use crate::core::request::{AppId, ModelId, Request};
use crate::scheduler::FifoQueues;

/// Admission thresholds and fairness knobs.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionConfig {
    /// Admit when P(finish ≤ deadline) is at least this (CLI
    /// `--admission[=threshold]`; bare flag = 0.5).
    pub threshold: f64,
    /// Early-reject below `threshold · reject_ratio`; the band in between
    /// downgrades to best-effort.
    pub reject_ratio: f64,
    /// Per-app deficit-credit ceiling (bounds how much burst an idle app
    /// can bank).
    pub deficit_cap: f64,
    /// Credit level at which a starving app's below-threshold (but not
    /// hopeless) requests are admitted anyway.
    pub boost: f64,
    /// Max best-effort batch size (model-pure fills from the lane).
    pub be_batch: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            threshold: 0.5,
            reject_ratio: 0.25,
            deficit_cap: 8.0,
            boost: 4.0,
            be_batch: 8,
        }
    }
}

impl AdmissionConfig {
    /// Default knobs at a caller-chosen admit threshold.
    pub fn with_threshold(threshold: f64) -> Self {
        AdmissionConfig {
            threshold: threshold.clamp(0.0, 1.0),
            ..Default::default()
        }
    }
}

/// The three fates of an arrival under admission control.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    Admit,
    Downgrade,
    Reject,
}

impl Decision {
    /// One-letter code used by the golden decision-sequence snapshots.
    pub fn letter(self) -> &'static str {
        match self {
            Decision::Admit => "A",
            Decision::Downgrade => "D",
            Decision::Reject => "R",
        }
    }
}

/// Per-app admission tallies (fairness accounting).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AppAdmission {
    pub arrivals: usize,
    pub admitted: usize,
    pub downgraded: usize,
    pub rejected: usize,
}

/// Run-level admission outcome counts, flowing through
/// `EngineResult`/`ServeResult`/`Cell` into the experiment JSON.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct AdmissionStats {
    /// Whether an admission controller was attached at all (stats from an
    /// admission-off run are all-zero *and* disabled).
    pub enabled: bool,
    pub admitted: usize,
    pub downgraded: usize,
    pub early_rejected: usize,
    /// Downgraded requests that actually executed in a best-effort batch.
    pub best_effort_served: usize,
    pub best_effort_batches: usize,
    /// Per-app tallies in first-seen order.
    pub per_app: Vec<(u32, AppAdmission)>,
}

impl AdmissionStats {
    /// Largest/smallest per-app admitted share among apps with arrivals —
    /// the fairness spread the overload experiment reports (1.0 = exactly
    /// even; meaningful only with ≥ 2 active apps).
    pub fn admit_share_spread(&self) -> Option<(f64, f64)> {
        let shares: Vec<f64> = self
            .per_app
            .iter()
            .filter(|(_, a)| a.arrivals > 0)
            .map(|(_, a)| a.admitted as f64 / a.arrivals as f64)
            .collect();
        if shares.len() < 2 {
            return None;
        }
        let max = shares.iter().cloned().fold(f64::MIN, f64::max);
        let min = shares.iter().cloned().fold(f64::MAX, f64::min);
        Some((min, max))
    }
}

#[derive(Debug, Clone, Copy, Default)]
struct AppState {
    deficit: f64,
    adm: AppAdmission,
}

/// The admission controller: probability gate + fairness guard +
/// best-effort lane. Owned by the serving loop as
/// `Option<AdmissionController>` — `None` (the default) keeps the arrival
/// path bit-exact with the pre-admission loop.
pub struct AdmissionController {
    cfg: AdmissionConfig,
    /// Per-(model, app) solo exec-time distributions, seeded from the same
    /// deployment-time profiles the schedulers get (linear probe — a
    /// handful of traffic classes, no hashing).
    profiles: Vec<((u32, u32), Histogram)>,
    /// Per-app fairness state in first-seen order.
    apps: Vec<(u32, AppState)>,
    /// Saturating contention signal: probability-gate failures push it up,
    /// passes bleed it down. The fairness guard only bites while this is
    /// high, so light load is never distorted.
    pressure: u32,
    /// Best-effort lane: per-model FIFO sub-queues (the scheduler-side
    /// queue machinery, reused).
    lane: FifoQueues,
    admitted: usize,
    downgraded: usize,
    early_rejected: usize,
    best_effort_served: usize,
    best_effort_batches: usize,
}

impl AdmissionController {
    const PRESSURE_CAP: u32 = 64;
    const PRESSURE_GATE: u32 = 8;
    /// Unprofiled-class placeholder (the estimator's cold-start fallback).
    const FALLBACK_EXEC_MS: f64 = 10.0;

    pub fn new(cfg: AdmissionConfig) -> Self {
        AdmissionController {
            cfg,
            profiles: Vec::new(),
            apps: Vec::new(),
            pressure: 0,
            lane: FifoQueues::new(),
            admitted: 0,
            downgraded: 0,
            early_rejected: 0,
            best_effort_served: 0,
            best_effort_batches: 0,
        }
    }

    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    /// Install the deployment-time exec-time distribution for one
    /// (model, app) traffic class — same seeding call sites as the
    /// schedulers' `seed_app_profile`.
    pub fn seed_profile(&mut self, model: ModelId, app: AppId, hist: &Histogram) {
        let key = (model.0, app.0);
        match self.profiles.iter_mut().find(|(k, _)| *k == key) {
            Some((_, h)) => *h = hist.clone(),
            None => self.profiles.push((key, hist.clone())),
        }
    }

    /// P(finish ≤ deadline) given `slack_ms` = deadline − now − backlog:
    /// the class distribution's CDF at the remaining slack. Falls back to
    /// the model's first profiled class, then to a 10 ms point mass.
    fn attain_probability(&self, model: ModelId, app: AppId, slack_ms: f64) -> f64 {
        if slack_ms <= 0.0 {
            return 0.0;
        }
        let key = (model.0, app.0);
        let hist = self
            .profiles
            .iter()
            .find(|(k, _)| *k == key)
            .or_else(|| self.profiles.iter().find(|((m, _), _)| *m == model.0))
            .map(|(_, h)| h);
        match hist {
            Some(h) => h.cdf(slack_ms),
            None if slack_ms >= Self::FALLBACK_EXEC_MS => 1.0,
            None => 0.0,
        }
    }

    fn app_index(&mut self, app: AppId) -> usize {
        match self.apps.iter().position(|(a, _)| *a == app.0) {
            Some(i) => i,
            None => {
                // First-seen growth only — the warm path never allocates.
                self.apps.push((app.0, AppState::default()));
                self.apps.len() - 1
            }
        }
    }

    /// Decide one arrival's fate. `backlog_ms` is the *best* (minimum)
    /// candidate replica's drain estimate; `f64::INFINITY` when no replica
    /// hosts the model. Returns the decision plus the estimated
    /// P(finish ≤ deadline) (telemetry records it).
    pub fn decide(&mut self, req: &Request, backlog_ms: f64, now: Micros) -> (Decision, f64) {
        let slack_ms = us_to_ms(req.deadline.saturating_sub(now)) - backlog_ms;
        let p = self.attain_probability(req.model, req.app, slack_ms);
        let ai = self.app_index(req.app);
        // Every arrival is one admission opportunity; credit all apps
        // their fair share of it.
        let share = 1.0 / self.apps.len() as f64;
        let cap = self.cfg.deficit_cap;
        for (_, st) in self.apps.iter_mut() {
            st.deficit = (st.deficit + share).min(cap);
        }
        self.apps[ai].1.adm.arrivals += 1;
        let gate = p >= self.cfg.threshold;
        if gate {
            self.pressure = self.pressure.saturating_sub(1);
        } else {
            self.pressure = (self.pressure + 2).min(Self::PRESSURE_CAP);
        }
        let contended = self.pressure >= Self::PRESSURE_GATE;
        let floor = self.cfg.threshold * self.cfg.reject_ratio;
        let spend = |st: &mut AppState| st.deficit = (st.deficit - 1.0).max(0.0);
        let decision = if gate {
            if contended && self.apps[ai].1.deficit < 1.0 {
                // Fair share spent under contention: the hot app yields
                // this slot to best-effort instead of starving others.
                Decision::Downgrade
            } else {
                spend(&mut self.apps[ai].1);
                Decision::Admit
            }
        } else if p < floor {
            Decision::Reject
        } else if contended && self.apps[ai].1.deficit >= self.cfg.boost {
            // Starvation guard: an app far under its fair share gets its
            // marginal (below-threshold but not hopeless) requests in.
            spend(&mut self.apps[ai].1);
            Decision::Admit
        } else {
            Decision::Downgrade
        };
        match decision {
            Decision::Admit => {
                self.admitted += 1;
                self.apps[ai].1.adm.admitted += 1;
            }
            Decision::Downgrade => {
                self.downgraded += 1;
                self.apps[ai].1.adm.downgraded += 1;
            }
            Decision::Reject => {
                self.early_rejected += 1;
                self.apps[ai].1.adm.rejected += 1;
            }
        }
        (decision, p)
    }

    /// Park a downgraded request in the best-effort lane.
    pub fn push_best_effort(&mut self, req: Request) {
        self.lane.push(req);
    }

    /// Requests parked in the best-effort lane.
    pub fn best_effort_pending(&self) -> usize {
        self.lane.len()
    }

    /// Form a model-pure best-effort batch for an idle worker: the
    /// earliest-parked request among models `hosts` accepts heads it, FIFO
    /// within its model, capped at `be_batch`. None = nothing servable.
    pub fn next_best_effort(&mut self, hosts: impl Fn(ModelId) -> bool) -> Option<Vec<Request>> {
        let model = self.lane.front_matching(&hosts)?.model;
        let batch = self.lane.drain_model(model, self.cfg.be_batch);
        debug_assert!(!batch.is_empty(), "front_matching promised a head");
        self.best_effort_batches += 1;
        self.best_effort_served += batch.len();
        Some(batch)
    }

    /// Remove every parked request whose model `hosted` rejects — an
    /// elastic unload can orphan lane entries that could otherwise never
    /// execute (and would wedge the pumps' drain check). The caller must
    /// complete the returned requests. Allocation-free when nothing is
    /// orphaned (the common case: an empty `Vec` does not allocate).
    pub fn evict_unhosted(&mut self, hosted: impl Fn(ModelId) -> bool) -> Vec<Request> {
        let mut out = Vec::new();
        while let Some(r) = self.lane.front_matching(|m| !hosted(m)) {
            let model = r.model;
            let n = self.lane.pending_for(model);
            out.extend(self.lane.drain_model(model, n));
        }
        out
    }

    /// Flush every still-parked best-effort request (end-of-run drain —
    /// they terminate as unserved, keeping completion conservation exact).
    pub fn drain_best_effort(&mut self) -> Vec<Request> {
        let mut out = Vec::with_capacity(self.lane.len());
        while let Some(r) = self.lane.pop_front() {
            out.push(r);
        }
        out
    }

    /// Snapshot the run-level stats (one allocation; called at teardown).
    pub fn stats(&self) -> AdmissionStats {
        AdmissionStats {
            enabled: true,
            admitted: self.admitted,
            downgraded: self.downgraded,
            early_rejected: self.early_rejected,
            best_effort_served: self.best_effort_served,
            best_effort_batches: self.best_effort_batches,
            per_app: self.apps.iter().map(|(a, st)| (*a, st.adm)).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clock::ms_to_us;

    const M0: ModelId = ModelId(0);
    const A0: AppId = AppId(0);
    const A1: AppId = AppId(1);

    fn req(id: u64, app: AppId, release: Micros, slo_ms: f64) -> Request {
        Request::new(id, app, release, ms_to_us(slo_ms), 10.0)
    }

    /// A controller with a profiled 8–12 ms class (mean 10).
    fn seeded() -> AdmissionController {
        let mut c = AdmissionController::new(AdmissionConfig::default());
        c.seed_profile(M0, A0, &Histogram::from_weights(8.0, 1.0, &[1.0, 1.0, 1.0, 1.0]));
        c
    }

    #[test]
    fn threshold_bands_route_to_three_fates() {
        let mut c = seeded();
        // Plenty of slack, empty backlog → admit.
        let (d, p) = c.decide(&req(0, A0, 0, 100.0), 0.0, 0);
        assert_eq!(d, Decision::Admit);
        assert!(p > 0.99, "p={p}");
        // Backlog eats the whole budget → hopeless → reject.
        let (d, p) = c.decide(&req(1, A0, 0, 100.0), 99.0, 0);
        assert_eq!(d, Decision::Reject);
        assert!(p < 0.125, "p={p}");
        // Marginal slack (between the floors) → downgrade.
        let (d, p) = c.decide(&req(2, A0, 0, 100.0), 91.0, 0);
        assert_eq!(d, Decision::Downgrade, "p={p}");
        let s = c.stats();
        assert!(s.enabled);
        assert_eq!((s.admitted, s.downgraded, s.early_rejected), (1, 1, 1));
        assert_eq!(s.per_app.len(), 1);
        assert_eq!(s.per_app[0].1.arrivals, 3);
    }

    #[test]
    fn no_host_is_hopeless() {
        let mut c = seeded();
        let (d, p) = c.decide(&req(0, A0, 0, 1_000.0), f64::INFINITY, 0);
        assert_eq!(d, Decision::Reject);
        assert_eq!(p, 0.0);
    }

    #[test]
    fn unprofiled_class_uses_point_fallback() {
        let mut c = AdmissionController::new(AdmissionConfig::default());
        let (d, _) = c.decide(&req(0, A0, 0, 100.0), 0.0, 0);
        assert_eq!(d, Decision::Admit, "10 ms placeholder fits 100 ms slack");
        let (d, _) = c.decide(&req(1, A0, 0, 100.0), 95.0, 0);
        assert_eq!(d, Decision::Reject, "placeholder cannot fit 5 ms");
    }

    #[test]
    fn light_load_never_triggers_the_fairness_guard() {
        // A hot app at 3× the cold app's rate, but everything passes the
        // gate: every single request is admitted — the deficit guard must
        // not distort uncontended traffic.
        let mut c = seeded();
        c.seed_profile(M0, A1, &Histogram::from_weights(8.0, 1.0, &[1.0; 4]));
        for i in 0..400u64 {
            let app = if i % 4 == 3 { A1 } else { A0 };
            let (d, _) = c.decide(&req(i, app, 0, 200.0), 0.0, 0);
            assert_eq!(d, Decision::Admit, "arrival {i}");
        }
        let s = c.stats();
        assert_eq!(s.admitted, 400);
        assert_eq!(s.downgraded + s.early_rejected, 0);
    }

    #[test]
    fn contended_hot_app_yields_to_fair_share() {
        // Sustained contention: every request is marginal (gate fails but
        // not hopeless), one app arrives 3× as often. The starvation boost
        // admits each app's share; the hot app's surplus downgrades.
        let mut c = seeded();
        c.seed_profile(M0, A1, &Histogram::from_weights(8.0, 1.0, &[1.0; 4]));
        for i in 0..600u64 {
            let app = if i % 4 == 3 { A1 } else { A0 };
            // backlog 91 ms on a 100 ms SLO → p ≈ 0.25..0.5 band.
            let _ = c.decide(&req(i, app, 0, 100.0), 91.0, 0);
        }
        let s = c.stats();
        let hot = s.per_app.iter().find(|(a, _)| *a == 0).unwrap().1;
        let cold = s.per_app.iter().find(|(a, _)| *a == 1).unwrap().1;
        assert!(hot.arrivals > 2 * cold.arrivals);
        // Absolute admissions are near-equal (each app spends the same
        // credit stream), so the hot app cannot starve the cold one.
        let (lo, hi) = (hot.admitted.min(cold.admitted), hot.admitted.max(cold.admitted));
        assert!(cold.admitted > 0, "cold app starved: {cold:?}");
        assert!(
            hi as f64 <= lo as f64 * 1.5 + 4.0,
            "admission shares diverged: hot={hot:?} cold={cold:?}"
        );
        assert!(hot.downgraded > 0, "hot app's surplus must downgrade");
    }

    #[test]
    fn best_effort_lane_drains_model_pure_fifo() {
        let mut c = AdmissionController::new(AdmissionConfig::default());
        for i in 0..5u64 {
            let m = ModelId((i % 2) as u32);
            c.push_best_effort(req(i, A0, i, 1_000.0).with_model(m));
        }
        assert_eq!(c.best_effort_pending(), 5);
        // Worker hosting only model 1: earliest model-1 head (id 1) leads
        // a model-pure fill.
        let b = c.next_best_effort(|m| m == ModelId(1)).unwrap();
        assert_eq!(b.iter().map(|r| r.id.0).collect::<Vec<_>>(), vec![1, 3]);
        assert!(b.iter().all(|r| r.model == ModelId(1)));
        assert_eq!(c.best_effort_pending(), 3);
        // Nothing hosted → nothing served.
        assert!(c.next_best_effort(|m| m == ModelId(7)).is_none());
        // End-of-run flush returns the rest in arrival order.
        let rest = c.drain_best_effort();
        assert_eq!(rest.iter().map(|r| r.id.0).collect::<Vec<_>>(), vec![0, 2, 4]);
        let s = c.stats();
        assert_eq!(s.best_effort_served, 2);
        assert_eq!(s.best_effort_batches, 1);
    }

    #[test]
    fn best_effort_batch_respects_cap() {
        let mut c = AdmissionController::new(AdmissionConfig {
            be_batch: 2,
            ..Default::default()
        });
        for i in 0..5u64 {
            c.push_best_effort(req(i, A0, i, 1_000.0));
        }
        let b = c.next_best_effort(|_| true).unwrap();
        assert_eq!(b.len(), 2);
        assert_eq!(c.best_effort_pending(), 3);
    }
}
