//! Real-time pump: drives a [`ServingLoop`](super::ServingLoop) cluster on
//! wall-clock time with one OS thread per worker (threads, no tokio — the
//! offline vendored set, see DESIGN.md §3).
//!
//! Arrivals come in through an mpsc channel from any number of client
//! threads; each dispatch is shipped to its replica's worker thread, which
//! executes the batch (PJRT on the real path) and reports a `BatchDone`.
//! Elastic model loads ride the same per-worker channel: a
//! [`Dispatch::Load`](super::Dispatch) runs `Worker::load_model` on the
//! worker's thread (the PJRT worker actually loads the runtime there) and
//! answers with a `PlacementDone`; unloads are fire-and-forget
//! notifications that let the worker release executor-side state.
//! Unlike the historical single-worker `server::Server`, execution never
//! blocks the scheduling loop — N batches run concurrently, one per
//! replica.

use super::ingress::{self, Ingress, IngressCounts};
use super::{AdmissionStats, Dispatch, Event, PlacementStats, ServingLoop, WorkerStats};
use crate::clock::{Clock, Micros};
use crate::core::request::{Completion, ModelId, Request};
use crate::scheduler::Scheduler;
use crate::sim::worker::Worker;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::Duration;

/// Sets the shutdown flag when the scheduling loop exits — including by
/// panic — so the arrival forwarder (which may be blocked waiting on a
/// submitter that never hangs up) stops and `thread::scope` can join it.
struct ShutdownOnDrop(Arc<AtomicBool>);

impl Drop for ShutdownOnDrop {
    fn drop(&mut self) {
        self.0.store(true, Ordering::SeqCst);
    }
}

/// Result of a real-time serve.
#[derive(Debug)]
pub struct ServeResult {
    pub completions: Vec<Completion>,
    /// Per-replica execution counters.
    pub per_worker: Vec<WorkerStats>,
    /// Elastic placement counters (all zero on static runs).
    pub placement: PlacementStats,
    /// Admission-control tallies (disabled + all-zero when no controller
    /// was installed).
    pub admission: AdmissionStats,
    /// Wall-clock length of the run (µs since the serving clock's epoch).
    pub end_time: Micros,
    /// Lifecycle recorder, present when the loop was built with
    /// [`ServingLoop::with_telemetry`].
    pub telemetry: Option<Box<crate::telemetry::Recorder>>,
}

/// Work items shipped to a replica's executor thread.
enum Work {
    Batch(Vec<Request>),
    /// Load `model` (predicted cold-start hint, ms); answered with
    /// `Msg::Loaded`.
    Load(ModelId, f64),
    /// Release `model`'s executor-side state; no reply.
    Unload(ModelId),
}

/// Internal event-channel message: external arrivals and worker-thread
/// completions multiplexed onto one receiver (std mpsc has no `select`).
enum Msg {
    Arrival(Request),
    ArrivalsClosed,
    Done { worker: usize, batch_ms: f64 },
    /// A model load finished on this replica's thread; `load_ms` is the
    /// measured load time (the PJRT worker times the actual runtime
    /// load).
    Loaded {
        worker: usize,
        model: ModelId,
        load_ms: f64,
    },
    /// `Worker::execute`/`load_model` panicked on this replica's thread.
    /// Re-raised on the scheduling thread — a dead replica with a batch
    /// marked in-flight would otherwise hang the loop forever.
    WorkerPanicked { worker: usize },
}

fn ingest<C: Clock, S: Scheduler>(core: &mut ServingLoop<C, S>, msg: Msg, open: &mut bool) {
    match msg {
        Msg::Arrival(req) => {
            core.on_event(Event::Arrival(req));
        }
        Msg::ArrivalsClosed => *open = false,
        Msg::Done { worker, batch_ms } => {
            core.on_event(Event::BatchDone { worker, batch_ms });
        }
        Msg::Loaded {
            worker,
            model,
            load_ms,
        } => {
            core.on_event(Event::PlacementDone {
                worker,
                model,
                load_ms,
            });
        }
        Msg::WorkerPanicked { worker } => {
            panic!("worker thread {worker} panicked during batch execution");
        }
    }
}

/// Spawn one executor thread per replica inside `scope`; each exits when
/// its dispatch channel closes. Shared by both real-time pumps.
fn spawn_executors<'scope, W: Worker + 'scope>(
    scope: &'scope std::thread::Scope<'scope, '_>,
    workers: Vec<W>,
    etx: &Sender<Msg>,
) -> Vec<Sender<Work>> {
    let mut dispatch_txs: Vec<Sender<Work>> = Vec::with_capacity(workers.len());
    for (w, mut worker) in workers.into_iter().enumerate() {
        let (dtx, drx) = mpsc::channel::<Work>();
        dispatch_txs.push(dtx);
        let etx = etx.clone();
        scope.spawn(move || {
            while let Ok(work) = drx.recv() {
                let msg = match work {
                    Work::Batch(batch) => {
                        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            worker.execute(&batch)
                        }));
                        match result {
                            Ok(ms) => Msg::Done {
                                worker: w,
                                batch_ms: ms,
                            },
                            Err(_) => Msg::WorkerPanicked { worker: w },
                        }
                    }
                    Work::Load(model, hint_ms) => {
                        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            worker.load_model(model, hint_ms)
                        }));
                        match result {
                            Ok(ms) => Msg::Loaded {
                                worker: w,
                                model,
                                load_ms: ms,
                            },
                            Err(_) => Msg::WorkerPanicked { worker: w },
                        }
                    }
                    Work::Unload(model) => {
                        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            worker.unload_model(model)
                        }));
                        match result {
                            Ok(()) => continue, // fire-and-forget
                            Err(_) => Msg::WorkerPanicked { worker: w },
                        }
                    }
                };
                let fatal = matches!(msg, Msg::WorkerPanicked { .. });
                if etx.send(msg).is_err() || fatal {
                    break;
                }
            }
        });
    }
    dispatch_txs
}

/// Ship a wake's dispatches to the executor threads, recording `ExecStart`
/// for batches (they start the moment they are shipped — the replica
/// thread was idle). A send can only fail if the replica's thread died,
/// which `WorkerPanicked` should have surfaced already — fail loudly,
/// don't strand the batch as forever-in-flight.
fn ship_dispatches<C: Clock, S: Scheduler>(
    core: &mut ServingLoop<C, S>,
    dispatch_txs: &[Sender<Work>],
) -> usize {
    let dispatches = core.on_event(Event::Wake);
    let shipped = dispatches.len();
    for d in dispatches {
        let (worker, work) = match d {
            Dispatch::Execute { worker, batch } => {
                let now = core.now();
                if let Some(tel) = core.telemetry_mut() {
                    if let Some(b) = tel.last_batch_for(worker) {
                        tel.record(
                            now,
                            crate::telemetry::EventKind::ExecStart {
                                batch: b,
                                worker: worker as u32,
                            },
                        );
                    }
                }
                (worker, Work::Batch(batch))
            }
            Dispatch::Load {
                worker,
                model,
                cost_ms,
            } => (worker, Work::Load(model, cost_ms)),
            Dispatch::Unload { worker, model } => (worker, Work::Unload(model)),
        };
        dispatch_txs[worker]
            .send(work)
            .unwrap_or_else(|_| panic!("worker thread {worker} is gone"));
    }
    shipped
}

/// Serve until the submitters hang up and everything drains. `workers[i]`
/// executes the batches of replica `i` on its own thread.
pub fn serve_cluster<C: Clock, S: Scheduler, W: Worker>(
    mut core: ServingLoop<C, S>,
    workers: Vec<W>,
    rx: Receiver<Request>,
) -> ServeResult {
    let n = workers.len();
    assert_eq!(n, core.workers(), "one executor per scheduling replica");
    let (etx, erx) = mpsc::channel::<Msg>();

    std::thread::scope(|scope| {
        let dispatch_txs = spawn_executors(scope, workers, &etx);
        // Forward external arrivals onto the internal event channel so the
        // scheduling loop can block on a single receiver. The bounded wait
        // lets the forwarder notice shutdown even while submitters hold
        // their end open.
        let shutdown = Arc::new(AtomicBool::new(false));
        {
            let etx = etx.clone();
            let shutdown = shutdown.clone();
            scope.spawn(move || loop {
                match rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(req) => {
                        if etx.send(Msg::Arrival(req)).is_err() {
                            return;
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {
                        if shutdown.load(Ordering::SeqCst) {
                            return;
                        }
                    }
                    Err(RecvTimeoutError::Disconnected) => {
                        let _ = etx.send(Msg::ArrivalsClosed);
                        return;
                    }
                }
            });
        }
        drop(etx);
        let _shutdown_guard = ShutdownOnDrop(shutdown);

        let mut open = true;
        loop {
            // Ingest everything currently ready.
            loop {
                match erx.try_recv() {
                    Ok(msg) => ingest(&mut core, msg, &mut open),
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => {
                        open = false;
                        break;
                    }
                }
            }
            // Drain drops; dispatch to every idle replica.
            ship_dispatches(&mut core, &dispatch_txs);
            if !open && core.pending() == 0 && core.in_flight() == 0 && core.loading() == 0 {
                break;
            }
            // Idle: block briefly for new events or the next wake hint.
            let now = core.now();
            let wait_us = core
                .next_wake(now)
                .map(|h| h.saturating_sub(now).clamp(100, 5_000))
                .unwrap_or(1_000);
            match erx.recv_timeout(Duration::from_micros(wait_us)) {
                Ok(msg) => ingest(&mut core, msg, &mut open),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => open = false,
            }
        }
        // Closing the dispatch channels stops the worker threads; the
        // scope joins them (and the forwarder) on exit.
        drop(dispatch_txs);
    });

    core.drain_all();
    let end_time = core.now();
    let placement = core.placement_stats();
    let admission = core.admission_stats();
    let telemetry = core.take_telemetry();
    let (completions, per_worker) = core.into_completions();
    ServeResult {
        completions,
        per_worker,
        placement,
        admission,
        end_time,
        telemetry,
    }
}

/// Forward every not-yet-forwarded completion back to its ingress shard
/// as a wire reply (`forwarded` is the pump's cursor into
/// `core.completions()`), recording `WireOut` when telemetry is on.
/// Returns how many were forwarded this call.
fn forward_replies<C: Clock, S: Scheduler>(
    core: &mut ServingLoop<C, S>,
    ingress: &Ingress,
    forwarded: &mut usize,
) -> usize {
    let mut sent = 0usize;
    loop {
        let (shard, reply, req, at) = {
            let comps = core.completions();
            if *forwarded >= comps.len() {
                break;
            }
            let c = &comps[*forwarded];
            let (shard, reply) = ingress::reply_for(c);
            (shard, reply, c.request.id, c.at)
        };
        *forwarded += 1;
        ingress.push_reply(shard, reply);
        if let Some(tel) = core.telemetry_mut() {
            tel.record(
                at,
                crate::telemetry::EventKind::WireOut {
                    req,
                    shard: shard as u16,
                },
            );
        }
        sent += 1;
    }
    sent
}

/// How many wire arrivals the pump ingests per sweep before giving the
/// scheduler a wake — bounds scheduling latency under arrival floods.
const ARRIVALS_PER_SWEEP: usize = 1024;

/// Serve a network [`Ingress`]: the pump drains the lock-free arrival
/// ring directly (no mpsc hop, no forwarder thread), ships dispatches to
/// per-replica executor threads exactly like [`serve_cluster`], and
/// forwards every completion back to its originating shard/connection as
/// a wire reply. Runs until [`ingress::IngressController::begin_drain`]
/// is observed *and* everything in flight has drained — the same
/// exit-wait discipline as the in-process pump — then stops the shards
/// and returns the final ingress counters alongside the serve result.
pub fn serve_ingress<C: Clock, S: Scheduler, W: Worker>(
    mut core: ServingLoop<C, S>,
    workers: Vec<W>,
    net: Ingress,
) -> (ServeResult, IngressCounts) {
    let n = workers.len();
    assert_eq!(n, core.workers(), "one executor per scheduling replica");
    let (etx, erx) = mpsc::channel::<Msg>();
    let mut forwarded = 0usize;

    std::thread::scope(|scope| {
        let dispatch_txs = spawn_executors(scope, workers, &etx);
        drop(etx);

        // `open` only exists for `ingest`'s signature; no Msg::Arrival /
        // ArrivalsClosed flows here — arrivals come off the ring.
        let mut open = true;
        loop {
            let mut progress = false;
            // Worker-thread events first: completions free replicas.
            loop {
                match erx.try_recv() {
                    Ok(msg) => {
                        ingest(&mut core, msg, &mut open);
                        progress = true;
                    }
                    Err(TryRecvError::Empty) => break,
                    Err(TryRecvError::Disconnected) => break,
                }
            }
            // Bounded arrival sweep off the lock-free ring.
            let mut popped = 0usize;
            while popped < ARRIVALS_PER_SWEEP {
                let Some(req) = net.pop_arrival() else { break };
                popped += 1;
                let (id, at) = (req.id, req.release);
                if let Some(tel) = core.telemetry_mut() {
                    tel.record(
                        at,
                        crate::telemetry::EventKind::WireIn {
                            req: id,
                            shard: ingress::id_shard(id.0) as u16,
                        },
                    );
                }
                core.on_event(Event::Arrival(req));
            }
            progress |= popped > 0;
            progress |= ship_dispatches(&mut core, &dispatch_txs) > 0;
            progress |= forward_replies(&mut core, &net, &mut forwarded) > 0;
            if net.drain_requested()
                && net.arrivals_empty()
                && core.pending() == 0
                && core.in_flight() == 0
                && core.loading() == 0
            {
                break;
            }
            if !progress {
                // Idle: block briefly for worker events or the next wake
                // hint; the clamp keeps arrival-ring polling tight.
                let now = core.now();
                let wait_us = core
                    .next_wake(now)
                    .map(|h| h.saturating_sub(now).clamp(50, 1_000))
                    .unwrap_or(200);
                match erx.recv_timeout(Duration::from_micros(wait_us)) {
                    Ok(msg) => ingest(&mut core, msg, &mut open),
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => {}
                }
            }
        }
        drop(dispatch_txs);
    });

    // Terminal drops from the final drain still owe the wire a reply.
    core.drain_all();
    forward_replies(&mut core, &net, &mut forwarded);
    let counts = net.finish();
    let end_time = core.now();
    let placement = core.placement_stats();
    let admission = core.admission_stats();
    let telemetry = core.take_telemetry();
    let (completions, per_worker) = core.into_completions();
    (
        ServeResult {
            completions,
            per_worker,
            placement,
            admission,
            end_time,
            telemetry,
        },
        counts,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::edf::EdfScheduler;
    use crate::clock::{ms_to_us, RealClock};
    use crate::core::batchmodel::BatchCostModel;
    use crate::core::request::AppId;
    use crate::scheduler::SchedulerConfig;
    use crate::serve::{
        router, Cluster, ColdStartCost, ElasticConfig, Placement, PlacementController,
    };
    use crate::sim::worker::SimWorker;

    fn edf_scheds(n: usize) -> Vec<EdfScheduler> {
        let cfg = SchedulerConfig {
            cost_model: BatchCostModel::new(0.0, 1.0),
            ..Default::default()
        };
        (0..n)
            .map(|_| {
                let mut s = EdfScheduler::new(cfg.clone(), 0);
                s.seed_exec_mean(1.0);
                s
            })
            .collect()
    }

    #[test]
    fn drains_and_reports_per_worker() {
        let core = ServingLoop::new(
            RealClock::new(),
            Cluster::new(edf_scheds(2)),
            router::by_name("round_robin").unwrap(),
        );
        let workers: Vec<SimWorker> = (0..2)
            .map(|w| SimWorker::new(BatchCostModel::new(0.0, 1.0), 0.0, w))
            .collect();
        let (tx, rx) = mpsc::channel();
        for i in 0..16u64 {
            tx.send(Request::new(i, AppId(0), 0, ms_to_us(5_000.0), 1.0))
                .unwrap();
        }
        drop(tx);
        let res = serve_cluster(core, workers, rx);
        assert_eq!(res.completions.len(), 16);
        assert_eq!(res.per_worker.len(), 2);
        assert!(res.per_worker.iter().map(|w| w.batches).sum::<usize>() > 0);
        assert_eq!(res.placement.actions(), 0);
    }

    #[test]
    fn elastic_loads_complete_on_worker_threads() {
        // Two workers, partition placement over two models, all traffic on
        // model 0: the controller must replicate model 0 onto worker 1
        // through the worker thread's load_model and the run must still
        // drain (the exit condition waits for in-flight loads).
        let placement = Placement::parse("partition", 2, 2).unwrap();
        let cluster = Cluster::with_placement(edf_scheds(2), placement);
        let ctl = PlacementController::new(ElasticConfig {
            capacity: 2,
            interval_us: 1_000,
            alpha: 1.0,
            min_dwell_us: 0,
            cold_start: ColdStartCost::new(0.5, 0.5),
        });
        let core = ServingLoop::new(
            RealClock::new(),
            cluster,
            router::by_name("least_loaded").unwrap(),
        )
        .with_elastic(ctl);
        let workers: Vec<SimWorker> = (0..2)
            .map(|w| SimWorker::new(BatchCostModel::new(0.0, 1.0), 0.0, w))
            .collect();
        let (tx, rx) = mpsc::channel();
        let handle = std::thread::spawn(move || {
            for i in 0..60u64 {
                tx.send(Request::new(i, AppId(0), 0, ms_to_us(5_000.0), 1.0))
                    .unwrap();
                std::thread::sleep(Duration::from_micros(300));
            }
        });
        let res = serve_cluster(core, workers, rx);
        handle.join().unwrap();
        assert_eq!(res.completions.len(), 60, "conservation under elastic");
        assert!(
            res.placement.loads >= 1,
            "hot model should replicate: {:?}",
            res.placement
        );
    }
}
