//! Real-time pump: drives a [`ServingLoop`](super::ServingLoop) cluster on
//! wall-clock time with one OS thread per worker (threads, no tokio — the
//! offline vendored set, see DESIGN.md §3).
//!
//! Arrivals come in through an mpsc channel from any number of client
//! threads; each dispatch is shipped to its replica's worker thread, which
//! executes the batch (PJRT on the real path) and reports a `BatchDone`.
//! Elastic model loads ride the same per-worker channel: a
//! [`Dispatch::Load`](super::Dispatch) runs `Worker::load_model` on the
//! worker's thread (the PJRT worker actually loads the runtime there) and
//! answers with a `PlacementDone`; unloads are fire-and-forget
//! notifications that let the worker release executor-side state.
//! Unlike the historical single-worker `server::Server`, execution never
//! blocks the scheduling loop — N batches run concurrently, one per
//! replica.

use super::ingress::{self, Ingress, IngressCounts};
use super::ring::ArrivalRing;
use super::router::{BoardPolicy, BoardRouter, LoadBoard, Pinned};
use super::{
    AdmissionStats, Cluster, Dispatch, Event, Placement, PlacementStats, ServingLoop, WorkerStats,
};
use crate::clock::{Clock, Micros};
use crate::core::request::{Completion, ModelId, Request};
use crate::scheduler::Scheduler;
use crate::sim::worker::Worker;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::Duration;

/// Sets the shutdown flag when the scheduling loop exits — including by
/// panic — so the arrival forwarder (which may be blocked waiting on a
/// submitter that never hangs up) stops and `thread::scope` can join it.
struct ShutdownOnDrop(Arc<AtomicBool>);

impl Drop for ShutdownOnDrop {
    fn drop(&mut self) {
        self.0.store(true, Ordering::SeqCst);
    }
}

/// Result of a real-time serve.
#[derive(Debug)]
pub struct ServeResult {
    pub completions: Vec<Completion>,
    /// Per-replica execution counters.
    pub per_worker: Vec<WorkerStats>,
    /// Elastic placement counters (all zero on static runs).
    pub placement: PlacementStats,
    /// Admission-control tallies (disabled + all-zero when no controller
    /// was installed).
    pub admission: AdmissionStats,
    /// Wall-clock length of the run (µs since the serving clock's epoch).
    pub end_time: Micros,
    /// Lifecycle recorder, present when the loop was built with
    /// [`ServingLoop::with_telemetry`].
    pub telemetry: Option<Box<crate::telemetry::Recorder>>,
    /// Per-shard counters from the sharded wall-clock pump
    /// ([`serve_ingress_sharded`]); empty on unsharded runs — including
    /// S=1, which delegates to the sequential pump unchanged.
    pub shards: Vec<ShardStats>,
}

/// One scheduling shard's ledger (DESIGN.md §13). Every request a shard
/// takes responsibility for — popped off its own ingress partitions or
/// received over the handoff ring — must leave as exactly one completion
/// or one handoff to a peer; [`ShardStats::conserved`] is that per-shard
/// conservation verdict and the sharded pump's exit invariant.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShardStats {
    /// Shard index.
    pub shard: usize,
    /// First global replica id this shard owns.
    pub lo: usize,
    /// Number of replicas owned (contiguous from `lo`).
    pub workers: usize,
    /// Arrivals popped off this shard's own ingress partitions.
    pub popped: u64,
    /// Requests received from peer shards over the handoff ring.
    pub handoff_in: u64,
    /// Requests routed to a peer shard's replica and handed off.
    pub handoff_out: u64,
    /// Completions recorded by this shard's sub-core.
    pub completions: u64,
    /// Time spent in sweeps that made progress (µs).
    pub busy_us: u64,
    /// Shard-loop lifetime (µs).
    pub wall_us: u64,
}

impl ShardStats {
    /// Fraction of the shard's lifetime spent doing work — the
    /// scheduling-loop occupancy the `pump_shards` sweep reports.
    pub fn occupancy(&self) -> f64 {
        if self.wall_us == 0 {
            0.0
        } else {
            self.busy_us as f64 / self.wall_us as f64
        }
    }

    /// Per-shard conservation: in (pops + handoffs received) equals out
    /// (completions + handoffs sent).
    pub fn conserved(&self) -> bool {
        self.popped + self.handoff_in == self.completions + self.handoff_out
    }
}

/// Work items shipped to a replica's executor thread.
enum Work {
    Batch(Vec<Request>),
    /// Load `model` (predicted cold-start hint, ms); answered with
    /// `Msg::Loaded`.
    Load(ModelId, f64),
    /// Release `model`'s executor-side state; no reply.
    Unload(ModelId),
}

/// Internal event-channel message: external arrivals and worker-thread
/// completions multiplexed onto one receiver (std mpsc has no `select`).
enum Msg {
    Arrival(Request),
    ArrivalsClosed,
    Done { worker: usize, batch_ms: f64 },
    /// A model load finished on this replica's thread; `load_ms` is the
    /// measured load time (the PJRT worker times the actual runtime
    /// load).
    Loaded {
        worker: usize,
        model: ModelId,
        load_ms: f64,
    },
    /// `Worker::execute`/`load_model` panicked on this replica's thread.
    /// Re-raised on the scheduling thread — a dead replica with a batch
    /// marked in-flight would otherwise hang the loop forever.
    WorkerPanicked { worker: usize },
}

fn ingest<C: Clock, S: Scheduler>(core: &mut ServingLoop<C, S>, msg: Msg, open: &mut bool) {
    match msg {
        Msg::Arrival(req) => {
            core.on_event(Event::Arrival(req));
        }
        Msg::ArrivalsClosed => *open = false,
        Msg::Done { worker, batch_ms } => {
            core.on_event(Event::BatchDone { worker, batch_ms });
        }
        Msg::Loaded {
            worker,
            model,
            load_ms,
        } => {
            core.on_event(Event::PlacementDone {
                worker,
                model,
                load_ms,
            });
        }
        Msg::WorkerPanicked { worker } => {
            panic!("worker thread {worker} panicked during batch execution");
        }
    }
}

/// Batch-drain the event channel: ingest every message already waiting so
/// a burst of worker completions costs one scheduling sweep, not one loop
/// iteration per message. Returns how many were ingested; a disconnect
/// clears `open` (the ingress pump never reads it, the in-process pump
/// uses it as its arrivals-closed latch).
fn drain_events<C: Clock, S: Scheduler>(
    erx: &Receiver<Msg>,
    core: &mut ServingLoop<C, S>,
    open: &mut bool,
) -> usize {
    let mut drained = 0usize;
    loop {
        match erx.try_recv() {
            Ok(msg) => {
                ingest(core, msg, open);
                drained += 1;
            }
            Err(TryRecvError::Empty) => break,
            Err(TryRecvError::Disconnected) => {
                *open = false;
                break;
            }
        }
    }
    drained
}

/// Spawn one executor thread per replica inside `scope`; each exits when
/// its dispatch channel closes. Shared by both real-time pumps.
fn spawn_executors<'scope, W: Worker + 'scope>(
    scope: &'scope std::thread::Scope<'scope, '_>,
    workers: Vec<W>,
    etx: &Sender<Msg>,
) -> Vec<Sender<Work>> {
    let mut dispatch_txs: Vec<Sender<Work>> = Vec::with_capacity(workers.len());
    for (w, mut worker) in workers.into_iter().enumerate() {
        let (dtx, drx) = mpsc::channel::<Work>();
        dispatch_txs.push(dtx);
        let etx = etx.clone();
        scope.spawn(move || {
            while let Ok(work) = drx.recv() {
                let msg = match work {
                    Work::Batch(batch) => {
                        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            worker.execute(&batch)
                        }));
                        match result {
                            Ok(ms) => Msg::Done {
                                worker: w,
                                batch_ms: ms,
                            },
                            Err(_) => Msg::WorkerPanicked { worker: w },
                        }
                    }
                    Work::Load(model, hint_ms) => {
                        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            worker.load_model(model, hint_ms)
                        }));
                        match result {
                            Ok(ms) => Msg::Loaded {
                                worker: w,
                                model,
                                load_ms: ms,
                            },
                            Err(_) => Msg::WorkerPanicked { worker: w },
                        }
                    }
                    Work::Unload(model) => {
                        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            worker.unload_model(model)
                        }));
                        match result {
                            Ok(()) => continue, // fire-and-forget
                            Err(_) => Msg::WorkerPanicked { worker: w },
                        }
                    }
                };
                let fatal = matches!(msg, Msg::WorkerPanicked { .. });
                if etx.send(msg).is_err() || fatal {
                    break;
                }
            }
        });
    }
    dispatch_txs
}

/// Ship a wake's dispatches to the executor threads, recording `ExecStart`
/// for batches (they start the moment they are shipped — the replica
/// thread was idle). A send can only fail if the replica's thread died,
/// which `WorkerPanicked` should have surfaced already — fail loudly,
/// don't strand the batch as forever-in-flight.
fn ship_dispatches<C: Clock, S: Scheduler>(
    core: &mut ServingLoop<C, S>,
    dispatch_txs: &[Sender<Work>],
) -> usize {
    let dispatches = core.on_event(Event::Wake);
    let shipped = dispatches.len();
    for d in dispatches {
        let (worker, work) = match d {
            Dispatch::Execute { worker, batch } => {
                let now = core.now();
                if let Some(tel) = core.telemetry_mut() {
                    if let Some(b) = tel.last_batch_for(worker) {
                        tel.record(
                            now,
                            crate::telemetry::EventKind::ExecStart {
                                batch: b,
                                worker: worker as u32,
                            },
                        );
                    }
                }
                (worker, Work::Batch(batch))
            }
            Dispatch::Load {
                worker,
                model,
                cost_ms,
            } => (worker, Work::Load(model, cost_ms)),
            Dispatch::Unload { worker, model } => (worker, Work::Unload(model)),
        };
        dispatch_txs[worker]
            .send(work)
            .unwrap_or_else(|_| panic!("worker thread {worker} is gone"));
    }
    shipped
}

/// Serve until the submitters hang up and everything drains. `workers[i]`
/// executes the batches of replica `i` on its own thread.
pub fn serve_cluster<C: Clock, S: Scheduler, W: Worker>(
    mut core: ServingLoop<C, S>,
    workers: Vec<W>,
    rx: Receiver<Request>,
) -> ServeResult {
    let n = workers.len();
    assert_eq!(n, core.workers(), "one executor per scheduling replica");
    let (etx, erx) = mpsc::channel::<Msg>();

    std::thread::scope(|scope| {
        let dispatch_txs = spawn_executors(scope, workers, &etx);
        // Forward external arrivals onto the internal event channel so the
        // scheduling loop can block on a single receiver. The bounded wait
        // lets the forwarder notice shutdown even while submitters hold
        // their end open.
        let shutdown = Arc::new(AtomicBool::new(false));
        {
            let etx = etx.clone();
            let shutdown = shutdown.clone();
            scope.spawn(move || loop {
                match rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(req) => {
                        if etx.send(Msg::Arrival(req)).is_err() {
                            return;
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {
                        if shutdown.load(Ordering::SeqCst) {
                            return;
                        }
                    }
                    Err(RecvTimeoutError::Disconnected) => {
                        let _ = etx.send(Msg::ArrivalsClosed);
                        return;
                    }
                }
            });
        }
        drop(etx);
        let _shutdown_guard = ShutdownOnDrop(shutdown);

        let mut open = true;
        loop {
            // Ingest everything currently ready.
            drain_events(&erx, &mut core, &mut open);
            // Drain drops; dispatch to every idle replica.
            ship_dispatches(&mut core, &dispatch_txs);
            if !open && core.pending() == 0 && core.in_flight() == 0 && core.loading() == 0 {
                break;
            }
            // Idle: block briefly for new events or the next wake hint.
            let now = core.now();
            let wait_us = core
                .next_wake(now)
                .map(|h| h.saturating_sub(now).clamp(100, 5_000))
                .unwrap_or(1_000);
            match erx.recv_timeout(Duration::from_micros(wait_us)) {
                Ok(msg) => {
                    // Take whatever arrived with it too — one wakeup, one
                    // sweep, regardless of burst size.
                    ingest(&mut core, msg, &mut open);
                    drain_events(&erx, &mut core, &mut open);
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => open = false,
            }
        }
        // Closing the dispatch channels stops the worker threads; the
        // scope joins them (and the forwarder) on exit.
        drop(dispatch_txs);
    });

    core.drain_all();
    let end_time = core.now();
    let placement = core.placement_stats();
    let admission = core.admission_stats();
    let telemetry = core.take_telemetry();
    let (completions, per_worker) = core.into_completions();
    ServeResult {
        completions,
        per_worker,
        placement,
        admission,
        end_time,
        telemetry,
        shards: Vec::new(),
    }
}

/// Forward every not-yet-forwarded completion back to its ingress shard
/// as a wire reply (`forwarded` is the pump's cursor into
/// `core.completions()`), recording `WireOut` when telemetry is on.
/// Returns how many were forwarded this call.
fn forward_replies<C: Clock, S: Scheduler>(
    core: &mut ServingLoop<C, S>,
    ingress: &Ingress,
    forwarded: &mut usize,
) -> usize {
    let mut sent = 0usize;
    loop {
        let (shard, reply, req, at) = {
            let comps = core.completions();
            if *forwarded >= comps.len() {
                break;
            }
            let c = &comps[*forwarded];
            let (shard, reply) = ingress::reply_for(c);
            (shard, reply, c.request.id, c.at)
        };
        *forwarded += 1;
        ingress.push_reply(shard, reply);
        if let Some(tel) = core.telemetry_mut() {
            tel.record(
                at,
                crate::telemetry::EventKind::WireOut {
                    req,
                    shard: shard as u16,
                },
            );
        }
        sent += 1;
    }
    sent
}

/// How many wire arrivals the pump ingests per sweep before giving the
/// scheduler a wake — bounds scheduling latency under arrival floods.
const ARRIVALS_PER_SWEEP: usize = 1024;

/// Serve a network [`Ingress`]: the pump drains the lock-free arrival
/// ring directly (no mpsc hop, no forwarder thread), ships dispatches to
/// per-replica executor threads exactly like [`serve_cluster`], and
/// forwards every completion back to its originating shard/connection as
/// a wire reply. Runs until [`ingress::IngressController::begin_drain`]
/// is observed *and* everything in flight has drained — the same
/// exit-wait discipline as the in-process pump — then stops the shards
/// and returns the final ingress counters alongside the serve result.
pub fn serve_ingress<C: Clock, S: Scheduler, W: Worker>(
    mut core: ServingLoop<C, S>,
    workers: Vec<W>,
    net: Ingress,
) -> (ServeResult, IngressCounts) {
    let n = workers.len();
    assert_eq!(n, core.workers(), "one executor per scheduling replica");
    let (etx, erx) = mpsc::channel::<Msg>();
    let mut forwarded = 0usize;

    std::thread::scope(|scope| {
        let dispatch_txs = spawn_executors(scope, workers, &etx);
        drop(etx);

        // `open` only exists for `ingest`'s signature; no Msg::Arrival /
        // ArrivalsClosed flows here — arrivals come off the ring.
        let mut open = true;
        loop {
            // Worker-thread events first: completions free replicas.
            let mut progress = drain_events(&erx, &mut core, &mut open) > 0;
            // Bounded arrival sweep off the lock-free ring.
            let mut popped = 0usize;
            while popped < ARRIVALS_PER_SWEEP {
                let Some(req) = net.pop_arrival() else { break };
                popped += 1;
                let (id, at) = (req.id, req.release);
                if let Some(tel) = core.telemetry_mut() {
                    tel.record(
                        at,
                        crate::telemetry::EventKind::WireIn {
                            req: id,
                            shard: ingress::id_shard(id.0) as u16,
                        },
                    );
                }
                core.on_event(Event::Arrival(req));
            }
            progress |= popped > 0;
            progress |= ship_dispatches(&mut core, &dispatch_txs) > 0;
            progress |= forward_replies(&mut core, &net, &mut forwarded) > 0;
            if net.drain_requested()
                && net.arrivals_empty()
                && core.pending() == 0
                && core.in_flight() == 0
                && core.loading() == 0
            {
                break;
            }
            if !progress {
                // Idle: block briefly for worker events or the next wake
                // hint; the clamp keeps arrival-ring polling tight.
                let now = core.now();
                let wait_us = core
                    .next_wake(now)
                    .map(|h| h.saturating_sub(now).clamp(50, 1_000))
                    .unwrap_or(200);
                match erx.recv_timeout(Duration::from_micros(wait_us)) {
                    Ok(msg) => {
                        ingest(&mut core, msg, &mut open);
                        drain_events(&erx, &mut core, &mut open);
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => {}
                }
            }
        }
        drop(dispatch_txs);
    });

    // Terminal drops from the final drain still owe the wire a reply.
    core.drain_all();
    forward_replies(&mut core, &net, &mut forwarded);
    let counts = net.finish();
    let end_time = core.now();
    let placement = core.placement_stats();
    let admission = core.admission_stats();
    let telemetry = core.take_telemetry();
    let (completions, per_worker) = core.into_completions();
    (
        ServeResult {
            completions,
            per_worker,
            placement,
            admission,
            end_time,
            telemetry,
            shards: Vec::new(),
        },
        counts,
    )
}

// --- sharded wall-clock pump (DESIGN.md §13) -------------------------------

/// Handoff-ring capacity per scheduling shard. Pushes spin (never drop):
/// a handed-off request was already counted as a frame, so dropping it
/// here would break wire conservation — the ring only bounds memory.
const HANDOFF_CAP: usize = 1 << 12;

/// Everything a scheduling shard shares with its peers, by reference into
/// the coordinator's stack frame (the pump scope outlives the shards).
struct ShardCtx<'a> {
    /// Shard index and first global replica id owned.
    k: usize,
    lo: usize,
    /// Ingress arrival partitions this shard is the sole consumer of.
    parts: Vec<usize>,
    net: &'a Ingress,
    /// One handoff ring per shard; shard `k` pops only `handoff[k]`, any
    /// peer may push to it (the ring is multi-producer).
    handoff: &'a [ArrivalRing<(usize, Request)>],
    /// Shared board-backed router; `pick` returns global worker ids.
    picker: &'a BoardRouter,
    /// Global worker id → owning shard.
    worker_shard: &'a [usize],
    /// The full cluster placement (candidate sets span shards).
    placement: &'a Placement,
    /// Quiet-bit per shard + the stop latch (sharded-exit protocol).
    quiet_mask: &'a AtomicU64,
    stop: &'a AtomicBool,
    full_mask: u64,
}

/// Global candidate set for `model`, cached per model on first sight (the
/// only allocation on a shard's routing path, placement is static here —
/// the sharded pump refuses elastic configs).
fn model_candidates<'a>(
    cache: &'a mut Vec<(ModelId, Vec<usize>)>,
    placement: &Placement,
    n: usize,
    model: ModelId,
) -> &'a [usize] {
    let idx = match cache.iter().position(|(m, _)| *m == model) {
        Some(i) => i,
        None => {
            let ws: Vec<usize> = (0..n).filter(|&w| placement.hosts(w, model)).collect();
            cache.push((model, ws));
            cache.len() - 1
        }
    };
    &cache[idx].1
}

/// One scheduling shard: drains its own ingress partitions, routes via
/// the shared [`LoadBoard`], delivers local picks to its sub-core (the
/// `target` pin), hands remote picks to the owning shard's ring, runs its
/// replicas' executors, and publishes its replicas' load every sweep.
fn shard_pump<C: Clock, S: Scheduler, W: Worker>(
    mut core: ServingLoop<C, S>,
    workers: Vec<W>,
    target: Arc<AtomicUsize>,
    ctx: ShardCtx<'_>,
) -> (Vec<Completion>, Vec<WorkerStats>, Micros, ShardStats) {
    let bit = 1u64 << ctx.k;
    let mut stats = ShardStats {
        shard: ctx.k,
        lo: ctx.lo,
        workers: core.workers(),
        ..Default::default()
    };
    let start = core.now();
    let mut forwarded = 0usize;
    let mut ewma_ms = 0.0f64;
    let mut cand: Vec<(ModelId, Vec<usize>)> = Vec::new();
    let (etx, erx) = mpsc::channel::<Msg>();

    std::thread::scope(|scope| {
        let dispatch_txs = spawn_executors(scope, workers, &etx);
        drop(etx);
        let mut open = true;
        loop {
            let sweep_start = core.now();
            // Executor events first: completions free replicas.
            let mut progress = drain_events(&erx, &mut core, &mut open) > 0;
            // Bounded sweep over this shard's own ingress partitions.
            let mut popped = 0usize;
            for &p in &ctx.parts {
                while popped < ARRIVALS_PER_SWEEP {
                    let Some(req) = ctx.net.pop_arrival_from(p) else {
                        break;
                    };
                    popped += 1;
                    ewma_ms = if ewma_ms == 0.0 {
                        req.exec_ms
                    } else {
                        0.9 * ewma_ms + 0.1 * req.exec_ms
                    };
                    let ws = model_candidates(
                        &mut cand,
                        ctx.placement,
                        ctx.worker_shard.len(),
                        req.model,
                    );
                    let w = if ws.is_empty() {
                        // Unhosted model: deliver locally so the sub-core
                        // records the terminal drop (completes exactly once).
                        ctx.lo
                    } else {
                        ctx.picker.pick(ws)
                    };
                    if ctx.worker_shard[w] == ctx.k {
                        target.store(w - ctx.lo, Ordering::Release);
                        core.on_event(Event::Arrival(req));
                    } else {
                        // Remote pick: optimistic board bump, then hand off.
                        // Spin on a full ring — the frame is counted, a drop
                        // here would break conservation — but keep draining
                        // our own inbound ring while waiting, so two shards
                        // pushing into each other's full rings make mutual
                        // progress instead of deadlocking.
                        ctx.picker.board().note_routed(w);
                        stats.handoff_out += 1;
                        let mut item = (w, req);
                        while let Err(back) = ctx.handoff[ctx.worker_shard[w]].push(item) {
                            item = back;
                            if let Some((wr, inbound)) = ctx.handoff[ctx.k].pop() {
                                stats.handoff_in += 1;
                                target.store(wr - ctx.lo, Ordering::Release);
                                core.on_event(Event::Arrival(inbound));
                            } else {
                                std::thread::yield_now();
                            }
                        }
                    }
                }
            }
            stats.popped += popped as u64;
            // Requests peers routed to this shard's replicas.
            let mut handed = 0usize;
            while handed < ARRIVALS_PER_SWEEP {
                let Some((w, req)) = ctx.handoff[ctx.k].pop() else {
                    break;
                };
                handed += 1;
                target.store(w - ctx.lo, Ordering::Release);
                core.on_event(Event::Arrival(req));
            }
            stats.handoff_in += handed as u64;
            progress |= popped + handed > 0;
            progress |= ship_dispatches(&mut core, &dispatch_txs) > 0;
            // Authoritative board publish for the replicas this shard owns.
            for w_local in 0..core.workers() {
                let l = core.load_of(w_local);
                let est = ((l.pending + l.in_flight) as f64 * ewma_ms * 1_000.0) as u64;
                ctx.picker
                    .board()
                    .publish(ctx.lo + w_local, l.pending, l.in_flight, est);
            }
            progress |= forward_replies(&mut core, ctx.net, &mut forwarded) > 0;

            // Sharded-exit protocol: a shard is quiet when a drain was
            // requested and it owes nothing — partitions and handoff ring
            // empty, core drained. The last shard to go quiet re-verifies
            // *all* rings before latching `stop` (a peer's handoff push
            // happens-before its quiet bit, so a full mask plus empty
            // rings means no request can still be in flight between
            // shards); everyone exits on `stop` + own quiet.
            let quiet = ctx.net.drain_requested()
                && ctx.parts.iter().all(|&p| ctx.net.arrivals_empty_in(p))
                && ctx.handoff[ctx.k].is_empty()
                && core.pending() == 0
                && core.in_flight() == 0
                && core.loading() == 0;
            if quiet {
                let mask = ctx.quiet_mask.fetch_or(bit, Ordering::SeqCst) | bit;
                if mask == ctx.full_mask
                    && ctx.net.arrivals_empty()
                    && ctx.handoff.iter().all(|r| r.is_empty())
                {
                    ctx.stop.store(true, Ordering::SeqCst);
                }
                if ctx.stop.load(Ordering::SeqCst) {
                    break;
                }
            } else {
                ctx.quiet_mask.fetch_and(!bit, Ordering::SeqCst);
            }
            if progress {
                stats.busy_us += core.now().saturating_sub(sweep_start);
            } else {
                // Idle: block briefly for executor events or the next
                // wake hint; the clamp keeps ring polling tight.
                let now = core.now();
                let wait_us = core
                    .next_wake(now)
                    .map(|h| h.saturating_sub(now).clamp(50, 1_000))
                    .unwrap_or(200);
                match erx.recv_timeout(Duration::from_micros(wait_us)) {
                    Ok(msg) => {
                        ingest(&mut core, msg, &mut open);
                        drain_events(&erx, &mut core, &mut open);
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => {}
                }
            }
        }
        drop(dispatch_txs);
    });

    // Terminal drops from the final drain still owe the wire a reply.
    core.drain_all();
    forward_replies(&mut core, ctx.net, &mut forwarded);
    let end_time = core.now();
    let (completions, per_worker) = core.into_completions();
    stats.completions = completions.len() as u64;
    stats.wall_us = end_time.saturating_sub(start);
    (completions, per_worker, end_time, stats)
}

/// Serve a network [`Ingress`] with `shards` independent scheduling
/// shards, each owning a contiguous block of replicas on its own OS
/// thread (DESIGN.md §13): a frame goes wire → its ingress shard's ring
/// partition → the partition-owning scheduler shard → that shard's
/// executors without an mpsc hop, and only a load-aware routing decision
/// for a peer's replica crosses shards (over a lock-free handoff ring).
/// Load-aware routing stays available through the [`LoadBoard`] —
/// `least_loaded`/`join_shortest_queue` re-read as approximate board
/// snapshots — unlike the replay pump's load-oblivious-only sharding.
///
/// Falls back to the sequential [`serve_ingress`] (behaviorally and
/// byte-identical results) when `shards <= 1` or the configuration
/// couples replicas through global state the shards can't split:
/// elastic placement, admission control, telemetry, or a router with no
/// board-backed equivalent.
pub fn serve_ingress_sharded<C, S, W>(
    core: ServingLoop<C, S>,
    workers: Vec<W>,
    net: Ingress,
    shards: usize,
) -> (ServeResult, IngressCounts)
where
    C: Clock + Clone + Send,
    S: Scheduler,
    W: Worker,
{
    let n = workers.len();
    assert_eq!(n, core.workers(), "one executor per scheduling replica");
    let s = shards.clamp(1, n.max(1)).min(63);
    let policy = BoardPolicy::from_router_name(core.router_name());
    if s <= 1
        || core.elastic_enabled()
        || core.admission_enabled()
        || core.telemetry().is_some()
        || policy.is_none()
    {
        return serve_ingress(core, workers, net);
    }
    let policy = policy.expect("checked above");

    // Decompose the virgin core into per-shard sub-cores (contiguous
    // replica blocks, same bounds arithmetic as the replay lanes, §11).
    let (clock, mut scheds, placement, _router) = core.into_shard_parts();
    let mut lo = vec![0usize; s + 1];
    for (k, b) in lo.iter_mut().enumerate() {
        *b = k * n / s;
    }
    lo[s] = n;
    let mut worker_shard = vec![0usize; n];
    for k in 0..s {
        for w in lo[k]..lo[k + 1] {
            worker_shard[w] = k;
        }
    }
    let mut shard_scheds: Vec<Vec<S>> = Vec::with_capacity(s);
    let mut shard_workers: Vec<Vec<W>> = Vec::with_capacity(s);
    let mut workers = workers;
    for k in (0..s).rev() {
        shard_scheds.push(scheds.split_off(lo[k]));
        shard_workers.push(workers.split_off(lo[k]));
    }
    shard_scheds.reverse();
    shard_workers.reverse();

    // Ingress partition → scheduler shard, contiguous (partition p of P
    // goes to shard p·S/P), so each partition has exactly one consumer.
    let parts = net.arrival_partitions();
    let part_owner: Vec<usize> = (0..parts).map(|p| p * s / parts).collect();

    let board = Arc::new(LoadBoard::new(n));
    let picker = BoardRouter::new(board, policy);
    let handoff: Vec<ArrivalRing<(usize, Request)>> =
        (0..s).map(|_| ArrivalRing::new(HANDOFF_CAP)).collect();
    let quiet_mask = AtomicU64::new(0);
    let stop = AtomicBool::new(false);
    let full_mask = (1u64 << s) - 1;

    struct ShardInput<C, S, W> {
        core: ServingLoop<C, S>,
        workers: Vec<W>,
        target: Arc<AtomicUsize>,
        k: usize,
    }
    let inputs: Vec<ShardInput<C, S, W>> = shard_scheds
        .into_iter()
        .zip(shard_workers)
        .enumerate()
        .map(|(k, (scheds_k, workers_k))| {
            let len = lo[k + 1] - lo[k];
            let sub_placement = if placement.is_unconstrained() {
                Placement::unconstrained(len)
            } else {
                Placement::new(
                    (lo[k]..lo[k + 1])
                        .map(|w| {
                            placement
                                .hosted_on(w)
                                .map(<[ModelId]>::to_vec)
                                .unwrap_or_default()
                        })
                        .collect(),
                )
            };
            let target = Arc::new(AtomicUsize::new(0));
            let sub = ServingLoop::new(
                clock.clone(),
                Cluster::with_placement(scheds_k, sub_placement),
                Box::new(Pinned::new(target.clone())),
            );
            ShardInput {
                core: sub,
                workers: workers_k,
                target,
                k,
            }
        })
        .collect();

    let results: Vec<(Vec<Completion>, Vec<WorkerStats>, Micros, ShardStats)> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = inputs
                .into_iter()
                .map(|inp| {
                    let ctx = ShardCtx {
                        k: inp.k,
                        lo: lo[inp.k],
                        parts: (0..parts).filter(|&p| part_owner[p] == inp.k).collect(),
                        net: &net,
                        handoff: &handoff,
                        picker: &picker,
                        worker_shard: &worker_shard,
                        placement: &placement,
                        quiet_mask: &quiet_mask,
                        stop: &stop,
                        full_mask,
                    };
                    scope.spawn(move || shard_pump(inp.core, inp.workers, inp.target, ctx))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("scheduler shard panicked"))
                .collect()
        });

    // Merge: lift worker ids back to global, stable-sort completions by
    // completion time (matching the sequential pump's order).
    let mut completions = Vec::new();
    let mut per_worker = Vec::new();
    let mut shard_stats = Vec::with_capacity(s);
    let mut end_time = 0;
    for (k, (comps, ws, end, st)) in results.into_iter().enumerate() {
        let base = lo[k];
        completions.extend(comps.into_iter().map(|mut c| {
            c.worker = c.worker.map(|w| w + base);
            c
        }));
        per_worker.extend(ws.into_iter().map(|mut w| {
            w.worker += base;
            w
        }));
        end_time = end_time.max(end);
        shard_stats.push(st);
    }
    completions.sort_by_key(|c| c.at);
    let counts = net.finish();
    (
        ServeResult {
            completions,
            per_worker,
            placement: PlacementStats::default(),
            admission: AdmissionStats::default(),
            end_time,
            telemetry: None,
            shards: shard_stats,
        },
        counts,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::edf::EdfScheduler;
    use crate::clock::{ms_to_us, RealClock};
    use crate::core::batchmodel::BatchCostModel;
    use crate::core::request::AppId;
    use crate::scheduler::SchedulerConfig;
    use crate::serve::{
        router, Cluster, ColdStartCost, ElasticConfig, Placement, PlacementController,
    };
    use crate::sim::worker::SimWorker;

    fn edf_scheds(n: usize) -> Vec<EdfScheduler> {
        let cfg = SchedulerConfig {
            cost_model: BatchCostModel::new(0.0, 1.0),
            ..Default::default()
        };
        (0..n)
            .map(|_| {
                let mut s = EdfScheduler::new(cfg.clone(), 0);
                s.seed_exec_mean(1.0);
                s
            })
            .collect()
    }

    #[test]
    fn drains_and_reports_per_worker() {
        let core = ServingLoop::new(
            RealClock::new(),
            Cluster::new(edf_scheds(2)),
            router::by_name("round_robin").unwrap(),
        );
        let workers: Vec<SimWorker> = (0..2)
            .map(|w| SimWorker::new(BatchCostModel::new(0.0, 1.0), 0.0, w))
            .collect();
        let (tx, rx) = mpsc::channel();
        for i in 0..16u64 {
            tx.send(Request::new(i, AppId(0), 0, ms_to_us(5_000.0), 1.0))
                .unwrap();
        }
        drop(tx);
        let res = serve_cluster(core, workers, rx);
        assert_eq!(res.completions.len(), 16);
        assert_eq!(res.per_worker.len(), 2);
        assert!(res.per_worker.iter().map(|w| w.batches).sum::<usize>() > 0);
        assert_eq!(res.placement.actions(), 0);
    }

    #[test]
    fn elastic_loads_complete_on_worker_threads() {
        // Two workers, partition placement over two models, all traffic on
        // model 0: the controller must replicate model 0 onto worker 1
        // through the worker thread's load_model and the run must still
        // drain (the exit condition waits for in-flight loads).
        let placement = Placement::parse("partition", 2, 2).unwrap();
        let cluster = Cluster::with_placement(edf_scheds(2), placement);
        let ctl = PlacementController::new(ElasticConfig {
            capacity: 2,
            interval_us: 1_000,
            alpha: 1.0,
            min_dwell_us: 0,
            cold_start: ColdStartCost::new(0.5, 0.5),
        });
        let core = ServingLoop::new(
            RealClock::new(),
            cluster,
            router::by_name("least_loaded").unwrap(),
        )
        .with_elastic(ctl);
        let workers: Vec<SimWorker> = (0..2)
            .map(|w| SimWorker::new(BatchCostModel::new(0.0, 1.0), 0.0, w))
            .collect();
        let (tx, rx) = mpsc::channel();
        let handle = std::thread::spawn(move || {
            for i in 0..60u64 {
                tx.send(Request::new(i, AppId(0), 0, ms_to_us(5_000.0), 1.0))
                    .unwrap();
                std::thread::sleep(Duration::from_micros(300));
            }
        });
        let res = serve_cluster(core, workers, rx);
        handle.join().unwrap();
        assert_eq!(res.completions.len(), 60, "conservation under elastic");
        assert!(
            res.placement.loads >= 1,
            "hot model should replicate: {:?}",
            res.placement
        );
    }
}
